"""Workload subsystem tests (DESIGN.md §8).

Preprocessing geometry, NMS/decode math (property-based + numpy
references), the Workload/WorkloadEngine surface, golden-fixture
regressions per paper net, and the cross-backend / served-bucket
conformance sweeps driven by ``tests/harness.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import harness
from repro import workloads
from repro.workloads import (DetectConfig, decode_yolo, detect_head,
                             iou_matrix, letterbox, letterbox_boxes,
                             nms_fixed, topk_head, unletterbox_boxes)


# --------------------------------------------------------------------------
# Preprocessing
# --------------------------------------------------------------------------

class TestPreprocess:
    def test_letterbox_geometry(self):
        img = jnp.asarray(np.full((100, 50, 3), 200, np.uint8))
        out = np.asarray(letterbox(img, (64, 64)))
        assert out.shape == (64, 64, 3) and out.dtype == np.uint8
        # 100x50 scales by 0.64 -> 64x32 content, 16px gray bars each side
        assert (out[:, :16] == workloads.preprocess.LETTERBOX_FILL).all()
        assert (out[:, -16:] == workloads.preprocess.LETTERBOX_FILL).all()
        assert (out[:, 16:48] == 200).all()

    def test_letterbox_network_size_is_identity(self):
        rng = np.random.default_rng(0)
        img = rng.integers(0, 256, (64, 64, 3), dtype=np.uint8)
        out = np.asarray(letterbox(jnp.asarray(img), (64, 64)))
        np.testing.assert_array_equal(out, img)

    def test_center_crop_resize(self):
        rng = np.random.default_rng(1)
        img = rng.integers(0, 256, (37, 91, 3), dtype=np.uint8)
        out = np.asarray(workloads.center_crop_resize(jnp.asarray(img),
                                                      (16, 16)))
        assert out.shape == (16, 16, 3) and out.dtype == np.uint8

    def test_server_hook_matches_transform(self):
        wl = harness.conformance_workload("yolov2_tiny_voc")
        rng = np.random.default_rng(2)
        img = rng.integers(0, 256, (50, 70, 3), dtype=np.uint8)
        np.testing.assert_array_equal(
            wl.preprocess_hook(img),
            np.asarray(wl.preprocess(jnp.asarray(img))))

    @given(st.integers(8, 200), st.integers(8, 200),
           st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_letterbox_box_roundtrip_within_1px(self, h, w, seed):
        """Box coords mapped into the letterbox frame and back land
        within 1px of where they started (the satellite invariant)."""
        rng = np.random.default_rng(seed)
        x1, y1 = rng.uniform(0, w - 1), rng.uniform(0, h - 1)
        box = np.array([[x1, y1, rng.uniform(x1, w), rng.uniform(y1, h)]])
        fwd = letterbox_boxes(box, (h, w), (64, 64))
        back = unletterbox_boxes(fwd, (h, w), (64, 64))
        assert np.abs(back - box).max() < 1.0


# --------------------------------------------------------------------------
# NMS invariants (property-based)
# --------------------------------------------------------------------------

def _random_boxes(rng, n, extent=100.0):
    x1y1 = rng.uniform(0, extent * 0.8, (n, 2))
    wh = rng.uniform(1, extent * 0.4, (n, 2))
    return np.concatenate([x1y1, x1y1 + wh], -1).astype(np.float32)


def _valid_rows(rows):
    rows = np.asarray(rows)
    return rows[rows[:, 4] > 0]


class TestNMSInvariants:
    @given(st.integers(2, 24), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_permutation_invariance(self, n, seed):
        rng = np.random.default_rng(seed)
        boxes = _random_boxes(rng, n)
        scores = rng.uniform(0.01, 1, n).astype(np.float32)
        perm = rng.permutation(n)
        a = _valid_rows(nms_fixed(jnp.asarray(boxes), jnp.asarray(scores),
                                  iou_thresh=0.5, max_det=n))
        b = _valid_rows(nms_fixed(jnp.asarray(boxes[perm]),
                                  jnp.asarray(scores[perm]),
                                  iou_thresh=0.5, max_det=n))
        np.testing.assert_array_equal(a, b)

    @given(st.integers(2, 24), st.floats(0.1, 0.9),
           st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_kept_boxes_iou_bounded(self, n, iou_t, seed):
        """The defining greedy-NMS invariant: no two surviving boxes of
        the same class overlap by more than the threshold."""
        rng = np.random.default_rng(seed)
        kept = _valid_rows(nms_fixed(
            jnp.asarray(_random_boxes(rng, n)),
            jnp.asarray(rng.uniform(0.01, 1, n).astype(np.float32)),
            iou_thresh=iou_t, max_det=n))
        if len(kept) > 1:
            ious = np.array(iou_matrix(jnp.asarray(kept[:, :4]),
                                       jnp.asarray(kept[:, :4])))
            np.fill_diagonal(ious, 0)
            assert ious.max() <= iou_t + 1e-6

    @given(st.integers(2, 24), st.integers(1, 6), st.floats(0.0, 0.8),
           st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_k_cap_and_score_floor(self, n, max_det, score_t, seed):
        rng = np.random.default_rng(seed)
        rows = np.asarray(nms_fixed(
            jnp.asarray(_random_boxes(rng, n)),
            jnp.asarray(rng.uniform(0.01, 1, n).astype(np.float32)),
            iou_thresh=0.5, score_thresh=score_t, max_det=max_det))
        assert rows.shape == (max_det, 6)
        kept = _valid_rows(rows)
        assert len(kept) <= max_det
        assert (kept[:, 4] >= score_t).all()
        # survivors first, score-descending; padding rows all-zero
        assert (kept[:, 4] == np.sort(kept[:, 4])[::-1]).all()
        assert (rows[len(kept):] == 0).all()

    @given(st.integers(2, 16), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_matches_numpy_greedy_reference(self, n, seed):
        rng = np.random.default_rng(seed)
        boxes = _random_boxes(rng, n)
        scores = rng.uniform(0.01, 1, n).astype(np.float32)
        iou_t = 0.45
        order = np.argsort(-scores, kind="stable")
        keep: list[int] = []
        for i in order:
            ious = np.asarray(iou_matrix(jnp.asarray(boxes[i][None]),
                                         jnp.asarray(boxes[keep])))
            if not keep or (ious <= iou_t).all():
                keep.append(int(i))
        expect = np.concatenate(
            [boxes[keep], scores[keep, None],
             np.zeros((len(keep), 1), np.float32)], -1)
        got = _valid_rows(nms_fixed(jnp.asarray(boxes),
                                    jnp.asarray(scores),
                                    iou_thresh=iou_t, max_det=n))
        np.testing.assert_array_equal(got, expect)

    def test_zero_score_never_occupies_a_slot(self):
        """score > 0 is the validity mask: a candidate scored exactly 0
        must not survive even at score_thresh=0."""
        boxes = jnp.asarray([[0, 0, 5, 5], [20, 20, 30, 30]], jnp.float32)
        scores = jnp.asarray([0.0, 0.4], jnp.float32)
        rows = np.asarray(nms_fixed(boxes, scores, iou_thresh=0.5,
                                    score_thresh=0.0, max_det=2))
        kept = _valid_rows(rows)
        assert len(kept) == 1 and kept[0, 4] == np.float32(0.4)
        assert (rows[1:] == 0).all()

    def test_class_aware_nms_keeps_cross_class_overlaps(self):
        boxes = jnp.asarray([[0, 0, 10, 10], [0, 0, 10, 10]], jnp.float32)
        scores = jnp.asarray([0.9, 0.8], jnp.float32)
        same = _valid_rows(nms_fixed(boxes, scores,
                                     jnp.asarray([1, 1], jnp.int32),
                                     iou_thresh=0.5, max_det=2))
        diff = _valid_rows(nms_fixed(boxes, scores,
                                     jnp.asarray([1, 2], jnp.int32),
                                     iou_thresh=0.5, max_det=2))
        assert len(same) == 1 and len(diff) == 2


# --------------------------------------------------------------------------
# YOLO decode math
# --------------------------------------------------------------------------

class TestDecode:
    def test_decode_matches_numpy_reference(self):
        cfg = DetectConfig(anchors=((1.0, 2.0), (3.0, 1.5)), n_classes=3,
                           class_names=None)
        rng = np.random.default_rng(4)
        feat = rng.normal(0, 1.5, (2, 3, 4, cfg.channels)).astype(
            np.float32)
        boxes, scores, classes = decode_yolo(jnp.asarray(feat), cfg,
                                             (48, 64))
        f = feat.reshape(2, 3, 4, 2, 8)
        sig = lambda v: 1 / (1 + np.exp(-v))
        e = np.exp(f[..., 5:] - f[..., 5:].max(-1, keepdims=True))
        probs = e / e.sum(-1, keepdims=True)
        bx = (sig(f[..., 0]) + np.arange(4)[None, None, :, None]) / 4
        by = (sig(f[..., 1]) + np.arange(3)[None, :, None, None]) / 3
        anchors = np.array(cfg.anchors, np.float32)
        bw = anchors[:, 0] * np.exp(f[..., 2]) / 4
        bh = anchors[:, 1] * np.exp(f[..., 3]) / 3
        score_ref = sig(f[..., 4]) * probs.max(-1)
        x1 = np.clip((bx - bw / 2) * 64, 0, 64)
        y1 = np.clip((by - bh / 2) * 48, 0, 48)
        np.testing.assert_allclose(np.asarray(scores).reshape(2, 3, 4, 2),
                                   score_ref, rtol=0, atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(classes).reshape(2, 3, 4, 2), probs.argmax(-1))
        got_boxes = np.asarray(boxes).reshape(2, 3, 4, 2, 4)
        np.testing.assert_allclose(got_boxes[..., 0], x1, rtol=0,
                                   atol=1e-4)
        np.testing.assert_allclose(got_boxes[..., 1], y1, rtol=0,
                                   atol=1e-4)

    def test_detect_head_fixed_shape_and_validity(self):
        cfg = DetectConfig(n_classes=20, score_thresh=0.01, max_det=7)
        rng = np.random.default_rng(5)
        feat = jnp.asarray(rng.normal(0, 2, (3, 2, 2, cfg.channels)),
                           jnp.float32)
        rows = np.asarray(detect_head(feat, cfg, (32, 32)))
        assert rows.shape == (3, 7, 6)
        valid = rows[..., 4] > 0
        assert valid.any()
        assert (rows[..., :4] >= 0).all() and (rows[..., :4] <= 32).all()
        assert (rows[~valid] == 0).all()

    def test_topk_head(self):
        logits = jnp.asarray([[0.0, 2.0, 1.0, -1.0]])
        rows = np.asarray(topk_head(logits, 3))
        assert rows.shape == (1, 3, 2)
        np.testing.assert_array_equal(rows[0, :, 0], [1, 2, 0])
        assert (np.diff(rows[0, :, 1]) <= 0).all()
        np.testing.assert_allclose(rows[0, :, 1].sum(), 1.0, atol=0.2)


# --------------------------------------------------------------------------
# Workload surface
# --------------------------------------------------------------------------

class TestWorkloadSurface:
    def test_registry(self):
        assert set(workloads.names()) >= {"alexnet_imagenet",
                                          "vgg16_imagenet",
                                          "yolov2_tiny_voc"}
        with pytest.raises(KeyError, match="unknown workload"):
            workloads.get("resnet50")
        with pytest.raises(ValueError, match="dense layers fixed"):
            workloads.get("alexnet_imagenet", variant="tiny", input_hw=64)

    def test_checkpoint_params_deterministic(self):
        wl1 = harness.conformance_workload("alexnet_imagenet")
        wl2 = harness.conformance_workload("alexnet_imagenet")
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            wl1.params, wl2.params)

    def test_engine_composes_head(self):
        wl = harness.conformance_workload("vgg16_imagenet")
        x = harness.seeded_batch(wl)
        np.testing.assert_array_equal(
            np.asarray(wl.engine(x)),
            np.asarray(jax.jit(wl.postprocess)(wl.engine.raw(x))))

    def test_engine_trace_count_covers_head(self):
        wl = harness.conformance_workload("alexnet_imagenet")
        x = harness.seeded_batch(wl, batch=1)
        wl.engine(x)
        n = wl.engine.trace_count
        assert n >= 2                      # forward + head
        wl.engine(x)
        assert wl.engine.trace_count == n  # cached executable, no retrace

    def test_predict_and_format(self):
        wl = harness.conformance_workload("yolov2_tiny_voc")
        rng = np.random.default_rng(6)
        preds = wl.predict([rng.integers(0, 256, (40, 56, 3),
                                         dtype=np.uint8)])
        dets = wl.format(preds[0])
        assert all({"box", "score", "class_id", "label"} <= set(d)
                   for d in dets)
        wc = harness.conformance_workload("alexnet_imagenet")
        rows = wc.predict([rng.integers(0, 256, (20, 20, 3),
                                        dtype=np.uint8)])
        top = wc.format(rows[0])
        assert len(top) == wc.top_k
        assert all(0 <= t["prob"] <= 1 for t in top)


# --------------------------------------------------------------------------
# Golden-file regressions (regen: pytest --regen-golden)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", harness.CONFORMANCE_NAMES)
def test_golden_fixture(name, regen_golden):
    harness.check_golden(name, regen=regen_golden)


# --------------------------------------------------------------------------
# Conformance sweeps: all backends x all workloads, served buckets
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", harness.CONFORMANCE_NAMES)
def test_all_backends_bit_exact(name):
    harness.sweep_backends(name)


def test_served_buckets_detect():
    harness.sweep_served_buckets(
        harness.conformance_workload("yolov2_tiny_voc"))


def test_served_buckets_classify():
    harness.sweep_served_buckets(
        harness.conformance_workload("alexnet_imagenet"))


def test_paper_yolo_serves_image_to_boxes():
    """The acceptance path: the real YOLOv2-Tiny spec (reduced resolution
    — fully convolutional) behind workloads.get -> InferenceServer, with
    zero serve-time retraces and cross_check-exact decoded rows."""
    wl = workloads.get("yolov2_tiny_voc", input_hw=64,
                       detect=harness.CONFORMANCE_DETECT,
                       seed=harness.SEED)
    assert wl.name == "yolov2_tiny_voc"
    # buckets (1, 4) with groups (1, 2, 1): the middle group of 2 serves
    # zero-padded to bucket 4.
    harness.sweep_served_buckets(wl, buckets=(1, 4), n_requests=4,
                                 raw_hw=(96, 128))
