"""Multi-tenant multiplexer tests (DESIGN.md §12).

Weighted-fair row splits under saturation, strict priority classes,
idle-lane vtime catch-up (no banked credit), per-tenant degradation
isolation via tenant-matched fault injection, per-tenant metrics and
flight-recorder tagging, and bit-exactness of multiplexed serving
against each engine's own live-compiled reference.
"""

import jax
import numpy as np
import pytest

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.serving import MultiTenantServer, PhoneBitEngine, faults
from repro.serving.faults import FaultPlan, FaultSpec, RetryPolicy

SPEC_A = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
          Pool(2, 2), FloatDense(8 * 8 * 16, 10)]
SPEC_B = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
          Pool(2, 2), FloatDense(8 * 8 * 32, 10)]


@pytest.fixture(scope="module")
def eng_a():
    params = bnn_model.init_params(jax.random.key(0), SPEC_A)
    return PhoneBitEngine.from_trained(params, SPEC_A, (16, 16))


@pytest.fixture(scope="module")
def eng_b():
    params = bnn_model.init_params(jax.random.key(1), SPEC_B)
    return PhoneBitEngine.from_trained(params, SPEC_B, (16, 16))


def _images(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(n)]


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += max(s, 0.0)


def _mux(**kw):
    clock = FakeClock()
    kw.setdefault("buckets", (1, 2))
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_wait_s", 0.0)
    return MultiTenantServer(clock=clock, sleep=clock.sleep, **kw), clock


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    yield
    faults.uninstall()


# --------------------------------------------------------------------------
# registration contract
# --------------------------------------------------------------------------

class TestRegistration:
    def test_duplicate_tenant_rejected(self, eng_a):
        mux, _ = _mux()
        mux.add_tenant("a", eng_a)
        with pytest.raises(ValueError, match="already registered"):
            mux.add_tenant("a", eng_a)

    def test_nonpositive_weight_rejected(self, eng_a):
        mux, _ = _mux()
        with pytest.raises(ValueError, match="weight"):
            mux.add_tenant("a", eng_a, weight=0.0)

    def test_unknown_tenant_submit_raises(self, eng_a):
        mux, _ = _mux()
        mux.add_tenant("a", eng_a)
        with pytest.raises(KeyError, match="unknown tenant"):
            mux.submit("nope", _images(1)[0])


# --------------------------------------------------------------------------
# weighted fairness + priority
# --------------------------------------------------------------------------

class TestFairness:
    def test_weighted_rows_split_3_to_1(self, eng_a, eng_b):
        """Both lanes saturated over an 8-step window: dispatched device
        rows split exactly by weight (a charged rows/3, b rows/1)."""
        mux, _ = _mux()
        mux.add_tenant("a", eng_a, weight=3.0)
        mux.add_tenant("b", eng_b, weight=1.0)
        mux.server("a").compile_buckets()
        mux.server("b").compile_buckets()
        ra = [mux.submit("a", i) for i in _images(16)]
        rb = [mux.submit("b", i) for i in _images(16, seed=1)]
        for _ in range(8):
            mux.step(force=True)
        rows = {t: mux.server(t).dispatched_rows for t in ("a", "b")}
        assert rows == {"a": 12, "b": 4}
        mux.drain()
        assert all(r.outcome == "served" for r in ra + rb)
        fair = mux.metrics()["fairness"]
        assert fair["a"]["weight"] == 3.0
        # equal weighted shares: vtime converges across lanes
        assert fair["a"]["dispatched_rows"] == 16
        assert fair["b"]["dispatched_rows"] == 16

    def test_priority_class_preempts(self, eng_a, eng_b):
        """A backlogged higher-priority lane dispatches exclusively
        until its queue empties, regardless of weights."""
        mux, _ = _mux()
        mux.add_tenant("hi", eng_a, priority=1, weight=1.0)
        mux.add_tenant("lo", eng_b, priority=0, weight=100.0)
        rs_hi = [mux.submit("hi", i) for i in _images(4)]
        rs_lo = [mux.submit("lo", i) for i in _images(4, seed=1)]
        for _ in range(2):                  # 2 steps x bucket-2 batches
            mux.step(force=True)
        assert mux.server("hi").dispatched_rows == 4
        assert mux.server("lo").dispatched_rows == 0
        mux.drain()
        assert all(r.outcome == "served" for r in rs_hi + rs_lo)
        assert mux.server("lo").dispatched_rows == 4

    def test_idle_lane_banks_no_credit(self, eng_a, eng_b):
        """A lane waking from idle starts at the arbiter's virtual
        clock — it cannot burst on vtime accumulated while empty."""
        mux, _ = _mux()
        mux.add_tenant("a", eng_a)
        mux.add_tenant("b", eng_b)
        for i in _images(6):
            mux.submit("a", i)
        for _ in range(3):
            mux.step(force=True)
        assert mux.lanes["a"].vtime == pytest.approx(6.0)
        assert mux.lanes["b"].vtime == 0.0      # idle, never charged
        mux.submit("b", _images(1)[0])
        # catch-up: b competes from _v, not from 0
        assert mux.lanes["b"].vtime == pytest.approx(mux._v)
        assert mux.lanes["b"].vtime == pytest.approx(
            mux.lanes["a"].vtime)
        mux.drain()


# --------------------------------------------------------------------------
# isolation
# --------------------------------------------------------------------------

class TestIsolation:
    def _engines_one_rung_up(self, eng_a, eng_b):
        a = PhoneBitEngine(spec=eng_a.spec, packed=eng_a.packed,
                           input_hw=eng_a.input_hw, matmul_mode="xla_pm1")
        b = PhoneBitEngine(spec=eng_b.spec, packed=eng_b.packed,
                           input_hw=eng_b.input_hw, matmul_mode="xla_pm1")
        return a, b

    def test_degradation_is_per_tenant(self, eng_a, eng_b):
        """Faults matched to tenant 'a' demote a's backend ladder only:
        b keeps serving on its configured mode, bit-exact."""
        a, b = self._engines_one_rung_up(eng_a, eng_b)
        mux, _ = _mux(buckets=(1,), max_batch=1,
                      retry=RetryPolicy(max_attempts=4,
                                        backoff_base_s=0.001, jitter=0.0),
                      demote_after=1, probe_after_s=1000.0)
        mux.add_tenant("a", a)
        mux.add_tenant("b", b)
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault",
                      match={"tenant": "a", "mode": "xla_pm1"})]))
        try:
            ra = [mux.submit("a", i) for i in _images(2)]
            rb = [mux.submit("b", i) for i in _images(2, seed=1)]
            mux.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in ra + rb)
        assert mux.server("a").health.mode == "xla"       # demoted
        assert mux.server("b").health.mode == "xla_pm1"   # untouched
        assert mux.server("a").metrics()["degraded"] == 1
        assert mux.server("b").metrics()["degraded"] == 0
        # b's results come off its healthy fast path, bit-exact
        img = _images(2, seed=1)[0]
        want = np.asarray(b.compile(1, mode="xla_pm1")(
            np.asarray(img)[None]))[0]
        np.testing.assert_array_equal(np.asarray(rb[0].result), want)

    def test_per_tenant_metrics_and_flight_tags(self, eng_a, eng_b):
        mux, _ = _mux()
        mux.add_tenant("a", eng_a)
        mux.add_tenant("b", eng_b)
        rs = [mux.submit("a", i) for i in _images(2)]
        rs += [mux.submit("b", i) for i in _images(2, seed=1)]
        mux.drain()
        assert all(r.outcome == "served" for r in rs)
        m = mux.metrics()
        assert m["tenants"]["a"]["tenant"] == "a"
        assert m["tenants"]["b"]["tenant"] == "b"
        assert m["queue_depth"] == 0
        for t in ("a", "b"):
            recs = mux.server(t).flight.dump()
            assert recs and all(r["tenant"] == t for r in recs)


# --------------------------------------------------------------------------
# numerics: multiplexing never changes results
# --------------------------------------------------------------------------

def test_multitenant_workloads_match_cross_check_oracle():
    """Two registered workloads behind one multiplexer: every served
    decoded prediction equals the workload's own ``cross_check`` oracle
    (which itself asserts graph == legacy-flat bit-exactness) on the
    identically-preprocessed input."""
    import jax.numpy as jnp

    from repro import workloads

    mux, _ = _mux(buckets=(1,), max_batch=1)
    wls = {"alex": workloads.get("alexnet_imagenet", variant="tiny"),
           "vgg": workloads.get("vgg16_imagenet", variant="tiny")}
    for t, wl in wls.items():
        mux.add_workload(t, wl)
    rng = np.random.default_rng(0)
    # off-network sizes: the lane's preprocess hook must normalize
    imgs = {t: [rng.integers(0, 256, (24, 20, 3), dtype=np.uint8)
                for _ in range(2)] for t in wls}
    rs = {t: [mux.submit(t, i) for i in imgs[t]] for t in wls}
    mux.drain()
    for t, wl in wls.items():
        assert all(r.outcome == "served" for r in rs[t])
        for r, img in zip(rs[t], imgs[t]):
            x = jnp.stack([wl.preprocess(jnp.asarray(img))])
            want = np.asarray(wl.engine.cross_check(x))[0]
            np.testing.assert_array_equal(np.asarray(r.result), want)


def test_multiplexed_results_bitexact(eng_a, eng_b):
    """Every multiplexed result equals the owning engine's own
    live-compiled batch-1 reference, bit for bit."""
    mux, _ = _mux(buckets=(1,), max_batch=1)
    mux.add_tenant("a", eng_a)
    mux.add_tenant("b", eng_b)
    imgs_a, imgs_b = _images(3), _images(3, seed=1)
    ra = [mux.submit("a", i) for i in imgs_a]
    rb = [mux.submit("b", i) for i in imgs_b]
    mux.drain()
    assert all(r.outcome == "served" for r in ra + rb)
    fa, fb = eng_a.compile(1), eng_b.compile(1)
    for r, img in zip(ra, imgs_a):
        np.testing.assert_array_equal(
            np.asarray(r.result), np.asarray(fa(np.asarray(img)[None]))[0])
    for r, img in zip(rb, imgs_b):
        np.testing.assert_array_equal(
            np.asarray(r.result), np.asarray(fb(np.asarray(img)[None]))[0])
