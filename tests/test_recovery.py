"""Crash-safety tests (DESIGN.md §14).

The three §14 mechanisms, each proven at its contract:

* **KV checkpoint/restore** — a decode fault that exhausts the retry
  budget restores from the last consistent cut and replays the ≤N
  uncheckpointed tokens **bit-exactly** (the faulted run's token stream
  equals the unfaulted baseline's, for N ∈ {1, 4}); the
  ``kv.snapshot`` / ``kv.restore`` fault sites exercise the snapshot
  policy (cadence faults keep the old cut, admission faults invalidate
  it, restore faults burn an attempt).
* **Durable request journal** — WAL order, fsynced appends, torn-tail
  tolerance, jid continuation across reopens, and the end-to-end kill
  -9 pin: a subprocess serving from an AOT artifact + journal is
  SIGKILLed mid-stream, and a fresh process replays every
  journaled-but-unresolved request with **zero** serve-time retraces.
* **Per-bucket backend health** — a fault pinned to one batch bucket
  demotes only that bucket's ladder; other buckets keep their fast
  backend, and the demoted bucket re-probes/promotes on its own
  (§11 ladder semantics, now bucket-scoped).

Plus the §14.4 migration path: an LMReplicaGroup lane whose restore
budget is exhausted hands its in-flight sequences to a healthy lane,
prefix-preserved.
"""

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.serving import InferenceServer, PhoneBitEngine, faults
from repro.serving.faults import (BucketHealth, FaultPlan, FaultSpec,
                                  RetryPolicy)
from repro.serving.recovery import (RequestJournal, decode_payload,
                                    encode_payload, replay_journal)

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def tiny_engine():
    spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
            Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    return PhoneBitEngine.from_trained(params, spec, (16, 16))


def _images(n, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(n)]


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += max(s, 0.0)


def _server(engine, clock=None, **kw):
    clock = clock or FakeClock()
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_s", 0.0)
    return InferenceServer(engine, clock=clock, sleep=clock.sleep, **kw), \
        clock


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    yield
    faults.uninstall()


# --------------------------------------------------------------------------
# Request journal: format, WAL order, torn tails
# --------------------------------------------------------------------------

class TestRequestJournal:
    def test_submit_resolve_scan(self, tmp_path):
        j = RequestJournal(tmp_path / "j.jsonl")
        a = j.submit("lm", ([1, 2, 3], 4))
        b = j.submit("lm", ([5], 2))
        j.resolve(a, "served")
        j.close()
        state = RequestJournal.scan(tmp_path / "j.jsonl")
        assert not state.torn_tail
        assert list(state.unresolved) == [b]
        assert state.unresolved[b]["payload"]["prompt"] == [5]
        assert state.max_jid == b

    def test_jid_continues_across_reopen(self, tmp_path):
        j1 = RequestJournal(tmp_path / "j.jsonl")
        last = [j1.submit("lm", ([1], 1)) for _ in range(3)][-1]
        j1.close()
        j2 = RequestJournal(tmp_path / "j.jsonl")
        assert j2.submit("lm", ([2], 1)) == last + 1
        j2.close()

    def test_torn_tail_tolerated(self, tmp_path):
        j = RequestJournal(tmp_path / "j.jsonl")
        a = j.submit("lm", ([1, 2], 4))
        j.submit("lm", ([3], 2))
        j.close()
        # a kill -9 mid-append leaves a half-written last line
        with open(tmp_path / "j.jsonl", "a") as f:
            f.write('{"op": "resolve", "jid')
        state = RequestJournal.scan(tmp_path / "j.jsonl")
        assert state.torn_tail
        assert len(state.unresolved) == 2      # both submits survive
        assert a in state.unresolved

    def test_payload_roundtrip(self):
        prompt, max_new = decode_payload(
            "lm", encode_payload("lm", ([7, 8, 9], 5)))
        assert prompt == [7, 8, 9] and max_new == 5
        img = _images(1)[0]
        back = decode_payload("bnn", encode_payload("bnn", img))
        np.testing.assert_array_equal(back, img)
        assert back.dtype == img.dtype
        with pytest.raises(ValueError):
            encode_payload("nope", None)

    def test_fresh_journal_on_missing_file(self, tmp_path):
        state = RequestJournal.scan(tmp_path / "absent.jsonl")
        assert state.records == [] and state.max_jid == -1


class TestJournalServing:
    def test_wal_closes_every_record(self, tiny_engine, tmp_path):
        j = RequestJournal(tmp_path / "j.jsonl")
        server, _ = _server(tiny_engine, journal=j)
        rs = [server.submit(p) for p in _images(3)]
        server.drain()
        j.close()
        assert all(r.outcome == "served" for r in rs)
        state = RequestJournal.scan(tmp_path / "j.jsonl")
        assert not state.unresolved
        assert sum(1 for r in state.records if r["op"] == "submit") == 3

    def test_rejected_submit_not_journaled(self, tiny_engine, tmp_path):
        j = RequestJournal(tmp_path / "j.jsonl")
        server, _ = _server(tiny_engine, journal=j)
        r = server.submit(np.zeros((4, 4, 3), np.uint8))     # wrong shape
        j.close()
        assert r.outcome == "rejected"
        # rejects never entered the system — nothing to replay
        assert RequestJournal.scan(tmp_path / "j.jsonl").records == []

    def test_replay_resubmits_unresolved(self, tiny_engine, tmp_path):
        img = _images(1)[0]
        j = RequestJournal(tmp_path / "j.jsonl")
        j.submit("bnn", img)                   # journaled, never served
        j.close()
        server, _ = _server(tiny_engine,
                            journal=RequestJournal(tmp_path / "j.jsonl"))
        rs = replay_journal(server, tmp_path / "j.jsonl")
        server.drain()
        server.journal.close()
        assert len(rs) == 1 and rs[0].outcome == "served"
        np.testing.assert_array_equal(np.asarray(rs[0].payload), img)
        # the replayed serve closed the ORIGINAL record (same jid),
        # and did not journal a duplicate submit
        state = RequestJournal.scan(tmp_path / "j.jsonl")
        assert not state.unresolved
        assert sum(1 for r in state.records if r["op"] == "submit") == 1

    def test_replay_skips_other_kind(self, tiny_engine, tmp_path):
        j = RequestJournal(tmp_path / "j.jsonl")
        j.submit("lm", ([1, 2], 4))            # an LM record in a BNN lane
        j.submit("bnn", _images(1)[0])
        j.close()
        server, _ = _server(tiny_engine)
        rs = replay_journal(server, tmp_path / "j.jsonl")
        server.drain()
        assert len(rs) == 1 and rs[0].outcome == "served"


# --------------------------------------------------------------------------
# Per-bucket backend health (§14.3)
# --------------------------------------------------------------------------

class TestBucketHealthUnit:
    def test_demotion_is_bucket_scoped(self):
        h = BucketHealth("xla_pm1", demote_after=2)
        assert h.record_failure(4, now=0.0) is None
        assert h.record_failure(4, now=1.0) == "xla"
        assert h.mode_for(4) == "xla"
        assert h.mode_for(2) == "xla_pm1"      # untouched ladder
        assert h.mode == "xla"                 # aggregate = worst rung
        assert h.demotions == [{"t": 1.0, "from_mode": "xla_pm1",
                                "to_mode": "xla", "bucket": 4}]

    def test_success_on_one_bucket_keeps_others_streaks(self):
        h = BucketHealth("xla_pm1", demote_after=2)
        h.record_failure(4, now=0.0)
        h.record_success(2)                    # different ladder
        assert h.record_failure(4, now=1.0) == "xla"

    def test_probe_and_promote_per_bucket(self):
        h = BucketHealth("xla_pm1", demote_after=1, probe_after_s=10.0)
        h.record_failure(4, now=0.0)
        assert h.probe_due(2, now=100.0) is None   # healthy: no probe
        assert h.probe_due(4, now=5.0) is None     # still quarantined
        assert h.probe_due(4, now=10.0) == "xla_pm1"
        h.promote(4, "xla_pm1")
        assert h.mode_for(4) == "xla_pm1" and h.mode == "xla_pm1"

    def test_snapshot_shape(self):
        h = BucketHealth("xla_pm1", demote_after=1)
        h.record_failure(4, now=0.0)
        h.ladder(2)                    # materialized at first dispatch
        h.record_success(2)
        snap = h.snapshot(now=1.0)
        assert snap["mode"] == "xla" and snap["demotions"] == 1
        assert sorted(snap["buckets"]) == [2, 4]
        assert snap["buckets"][4]["mode"] == "xla"
        assert snap["buckets"][2]["mode"] == "xla_pm1"


class TestPerBucketIsolation:
    """The acceptance scenario: a fault pinned to ONE batch bucket
    demotes only that bucket's ladder; other buckets keep serving the
    fast backend, and the demoted bucket re-probes and promotes on its
    own quarantine clock (§11 ladder semantics, bucket-scoped)."""

    def _stormy(self, tiny_engine, **kw):
        eng = PhoneBitEngine(spec=tiny_engine.spec,
                             packed=tiny_engine.packed,
                             input_hw=tiny_engine.input_hw,
                             matmul_mode="xla_pm1")
        kw.setdefault("retry", RetryPolicy(max_attempts=4,
                                           backoff_base_s=0.001,
                                           jitter=0.0))
        return _server(eng, **kw)

    def test_one_bucket_demotes_others_untouched(self, tiny_engine):
        server, clock = self._stormy(tiny_engine, demote_after=1,
                                     probe_after_s=10.0)
        server.compile_buckets()
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault", times=1,
                      match={"mode": "xla_pm1", "bucket": 2})]))
        try:
            r2 = [server.submit(p) for p in _images(2)]   # → bucket 2
            server.drain()
            assert server.health.mode_for(2) == "xla"     # demoted
            assert server.health.mode == "xla"            # worst rung
            # other buckets still serve the fast backend, no probe
            r1 = server.submit(_images(1)[0])             # → bucket 1
            r4 = [server.submit(p) for p in _images(4)]   # → bucket 4
            server.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in r2 + [r1] + r4)
        assert server.health.mode_for(1) == "xla_pm1"
        assert server.health.mode_for(4) == "xla_pm1"
        demos = server.health.demotions
        assert len(demos) == 1 and demos[0]["bucket"] == 2
        flights = [f for f in server.flight.dump()
                   if f.get("kind") == "demotion"]
        assert flights and flights[0]["bucket"] == 2
        bh = server.metrics()["bucket_health"]
        assert bh[2]["mode"] == "xla" and bh[1]["mode"] == "xla_pm1"

    def test_demoted_bucket_reprobes_and_promotes(self, tiny_engine):
        server, clock = self._stormy(tiny_engine, demote_after=1,
                                     probe_after_s=10.0)
        server.compile_buckets()
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault", times=1,
                      match={"mode": "xla_pm1", "bucket": 2})]))
        try:
            rs = [server.submit(p) for p in _images(2)]
            server.drain()
            assert server.health.mode_for(2) == "xla"
            clock.t += 60.0                    # quarantine expires
            # bucket-1 traffic must NOT probe the 2-bucket's ladder
            r1 = server.submit(_images(1)[0])
            server.drain()
            assert server.health.mode_for(2) == "xla"
            # 2-bucket traffic probes and promotes its own ladder
            rp = [server.submit(p) for p in _images(2)]
            server.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in rs + [r1] + rp)
        assert server.health.mode_for(2) == "xla_pm1"
        promos = [f for f in server.flight.dump()
                  if f.get("kind") == "promotion"]
        assert promos and promos[-1]["bucket"] == 2


# --------------------------------------------------------------------------
# KV checkpoint / restore (§14.2) — LM decode loop
# --------------------------------------------------------------------------

class TestCheckpointRestore:
    @pytest.fixture(scope="class")
    def lm(self):
        from repro.distributed.sharding import rules_for_mesh
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer
        from repro.serving.lm_server import LMServer

        cfg = transformer.LMConfig(name="t", n_layers=1, d_model=32,
                                   n_heads=2, n_kv_heads=2, d_head=16,
                                   d_ff=64, vocab=64, tie_embeddings=True)
        mesh = make_host_mesh(data=1, model=1)
        rules = rules_for_mesh(mesh)
        with mesh:
            params = transformer.init_params(jax.random.key(0), cfg, ep=1)
            yield dict(cfg=cfg, rules=rules, params=params, mesh=mesh,
                       LMServer=LMServer)

    def _mk(self, lm, **kw):
        kw.setdefault("n_slots", 2)
        kw.setdefault("max_seq", 32)
        return lm["LMServer"](cfg=lm["cfg"], rules=lm["rules"],
                              params=lm["params"], **kw)

    @pytest.mark.parametrize("every", [1, 4])
    def test_restore_is_bitexact(self, lm, every):
        """The §14.2 acceptance pin: a decode fault that exhausts the
        retry budget mid-generation restores from the last cut, replays
        the ≤N uncheckpointed tokens, and the final token stream equals
        the unfaulted baseline's bit for bit."""
        with lm["mesh"]:
            base = self._mk(lm)
            rb = base.submit([1, 2, 3], max_new=8)
            base.drain()
            assert rb.outcome == "served"

            s = self._mk(lm, checkpoint_every=every)
            r = s.submit([1, 2, 3], max_new=8)
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=4, after=2)]))
            try:
                s.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "served"
            assert r.result == rb.result           # bit-exact
            rec = s.metrics()["recovery"]
            assert rec["restores"] >= 1
            assert rec["checkpoint_every"] == every
            restored = [f for f in s.flight.dump()
                        if f.get("kind") == "restore"
                        and f.get("outcome") == "restored"]
            assert restored
            assert all(f["replayed"] <= every for f in restored)

    def test_multi_sequence_restore_bitexact(self, lm):
        """Both in-flight sequences survive one restore (slot remap is
        safe: attention reads only the owning slot's pages)."""
        with lm["mesh"]:
            base = self._mk(lm)
            b1 = base.submit([1, 2, 3], max_new=6)
            b2 = base.submit([4, 5], max_new=6)
            base.drain()

            s = self._mk(lm, checkpoint_every=2)
            r1 = s.submit([1, 2, 3], max_new=6)
            r2 = s.submit([4, 5], max_new=6)
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=3, after=1)]))
            try:
                s.drain()
            finally:
                faults.uninstall()
            assert (r1.outcome, r2.outcome) == ("served", "served")
            assert r1.result == b1.result and r2.result == b2.result
            assert s.restores >= 1

    def test_cadence_snapshot_fault_keeps_previous_cut(self, lm):
        with lm["mesh"]:
            s = self._mk(lm, checkpoint_every=1)
            s.submit([1, 2, 3], max_new=6)
            s.serve_tick()                     # admission cut + 1 tick
            good = s.checkpointer.set
            assert good is not None
            faults.install(FaultPlan([
                FaultSpec("kv.snapshot", "device_fault", times=1,
                          match={"reason": "cadence"})]))
            try:
                s.serve_tick()                 # cadence snapshot faults
            finally:
                faults.uninstall()
            # policy: the previous cut survives — replay bound grows
            assert s.checkpointer.set is good
            assert s.checkpointer.failed == 1
            s.drain()

    def test_admission_snapshot_fault_invalidates(self, lm):
        with lm["mesh"]:
            s = self._mk(lm, checkpoint_every=4)
            faults.install(FaultPlan([
                FaultSpec("kv.snapshot", "device_fault", times=1,
                          match={"reason": "admission"})]))
            try:
                r = s.submit([1, 2, 3], max_new=6)
                s.serve_tick()                 # admission snapshot faults
            finally:
                faults.uninstall()
            # policy: the old cut predates the prefill — no cut held
            assert s.checkpointer.set is None
            assert s.checkpointer.failed == 1
            s.drain()
            assert r.outcome == "served"       # serving is unaffected

    def test_restore_fault_burns_attempt_then_succeeds(self, lm):
        with lm["mesh"]:
            s = self._mk(lm, checkpoint_every=2, max_restore_attempts=2)
            r = s.submit([1, 2, 3], max_new=8)
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=3, after=1),
                FaultSpec("kv.restore", "device_fault", times=1)]))
            try:
                s.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "served"
            assert s.restores == 1
            fails = [f for f in s.flight.dump()
                     if f.get("outcome") == "restore_failed"]
            assert len(fails) == 1 and fails[0]["attempt"] == 1

    def test_recovery_disabled_errors_inflight(self, lm):
        """checkpoint_every=None is the pre-§14 contract: the in-flight
        batch resolves ``error`` (taxonomy parity with the BNN server:
        terminal outcome + flight record with the token count)."""
        with lm["mesh"]:
            s = self._mk(lm)
            r = s.submit([1, 2, 3], max_new=8)
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=8, after=1)]))
            try:
                s.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "error" and r.done
            errs = [f for f in s.flight.dump()
                    if f.get("outcome") == "error"]
            assert errs and "n_tokens" in errs[-1]

    def test_restore_attempts_exhausted_errors(self, lm):
        with lm["mesh"]:
            s = self._mk(lm, checkpoint_every=2, max_restore_attempts=1)
            r = s.submit([1, 2, 3], max_new=8)
            # every restore faults too: the single attempt burns, then
            # the in-flight sequence errors (bounded, never loops)
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=32, after=1),
                FaultSpec("kv.restore", "device_fault", times=32)]))
            try:
                s.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "error" and s.restores == 0


# --------------------------------------------------------------------------
# Cross-lane migration (§14.4)
# --------------------------------------------------------------------------

class TestMigration:
    @pytest.fixture(scope="class")
    def lm(self):
        from repro.distributed.sharding import rules_for_mesh
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer

        cfg = transformer.LMConfig(name="t", n_layers=1, d_model=32,
                                   n_heads=2, n_kv_heads=2, d_head=16,
                                   d_ff=64, vocab=64, tie_embeddings=True)
        mesh = make_host_mesh(data=1, model=1)
        rules = rules_for_mesh(mesh)
        with mesh:
            params = transformer.init_params(jax.random.key(0), cfg, ep=1)
            yield dict(cfg=cfg, rules=rules, params=params, mesh=mesh)

    def test_quarantined_lane_evacuates_to_healthy_lane(self, lm):
        from repro.distributed.replicas import LMReplicaGroup

        with lm["mesh"]:
            grp = LMReplicaGroup(lm["cfg"], lm["rules"], lm["params"],
                                 n_slots=2, max_seq=32, n_lanes=2,
                                 checkpoint_every=2,
                                 max_restore_attempts=1,
                                 probe_after_s=30.0)
            r = grp.submit([1, 2, 3], max_new=8, lane="lm0")
            # lm0's decode faults forever: in-lane restore replays into
            # the same fault, so the restore budget exhausts and the
            # sequence must migrate to lm1
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=1000,
                          match={"tenant": "lm0"})]))
            try:
                grp.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "served"
            assert len(r.result) == 8
            assert grp.migrations == 1
            lm0 = grp.lanes["lm0"]
            assert lm0.quarantines == 1
            assert lm0.quarantined(grp.clock())
            adopted = [f for f in grp.lanes["lm1"].server.flight.dump()
                       if f.get("kind") == "migration"]
            assert adopted and adopted[0]["src"] == "lm0"
            m = grp.metrics()
            assert m["migrations"] == 1
            assert m["routing"]["lm0"]["quarantined"] is True

    def test_migration_preserves_emitted_prefix(self, lm):
        """§14.4: migration is prefix-preserving — tokens the origin
        lane already emitted reach the caller verbatim; only future
        tokens come from the adopting lane."""
        from repro.distributed.replicas import LMReplicaGroup

        with lm["mesh"]:
            grp = LMReplicaGroup(lm["cfg"], lm["rules"], lm["params"],
                                 n_slots=2, max_seq=32, n_lanes=2,
                                 checkpoint_every=1,
                                 max_restore_attempts=1)
            r = grp.submit([1, 2, 3], max_new=8, lane="lm0")
            s0 = grp.lanes["lm0"].server
            # run clean ticks on lm0 so a known prefix exists
            for _ in range(3):
                grp.serve_tick()
            prefix = list(next(iter(s0.manager.active.values())).tokens)
            assert prefix
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=1000,
                          match={"tenant": "lm0"})]))
            try:
                grp.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "served"
            assert r.result[:len(prefix)] == prefix

    def test_routing_steers_around_quarantined_lane(self, lm):
        from repro.distributed.replicas import LMReplicaGroup

        with lm["mesh"]:
            grp = LMReplicaGroup(lm["cfg"], lm["rules"], lm["params"],
                                 n_slots=2, max_seq=32, n_lanes=2,
                                 checkpoint_every=2,
                                 max_restore_attempts=1)
            r = grp.submit([1, 2, 3], max_new=4, lane="lm0")
            faults.install(FaultPlan([
                FaultSpec("lm.step", "device_fault", times=1000,
                          match={"tenant": "lm0"})]))
            try:
                grp.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "served" and grp.migrations == 1
            # unpinned submits now route to the healthy lane only
            r2 = grp.submit([4, 5], max_new=4)
            assert grp.lanes["lm1"].server.queue_depth == 1
            assert grp.lanes["lm0"].server.queue_depth == 0
            grp.drain()
            assert r2.outcome == "served"


# --------------------------------------------------------------------------
# kill -9 → artifact + journal restart, end to end in fresh processes
# --------------------------------------------------------------------------

KILL_SPEC = """
SPEC = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
        Pool(2, 2), FloatDense(8 * 8 * 16, 10)]
params = bnn_model.init_params(jax.random.key(0), SPEC)
eng = PhoneBitEngine.from_trained(params, SPEC, (16, 16))
"""


def test_kill9_journal_replay_recovers_all(tmp_path):
    """The §14.3 pin: a serving process is SIGKILLed mid-stream; a
    fresh process boots from the same AOT artifact + journal, replays
    every journaled-but-unresolved request, resolves all of them, and
    never traces (zero serve-time retraces)."""
    from repro.serving import export_artifact

    spec = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
            Pool(2, 2), FloatDense(8 * 8 * 16, 10)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    eng = PhoneBitEngine.from_trained(params, spec, (16, 16))
    export_artifact(eng, tmp_path / "art", buckets=(1, 2))

    prelude = textwrap.dedent("""
        import os, sys
        os.environ["REPRO_AUTOTUNE_CACHE"] = "0"
        sys.path.insert(0, {src!r})
        import jax, numpy as np
        from repro.core import bnn_model
        from repro.core.bnn_model import BConv, FloatDense, Pool
        from repro.serving import InferenceServer, PhoneBitEngine
        from repro.serving.recovery import RequestJournal, replay_journal
    """).format(src=str(REPO / "src")) + textwrap.dedent(KILL_SPEC)

    kill = prelude + textwrap.dedent("""
        import signal
        server = InferenceServer(
            eng, artifact={art!r}, buckets=(1, 2), max_batch=2,
            max_wait_s=0.0, journal=RequestJournal({jpath!r}))
        rng = np.random.default_rng(3)
        for _ in range(8):
            server.submit(rng.integers(0, 256, (16, 16, 3),
                                       dtype=np.uint8))
        for _ in range(3):             # resolve a prefix, not the tail
            server.step(force=True)
        os.kill(os.getpid(), signal.SIGKILL)
    """).format(art=str(tmp_path / "art"), jpath=str(tmp_path / "j.jsonl"))
    p1 = subprocess.run([sys.executable, "-c", kill], capture_output=True,
                        text=True, timeout=420, env=dict(os.environ))
    assert p1.returncode == -9, \
        f"STDOUT:\n{p1.stdout}\nSTDERR:\n{p1.stderr}"

    pre = RequestJournal.scan(tmp_path / "j.jsonl")
    assert pre.unresolved, "kill phase resolved everything — nothing to prove"

    recover = prelude + textwrap.dedent("""
        import json
        jpath = {jpath!r}
        pre = RequestJournal.scan(jpath)
        server = InferenceServer(
            eng, artifact={art!r}, buckets=(1, 2), max_batch=2,
            max_wait_s=0.0, journal=RequestJournal(jpath))
        rs = replay_journal(server, jpath)
        server.drain()
        post = RequestJournal.scan(jpath)
        print(json.dumps({{
            "journaled_unresolved": len(pre.unresolved),
            "replayed": len(rs),
            "recovered": sum(1 for r in rs if r.outcome == "served"),
            "unresolved_after": len(post.unresolved),
            "trace_count": eng.trace_count,
        }}))
    """).format(art=str(tmp_path / "art"), jpath=str(tmp_path / "j.jsonl"))
    p2 = subprocess.run([sys.executable, "-c", recover],
                        capture_output=True, text=True, timeout=420,
                        env=dict(os.environ))
    assert p2.returncode == 0, \
        f"STDOUT:\n{p2.stdout}\nSTDERR:\n{p2.stderr}"
    rec = json.loads(p2.stdout.strip().splitlines()[-1])
    assert rec["journaled_unresolved"] == len(pre.unresolved) > 0
    assert rec["replayed"] == rec["journaled_unresolved"]
    assert rec["recovered"] == rec["journaled_unresolved"]
    assert rec["unresolved_after"] == 0
    assert rec["trace_count"] == 0         # artifact boot, zero retraces
