"""Shared model substrate: attention oracles, RoPE, MoE, CE — unit +
property tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import layers, moe as moe_lib, transformer


@pytest.fixture(scope="module")
def rules():
    mesh = make_host_mesh(data=1, model=1)
    with mesh:
        yield rules_for_mesh(mesh)


# --------------------------------------------------------------------------
# Chunked (flash) attention vs naive oracle
# --------------------------------------------------------------------------

class TestChunkedAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (6, 2)])
    def test_matches_reference(self, causal, h, kvh):
        b, s, hd = 2, 64, 16
        key = jax.random.key(h * 10 + kvh + causal)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kvh, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kvh, hd), jnp.float32)
        out = layers.chunked_attention(q, k, v, causal=causal,
                                       q_chunk=16, kv_chunk=16)
        ref = layers.reference_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("q_chunk,kv_chunk", [(64, 64), (32, 16),
                                                  (8, 64), (64, 8)])
    def test_chunking_invariance(self, q_chunk, kv_chunk):
        """Output is independent of the chunking schedule."""
        b, s, h, hd = 1, 64, 2, 8
        key = jax.random.key(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        a = layers.chunked_attention(q, k, v, causal=True,
                                     q_chunk=q_chunk, kv_chunk=kv_chunk)
        b_ = layers.reference_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)

    def test_gradients_flow(self):
        b, s, h, hd = 1, 32, 2, 8
        key = jax.random.key(1)
        q = jax.random.normal(key, (b, s, h, hd))

        def f(q):
            return jnp.sum(layers.chunked_attention(
                q, q, q, causal=True, q_chunk=8, kv_chunk=8))

        g = jax.grad(f)(q)
        assert np.isfinite(np.asarray(g)).all()
        assert float(jnp.abs(g).max()) > 0

    def test_flash_decode_partials(self):
        """Manual partial-combine == full softmax attention (1 query)."""
        b, s, kvh, hd, h = 2, 32, 2, 8, 4
        key = jax.random.key(2)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, h, hd))
        kc = jax.random.normal(ks[1], (b, s, kvh, hd))
        vc = jax.random.normal(ks[2], (b, s, kvh, hd))
        # two "shards" of the cache
        o1, m1, l1 = layers.flash_decode_local(q, kc[:, :16], vc[:, :16],
                                               jnp.int32(s), jnp.int32(0))
        o2, m2, l2 = layers.flash_decode_local(q, kc[:, 16:], vc[:, 16:],
                                               jnp.int32(s), jnp.int32(16))
        m = jnp.maximum(m1, m2)
        l = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
        o = (o1 * jnp.exp(m1 - m)[..., None]
             + o2 * jnp.exp(m2 - m)[..., None]) / l[..., None]
        ref = layers.reference_attention(
            q[:, None], kc, vc, causal=False)[:, 0]
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

class TestRoPE:
    def test_preserves_norm(self):
        x = jax.random.normal(jax.random.key(0), (2, 16, 4, 32))
        pos = jnp.arange(16)[None, :]
        y = layers.apply_rope(x, pos)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        hd = 32
        q = jax.random.normal(jax.random.key(1), (hd,))
        k = jax.random.normal(jax.random.key(2), (hd,))

        def dot_at(i, j):
            qr = layers.apply_rope(q[None, None, None, :],
                                   jnp.array([[i]]))[0, 0, 0]
            kr = layers.apply_rope(k[None, None, None, :],
                                   jnp.array([[j]]))[0, 0, 0]
            return float(qr @ kr)

        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
        assert abs(dot_at(0, 0) - dot_at(100, 100)) < 1e-4

    def test_position_zero_identity(self):
        x = jax.random.normal(jax.random.key(3), (1, 1, 2, 16))
        y = layers.apply_rope(x, jnp.zeros((1, 1), jnp.int32))
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# MoE: EP layer vs dense oracle
# --------------------------------------------------------------------------

class TestMoE:
    def test_matches_reference_high_capacity(self, rules):
        t, d, e, k, fe = 64, 16, 8, 2, 32
        key = jax.random.key(0)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (t, d), jnp.float32)
        router = jax.random.normal(ks[1], (d, e)) * 0.1
        wg = jax.random.normal(ks[2], (e, d, fe)) / np.sqrt(d)
        wu = jax.random.normal(ks[3], (e, d, fe)) / np.sqrt(d)
        wd = jax.random.normal(ks[4], (e, fe, d)) / np.sqrt(fe)
        out, aux = moe_lib.moe_apply(
            x, router, wg, wu, wd, n_experts=e, top_k=k,
            capacity_factor=float(e), rules=rules, token_axes=())
        ref = moe_lib.moe_reference(x, router, wg, wu, wd, n_experts=e,
                                    top_k=k)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
        assert float(aux) > 0

    def test_padded_experts_never_selected(self, rules):
        """n_real < E_pad: padding experts get zero routed tokens."""
        t, d, e_real, e_pad, k, fe = 32, 8, 5, 8, 2, 16
        key = jax.random.key(1)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (t, d))
        router = jax.random.normal(ks[1], (d, e_pad))
        w, ids, probs = moe_lib._route(x, router, n_real=e_real, top_k=k)
        assert int(jnp.max(ids)) < e_real
        assert float(jnp.sum(probs[:, e_real:])) < 1e-6

    def test_capacity_drops_overflow(self):
        ids = jnp.zeros((10, 1), jnp.int32)  # all tokens -> expert 0
        dest, keep = moe_lib._dispatch_indices(ids, n_experts=4, cap=3)
        assert int(keep.sum()) == 3          # capacity enforced
        assert sorted(np.asarray(dest[keep]).tolist()) == [0, 1, 2]

    @given(st.integers(0, 10_000), st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_dispatch_positions_unique(self, seed, k):
        """No two kept assignments land in the same bucket slot."""
        rng = np.random.default_rng(seed)
        e, cap, t = 6, 4, 16
        ids = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
        dest, keep = moe_lib._dispatch_indices(ids, n_experts=e, cap=cap)
        kept = np.asarray(dest)[np.asarray(keep)]
        assert len(set(kept.tolist())) == len(kept)
        assert (kept < e * cap).all()


# --------------------------------------------------------------------------
# Chunked CE == unchunked CE
# --------------------------------------------------------------------------

def test_chunked_ce_matches_dense(rules):
    b, s, d, v = 2, 32, 16, 64
    key = jax.random.key(0)
    x = jax.random.normal(jax.random.key(1), (b, s, d), jnp.float32)
    head = jax.random.normal(jax.random.key(2), (d, v), jnp.float32)
    labels = jax.random.randint(jax.random.key(3), (b, s), 0, v)
    dense = transformer.cross_entropy((x @ head), labels)
    chunked = transformer.chunked_ce(x, head, labels, rules, v)
    np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)
