"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import layer_integration, packing
from repro.kernels import ref
from repro.kernels.bitplane_pack import bitplane_pack
from repro.kernels.fused_conv_bn_binarize import fused_matmul_bn_binarize
from repro.kernels.mxu_pm1_matmul import mxu_pm1_matmul
from repro.kernels.xnor_popcount_matmul import xnor_popcount_matmul


def _packed(rng, rows, k):
    signs = rng.choice([-1.0, 1.0], size=(rows, k)).astype(np.float32)
    return packing.pack_signs(signs), signs


class TestXnorPopcountMatmul:
    @pytest.mark.parametrize("m,n,k,bm,bn,bk", [
        (8, 8, 64, 8, 8, 2),        # exact tiling
        (10, 7, 65, 8, 8, 2),       # padding on every dim
        (33, 40, 96, 16, 32, 1),    # multi-tile
        (1, 1, 1, 8, 8, 8),         # degenerate
        (4, 129, 2048, 4, 128, 32), # lane-width n
    ])
    def test_vs_oracle(self, m, n, k, bm, bn, bk):
        rng = np.random.default_rng(m * 7 + n * 3 + k)
        a, _ = _packed(rng, m, k)
        b, _ = _packed(rng, n, k)
        got = xnor_popcount_matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.xnor_popcount_matmul(a, b)))

    def test_word_weights(self):
        rng = np.random.default_rng(0)
        a, _ = _packed(rng, 6, 8 * 32)
        b, _ = _packed(rng, 5, 8 * 32)
        ww = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128], jnp.int32)
        got = xnor_popcount_matmul(a, b, ww, block_m=4, block_n=4, block_k=4,
                                   interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.xnor_popcount_matmul(a, b, ww)))

    @given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 300),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, m, n, k, seed):
        rng = np.random.default_rng(seed)
        a, _ = _packed(rng, m, k)
        b, _ = _packed(rng, n, k)
        got = xnor_popcount_matmul(a, b, block_m=16, block_n=16, block_k=4,
                                   interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.xnor_popcount_matmul(a, b)))


class TestFusedMatmulBnBinarize:
    @pytest.mark.parametrize("m,n,k,bm,bn,bk", [
        (16, 64, 64, 8, 32, 1),
        (9, 40, 100, 8, 32, 2),     # n not mult of 32, k padding
        (32, 33, 288, 16, 32, 4),
        (3, 256, 64, 4, 64, 2),
    ])
    def test_vs_oracle(self, m, n, k, bm, bn, bk):
        rng = np.random.default_rng(n * 31 + k)
        a, _ = _packed(rng, m, k)
        b, _ = _packed(rng, n, k)
        kv = k
        t = jnp.asarray(rng.integers(-5, kv + 5, n), jnp.int32)
        s = jnp.asarray(rng.integers(0, 2, n).astype(bool))
        got = fused_matmul_bn_binarize(a, b, t, s, block_m=bm, block_n=bn,
                                       block_k=bk, interpret=True)
        exp = ref.fused_matmul_bn_binarize(a, b, t, s)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))

    def test_with_plane_weights(self):
        rng = np.random.default_rng(4)
        a, _ = _packed(rng, 10, 16 * 32)
        b, _ = _packed(rng, 40, 16 * 32)
        ww = jnp.asarray(rng.integers(1, 129, 16), jnp.int32)
        t = jnp.asarray(rng.integers(0, 3000, 40), jnp.int32)
        s = jnp.asarray(rng.integers(0, 2, 40).astype(bool))
        got = fused_matmul_bn_binarize(a, b, t, s, ww, block_m=8, block_n=32,
                                       block_k=4, interpret=True)
        exp = ref.fused_matmul_bn_binarize(a, b, t, s, ww)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


class TestBitplanePack:
    @pytest.mark.parametrize("shape,bh", [
        ((2, 8, 8, 3), 4),
        ((1, 7, 5, 3), 4),          # h padding
        ((2, 4, 4, 33), 2),         # multi-word channels
        ((1, 1, 1, 1), 1),
    ])
    def test_vs_oracle(self, shape, bh):
        rng = np.random.default_rng(shape[1] * 13)
        x = jnp.asarray(rng.integers(0, 256, size=shape), jnp.uint8)
        got = bitplane_pack(x, block_h=bh, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.bitplane_pack(x)))


class TestMxuPm1Matmul:
    @pytest.mark.parametrize("m,n,k,bm,bn,bk", [
        (8, 8, 64, 8, 8, 1),
        (10, 9, 100, 8, 8, 2),      # channel-pad bits + block padding
        (16, 40, 513, 8, 16, 4),
    ])
    def test_vs_oracle(self, m, n, k, bm, bn, bk):
        rng = np.random.default_rng(k * 3 + m)
        a, av = _packed(rng, m, k)
        b, bv = _packed(rng, n, k)
        got = mxu_pm1_matmul(a, b, k_valid=k, block_m=bm, block_n=bn,
                             block_k=bk, interpret=True)
        exp = (av @ bv.T).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(got), exp)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.mxu_pm1_matmul(a, b, k_valid=k)))


class TestOpsDispatch:
    def test_modes_agree(self):
        from repro.kernels import ops
        rng = np.random.default_rng(9)
        a, _ = _packed(rng, 12, 130)
        b, _ = _packed(rng, 7, 130)
        outs = [np.asarray(ops.binary_matmul_dot(a, b, 130, mode=m))
                for m in ("vpu_popcount", "mxu_pm1", "xla")]
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])

    def test_fused_conv_matches_core(self):
        from repro.kernels import ops
        from repro.core import binary_conv
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.integers(-2**31, 2**31, size=(2, 6, 6, 2)),
                        jnp.int32)
        w = rng.choice([-1.0, 1.0], size=(3, 3, 64, 8)).astype(np.float32)
        wp = binary_conv.pack_conv_weights(jnp.asarray(w))
        t = jnp.asarray(rng.integers(0, 9 * 64, 8), jnp.int32)
        s = jnp.asarray(rng.integers(0, 2, 8).astype(bool))
        p = layer_integration.IntegratedParams(t, s)
        got = ops.fused_binary_conv2d(x, wp, p, 3, 3, 1, 1)
        exp = binary_conv.binary_conv2d_fused(x, wp, p, 3, 3, 1, 1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
