"""End-to-end system tests: fault tolerance, serving, drivers.

Covers the large-scale-runnability story on a single host:
checkpoint/restart with fault injection, elastic restore, straggler
detection, batch scheduling, KV-slot management, and the PhoneBit engine
serving path.
"""

import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.distributed.straggler import StragglerMonitor
from repro.serving import BatchScheduler, KVCacheManager, PhoneBitEngine

REPO = pathlib.Path(__file__).resolve().parent.parent


# --------------------------------------------------------------------------
# Checkpointing
# --------------------------------------------------------------------------

class TestCheckpoint:
    def _tree(self, scale=1.0):
        return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3) * scale,
                "nested": {"b": jnp.ones((4,), jnp.int32)}}

    def test_save_restore_roundtrip(self, tmp_path):
        tree = self._tree()
        save(tmp_path, 7, tree)
        assert latest_step(tmp_path) == 7
        out = restore(tmp_path, 7, jax.eval_shape(lambda: tree))
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                      np.asarray(tree["nested"]["b"]))

    def test_atomic_no_partial(self, tmp_path):
        # a leftover tmp file from a "crashed" writer is ignored
        (tmp_path / "tmp.3.999.npz").write_bytes(b"garbage")
        assert latest_step(tmp_path) is None
        save(tmp_path, 3, self._tree())
        assert latest_step(tmp_path) == 3

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for step in (1, 2, 3, 4):
            mgr.save(step, self._tree(step))
        steps = sorted(int(f.name.split("_")[1].split(".")[0])
                       for f in tmp_path.glob("step_*.npz"))
        assert steps == [3, 4]

    def test_async_writer(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_async(5, self._tree())
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        save(tmp_path, 1, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError, match="shape"):
            restore(tmp_path, 1, {"a": jax.ShapeDtypeStruct((3, 3),
                                                            jnp.float32)})


# --------------------------------------------------------------------------
# Straggler monitor
# --------------------------------------------------------------------------

class TestStraggler:
    def test_detects_outlier(self):
        warns = []
        mon = StragglerMonitor(on_warn=lambda s, dt, mu: warns.append(s),
                               min_samples=5)
        for i in range(20):
            mon.observe(i, 0.1 + 0.001 * (i % 3))
        assert not warns
        mon.observe(20, 1.5)        # 15x mean
        assert warns == [20]

    def test_persistent_triggers_mitigation(self):
        hits = []
        mon = StragglerMonitor(on_persistent=hits.append,
                               persistent_after=3, min_samples=5)
        for i in range(10):
            mon.observe(i, 0.1)
        for i in range(10, 13):     # degrading host
            mon.observe(i, 2.0)
        assert hits == [12]

    def test_outliers_do_not_poison_baseline(self):
        mon = StragglerMonitor(min_samples=5)
        for i in range(10):
            mon.observe(i, 0.1)
        base = mon.mean_step_time
        mon.observe(10, 5.0)
        assert abs(mon.mean_step_time - base) < 1e-9


# --------------------------------------------------------------------------
# Batch scheduler
# --------------------------------------------------------------------------

class TestScheduler:
    def test_batches_up_to_max(self):
        s = BatchScheduler(max_batch=4, max_wait_s=10.0)
        for i in range(6):
            s.submit(i)
        batch = s.next_batch()
        assert [r.payload for r in batch] == [0, 1, 2, 3]
        assert len(s) == 2

    def test_waits_for_more(self):
        s = BatchScheduler(max_batch=4, max_wait_s=10.0)
        s.submit(0)
        assert s.next_batch(now=s._queue[0].arrival_s + 0.1) is None
        assert s.next_batch(now=s._queue[0].arrival_s + 11) is not None

    def test_drain_pads_to_bucket(self):
        s = BatchScheduler(max_batch=8, max_wait_s=0.0, buckets=(1, 4, 8))
        for i in range(3):
            s.submit(i)
        seen = {}

        def run(payloads):
            seen["n"] = len(payloads)
            return [p * 10 for p in payloads]

        done = s.drain(run)
        assert seen["n"] == 4                    # padded 3 -> bucket 4
        assert [r.result for r in done] == [0, 10, 20]
        assert all(r.done for r in done)


# --------------------------------------------------------------------------
# KV-cache manager
# --------------------------------------------------------------------------

class TestKVCacheManager:
    def test_slot_lifecycle(self):
        mgr = KVCacheManager(n_slots=2, max_seq=64)
        s1 = mgr.admit(8, 4)
        s2 = mgr.admit(8, 4)
        assert not mgr.can_admit()
        assert mgr.utilization == 1.0
        done = False
        for t in range(4):
            done = mgr.record_token(s1.seq_id, t)
        assert done and mgr.can_admit()
        s3 = mgr.admit(4, 4)
        assert s3.slot == s1.slot    # slot recycled

    def test_eos_finishes(self):
        mgr = KVCacheManager(n_slots=1, max_seq=64)
        s = mgr.admit(4, 40)
        assert not mgr.record_token(s.seq_id, 7, eos_id=9)
        assert mgr.record_token(s.seq_id, 9, eos_id=9)
        assert s.tokens == [7, 9]

    def test_overlong_rejected(self):
        mgr = KVCacheManager(n_slots=1, max_seq=16)
        with pytest.raises(AssertionError):
            mgr.admit(10, 10)


# --------------------------------------------------------------------------
# Fault injection: checkpoint -> crash -> resume (full driver)
# --------------------------------------------------------------------------

def test_train_crash_resume(tmp_path):
    """Train 10 steps dying at step 6; the restart restores step 5's
    checkpoint and resumes from step 6 (deterministic pipeline)."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "minitron-8b", "--smoke",
            "--steps", "10", "--batch", "2", "--seq-len", "32",
            "--checkpoint-dir", str(tmp_path / "ckpt"),
            "--checkpoint-every", "3", "--log-every", "1"]

    r1 = subprocess.run(args + ["--fail-at", "6"], env=env,
                        capture_output=True, text=True, timeout=420)
    assert r1.returncode == 17, (r1.stdout[-1000:], r1.stderr[-1000:])
    assert "fault injection" in r1.stdout

    r2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        timeout=420)
    assert r2.returncode == 0, (r2.stdout[-1000:], r2.stderr[-1000:])
    assert "restored checkpoint at step 5" in r2.stdout
    assert "resuming from 6" in r2.stdout.replace("\n", " ")


# --------------------------------------------------------------------------
# PhoneBit engine end-to-end
# --------------------------------------------------------------------------

def test_engine_matches_float_oracle_small():
    """Random tiny BNN: packed engine == float sign oracle."""
    from repro.core import bnn_model
    from repro.core.bnn_model import BConv, BDense, FloatDense, Pool

    spec = [
        BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
        Pool(2, 2),
        BConv(32, 64, kernel=3, stride=1, pad=1),
        Pool(2, 2),
        BDense(4 * 4 * 64, 128),
        FloatDense(128, 10),
    ]
    key = jax.random.key(0)
    params = bnn_model.init_params(key, spec)
    params = [dict(p, mu=jax.random.normal(jax.random.key(i),
                                           p["mu"].shape) * 0.2)
              if "mu" in p else p for i, p in enumerate(params)]
    engine = PhoneBitEngine.from_trained(params, spec, (16, 16))
    x = jax.random.randint(jax.random.key(1), (2, 16, 16, 3), 0,
                           256).astype(jnp.uint8)
    packed_out = engine(x)
    float_out = bnn_model.float_forward(params, spec, x)
    np.testing.assert_allclose(np.asarray(packed_out),
                               np.asarray(float_out), rtol=1e-4, atol=1e-4)


def test_engine_artifact_roundtrip(tmp_path):
    from repro.core import bnn_model
    from repro.core.bnn_model import BConv, FloatDense, Pool

    spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
            Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    e1 = PhoneBitEngine.from_trained(params, spec, (16, 16))
    path = str(tmp_path / "model.npz")
    e1.save_artifact(path)
    e2 = PhoneBitEngine.from_artifact(path, spec, (16, 16))
    x = jax.random.randint(jax.random.key(1), (1, 16, 16, 3), 0,
                           256).astype(jnp.uint8)
    np.testing.assert_array_equal(np.asarray(e1(x)), np.asarray(e2(x)))
    assert e1.model_bytes == e2.model_bytes


def test_yolo_final_float_conv():
    """YOLOv2-Tiny-style FloatConv head + darknet stride-1 pool:
    packed engine == float oracle."""
    from repro.core import bnn_model
    from repro.core.bnn_model import BConv, FloatConv, Pool

    spec = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
            Pool(2, 1, pad=(0, 1)),
            BConv(16, 32, kernel=3, stride=1, pad=1),
            FloatConv(32, 12, kernel=1)]
    params = bnn_model.init_params(jax.random.key(2), spec)
    engine = PhoneBitEngine.from_trained(params, spec, (8, 8))
    x = jax.random.randint(jax.random.key(3), (2, 8, 8, 3), 0,
                           256).astype(jnp.uint8)
    out = engine(x)
    ref = bnn_model.float_forward(params, spec, x)
    assert out.shape == (2, 8, 8, 12)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_paper_network_specs_consistent():
    """The three paper networks build, convert, and report Tab-II-scale
    model sizes (float ~15-20x larger than packed)."""
    from repro.core import bnn_model, converter
    from repro.models import paper_nets

    for name in ("alexnet", "vgg16", "yolov2-tiny"):
        spec, (h, w, c) = paper_nets.get(name)
        params = bnn_model.init_params(jax.random.key(0), spec)
        packed = converter.convert(params, spec, (h, w))
        fb = converter.float_model_bytes(params)
        bb = converter.model_bytes(packed)
        ratio = fb / bb
        assert 5 < ratio < 40, (name, ratio)
