"""Minimal stand-in for ``hypothesis`` when it is not installed.

The test suite uses a narrow slice of the API — ``@given`` over
``st.integers`` / ``st.floats`` / ``st.sampled_from`` with
``@settings(max_examples=..., deadline=...)``.  This stub replays the same
contract with a deterministic PRNG: each ``@given`` test runs
``max_examples`` times on pseudo-random draws seeded by the test name, so
failures reproduce run-to-run.  It is installed by ``tests/conftest.py``
only when the real package is missing; with hypothesis available the stub
is never imported.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return SearchStrategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return SearchStrategy(lambda rng: rng.choice(elements))

    @staticmethod
    def booleans():
        return SearchStrategy(lambda rng: rng.random() < 0.5)


_DEFAULT_MAX_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(fn, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = [s.example_from(rng) for s in strats]
                drawn_kw = {k: s.example_from(rng)
                            for k, s in kw_strats.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except Exception as e:
                    raise AssertionError(
                        f"stub-hypothesis example {i + 1}/{n} failed with "
                        f"args={drawn} kwargs={drawn_kw}") from e

        # Hide the drawn parameters from pytest's fixture resolution: the
        # wrapper's visible signature is the original minus the trailing
        # positional params filled by `strats` and the kw-strategy names.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if strats:
            params = params[:-len(strats)]
        params = [p for p in params if p.name not in kw_strats]
        del wrapper.__wrapped__  # keep inspect from seeing fn's signature
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco


st = strategies
