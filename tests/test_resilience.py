"""Resilience-layer tests (DESIGN.md §11).

Fault-injection determinism, the retry/backoff math, payload validation
and bounded admission, the fault matrix (site × kind × retry policy)
under a fake clock — every request must terminally resolve with an
outcome in {served, shed, error, rejected} and non-faulted results must
stay bit-exact — plus backend degradation/quarantine/re-probe, the
dispatch watchdog, the bounded drain guard, LM-server protocol parity,
and a tiny in-process endurance smoke.
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.serving import (InferenceServer, PhoneBitEngine, faults)
from repro.serving.faults import (DEGRADE_LADDER, BackendHealth,
                                  CompileFault, DeviceFault, FaultError,
                                  FaultPlan, FaultSpec, RetryPolicy,
                                  WatchdogTimeout, demote_mode)
from repro.serving.scheduler import OUTCOMES


@pytest.fixture(scope="module")
def tiny_engine():
    spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
            Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    return PhoneBitEngine.from_trained(params, spec, (16, 16))


def _images(n, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(n)]


class FakeClock:
    """Monotonic fake clock; ``sleep`` advances it (what the server's
    injectable ``sleep`` hooks into so drain can wait out backoff)."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def sleep(self, s: float) -> None:
        self.t += max(s, 0.0)


def _server(engine, clock=None, **kw):
    clock = clock or FakeClock()
    kw.setdefault("buckets", (1, 2, 4))
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_s", 0.0)
    return InferenceServer(engine, clock=clock, sleep=clock.sleep, **kw), \
        clock


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    yield
    faults.uninstall()


# --------------------------------------------------------------------------
# Fault plan determinism
# --------------------------------------------------------------------------

class TestFaultPlan:
    def test_unknown_site_and_kind_rejected(self):
        with pytest.raises(ValueError, match="site"):
            FaultSpec("nope.where", "device_oom")
        with pytest.raises(ValueError, match="kind"):
            FaultSpec("server.device", "gremlins")

    def test_schedule_after_every_times(self):
        plan = FaultPlan([FaultSpec("server.device", "device_fault",
                                    after=2, every=2, times=2)])
        fired = []
        for i in range(10):
            try:
                plan.check("server.device")
                fired.append(False)
            except DeviceFault:
                fired.append(True)
        # skip 2, then every 2nd eligible call, capped at 2 fires
        assert fired == [False, False, True, False, True,
                         False, False, False, False, False]

    def test_rate_is_seed_deterministic(self):
        def run(seed):
            plan = FaultPlan([FaultSpec("server.device", "device_fault",
                                        rate=0.5)], seed=seed)
            out = []
            for _ in range(32):
                try:
                    plan.check("server.device")
                    out.append(0)
                except DeviceFault:
                    out.append(1)
            return out

        assert run(7) == run(7)
        assert run(7) != run(8)
        assert 0 < sum(run(7)) < 32

    def test_match_filters_ctx(self):
        plan = FaultPlan([FaultSpec("server.dispatch", "device_oom",
                                    match={"mode": "vpu_chain"})])
        plan.check("server.dispatch", mode="xla")        # no fire
        with pytest.raises(faults.DeviceOOM):
            plan.check("server.dispatch", mode="vpu_chain")

    def test_latency_spike_sleeps_not_raises(self):
        slept = []
        plan = FaultPlan([FaultSpec("server.device", "latency_spike",
                                    duration_s=0.25)], sleep=slept.append)
        plan.check("server.device")
        assert slept == [0.25]
        assert plan.log[0]["kind"] == "latency_spike"

    def test_injection_logged_and_counted(self):
        from repro.obs import metrics as obs_metrics

        with obs_metrics.use_registry() as reg:
            with faults.inject([FaultSpec("server.device",
                                          "device_fault")]) as plan:
                with pytest.raises(DeviceFault):
                    faults.maybe_fault("server.device", bucket=4)
            assert plan.fired("server.device")[0]["bucket"] == 4
            assert reg.snapshot()["faults.injected"] == 1
            assert reg.events("fault")[0]["site"] == "server.device"
        assert faults.get_plan() is None                 # uninstalled

    def test_disabled_is_one_global_read(self):
        assert faults._PLAN is None
        faults.maybe_fault("server.device")              # no-op, no raise


# --------------------------------------------------------------------------
# Retry policy math
# --------------------------------------------------------------------------

class TestRetryPolicy:
    def test_exponential_capped(self):
        p = RetryPolicy(max_attempts=5, backoff_base_s=0.1,
                        backoff_cap_s=0.35, jitter=0.0)
        assert [p.backoff_s(k) for k in (1, 2, 3, 4)] == \
            [0.1, 0.2, 0.35, 0.35]

    def test_jitter_bounded_and_seeded(self):
        p = RetryPolicy(backoff_base_s=0.1, jitter=0.5, seed=3)
        vals = [p.backoff_s(1) for _ in range(64)]
        assert all(0.05 <= v <= 0.15 for v in vals)
        p2 = RetryPolicy(backoff_base_s=0.1, jitter=0.5, seed=3)
        assert vals[0] == p2.backoff_s(1)

    def test_validation(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_attempts=0)
        with pytest.raises(ValueError):
            RetryPolicy(jitter=1.0)


# --------------------------------------------------------------------------
# Degradation ladder / backend health
# --------------------------------------------------------------------------

class TestBackendHealth:
    def test_ladder_demotes_to_floor(self):
        mode = DEGRADE_LADDER[0]
        seen = [mode]
        while (mode := demote_mode(mode)) is not None:
            seen.append(mode)
        assert tuple(seen) == DEGRADE_LADDER
        assert demote_mode("auto") == "xla"              # off-ladder

    def test_demote_after_consecutive_failures(self):
        h = BackendHealth("vpu_direct", demote_after=2)
        assert h.record_failure(now=0.0) is None
        h.record_success()                               # resets streak
        assert h.record_failure(now=1.0) is None
        assert h.record_failure(now=2.0) == "vpu_popcount"
        assert h.mode == "vpu_popcount"
        assert h.demotions == [{"t": 2.0, "from_mode": "vpu_direct",
                                "to_mode": "vpu_popcount"}]

    def test_quarantine_probe_and_promote(self):
        h = BackendHealth("vpu_direct", demote_after=1, probe_after_s=10.0)
        h.record_failure(now=0.0)
        assert h.mode == "vpu_popcount"
        assert h.probe_due(now=5.0) is None              # still quarantined
        assert h.probe_due(now=10.0) == "vpu_direct"
        h.promote("vpu_direct")
        assert h.mode == "vpu_direct"
        assert h.probe_due(now=100.0) is None            # cleared

    def test_failed_probe_doubles_interval(self):
        h = BackendHealth("vpu_direct", demote_after=1, probe_after_s=10.0,
                          probe_backoff=2.0)
        h.record_failure(now=0.0)
        h.probe_failed("vpu_direct", now=10.0)           # re-quarantine 20s
        assert h.probe_due(now=25.0) is None
        assert h.probe_due(now=30.0) == "vpu_direct"
        assert h.snapshot(now=0.0)["mode"] == "vpu_popcount"


# --------------------------------------------------------------------------
# Admission: validation + bounded queue
# --------------------------------------------------------------------------

class TestAdmission:
    def test_bad_payloads_rejected_not_enqueued(self, tiny_engine):
        server, _ = _server(tiny_engine)
        cases = [np.zeros((4, 4, 3), np.uint8),          # wrong shape
                 np.array([object()]),                   # non-numeric
                 np.full((16, 16, 3), np.nan)]           # NaN
        for p in cases:
            r = server.submit(p)
            assert r.done and r.outcome == "rejected" and r.error
        assert len(server.scheduler) == 0
        assert server.metrics()["rejected"] == len(cases)
        outs = [f["outcome"] for f in server.flight.dump()]
        assert outs == ["rejected"] * len(cases)

    def test_good_payload_accepted(self, tiny_engine):
        server, _ = _server(tiny_engine)
        r = server.submit(_images(1)[0])
        assert not r.done and len(server.scheduler) == 1
        server.drain()
        assert r.outcome == "served"

    def test_queue_full_rejects(self, tiny_engine):
        server, _ = _server(tiny_engine, max_queue=2)
        imgs = _images(4)
        rs = [server.submit(p) for p in imgs]
        assert [r.outcome for r in rs] == \
            [None, None, "rejected", "rejected"]
        server.drain()
        assert [r.outcome for r in rs[:2]] == ["served", "served"]

    def test_validation_off_defers_to_serve_path(self, tiny_engine):
        # With validation off the bad payload still terminally resolves
        # (error), it just costs a dispatch attempt.
        server, _ = _server(tiny_engine, validate=False,
                            retry=RetryPolicy(max_attempts=2,
                                              jitter=0.0))
        r = server.submit(np.zeros((4, 4, 3), np.uint8))
        server.drain()
        assert r.done and r.outcome == "error"


# --------------------------------------------------------------------------
# The fault matrix: site × kind × retry policy under a fake clock
# --------------------------------------------------------------------------

MATRIX_SITES = [
    ("server.preprocess", "preprocess_error"),
    ("server.dispatch", "device_oom"),
    ("server.device", "device_fault"),
    ("engine.compile", "compile_error"),
    ("executor.call", "device_oom"),
]
MATRIX_RETRY = [
    pytest.param(None, id="no-retry"),
    pytest.param(RetryPolicy(max_attempts=1, jitter=0.0), id="one-shot"),
    pytest.param(RetryPolicy(max_attempts=3, backoff_base_s=0.01,
                             jitter=0.0), id="retry3"),
]


class TestFaultMatrix:
    @pytest.mark.parametrize("site,kind", MATRIX_SITES,
                             ids=[s for s, _ in MATRIX_SITES])
    @pytest.mark.parametrize("retry", MATRIX_RETRY)
    def test_every_request_terminally_resolves(self, tiny_engine, site,
                                               kind, retry):
        """One fault fires at the given site; every submitted request
        must end done=True with a legal outcome, the loop must survive,
        and non-faulted requests must serve bit-exact."""
        # Fresh engine (sharing the converted artifact), no precompile:
        # the first dispatch's cache-miss compile is the engine.compile
        # site's natural fire point — a warm executable cache would
        # never miss again.
        eng = PhoneBitEngine(spec=tiny_engine.spec,
                             packed=tiny_engine.packed,
                             input_hw=tiny_engine.input_hw)
        server, clock = _server(eng, retry=retry,
                                buckets=(1,), max_batch=1)
        imgs = _images(6)
        plan = FaultPlan([FaultSpec(site, kind, times=1)],
                         sleep=clock.sleep)
        faults.install(plan)
        try:
            rs = [server.submit(p) for p in imgs]
            done = server.drain()
        finally:
            faults.uninstall()
        assert len(done) == len(rs)
        assert all(r.done and r.outcome in OUTCOMES for r in rs)
        assert len(plan.log) == 1                    # the fault did fire
        n_retries = server.metrics()["retries"]
        budget = retry.max_attempts if retry else 1
        if budget > 1:
            # transient single fault + retry budget -> everything serves
            assert all(r.outcome == "served" for r in rs)
            assert n_retries >= 1
        else:
            outcomes = {r.outcome for r in rs}
            assert outcomes <= {"served", "error"}
            assert sum(r.outcome == "error" for r in rs) == 1
        # non-faulted requests are bit-exact vs the cross-check oracle
        for r in rs:
            if r.outcome != "served" or r.attempts:
                continue
            want = np.asarray(eng.cross_check(
                np.asarray(r.payload)[None]))[0]
            np.testing.assert_array_equal(np.asarray(r.result), want)
        # flight rows exist for every terminal outcome
        flight_ids = {f.get("id") for f in server.flight.dump()}
        assert {r.id for r in rs} <= flight_ids

    @pytest.mark.parametrize("kind", ["latency_spike"])
    def test_latency_spike_serves_everything(self, tiny_engine, kind):
        server, clock = _server(tiny_engine, buckets=(1,), max_batch=1)
        server.compile_buckets()
        plan = FaultPlan([FaultSpec("server.device", kind, times=2,
                                    duration_s=0.5)], sleep=clock.sleep)
        faults.install(plan)
        try:
            rs = [server.submit(p) for p in _images(4)]
            server.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in rs)
        assert len(plan.log) == 2
        assert clock.t >= 1.0                        # the spikes stalled

    def test_retry_backoff_runs_on_server_clock(self, tiny_engine):
        """The retried request becomes eligible only after the policy's
        deterministic (jitter=0) backoff has elapsed on the fake clock."""
        server, clock = _server(
            tiny_engine, buckets=(1,), max_batch=1,
            retry=RetryPolicy(max_attempts=2, backoff_base_s=5.0,
                              backoff_cap_s=100.0, jitter=0.0))
        server.compile_buckets()
        faults.install(FaultPlan([FaultSpec("server.device",
                                            "device_fault", times=1)]))
        try:
            r = server.submit(_images(1)[0])
            server.step(force=True)                  # dispatch
            server.step(force=True)                  # readback faults
            assert not r.done and r.not_before == pytest.approx(5.0)
            t_before = clock.t
            server.drain()                           # waits out backoff
        finally:
            faults.uninstall()
        assert r.outcome == "served"
        assert clock.t - t_before >= 5.0             # slept through sleep()

    def test_fault_stream_is_replayable(self, tiny_engine):
        """Same seed + same request stream -> identical injection log
        and identical outcomes (what makes storms debuggable)."""
        def run():
            server, clock = _server(
                tiny_engine,
                retry=RetryPolicy(max_attempts=2, backoff_base_s=0.001,
                                  jitter=0.0))
            server.compile_buckets()
            plan = FaultPlan([FaultSpec("server.device", "device_fault",
                                        rate=0.3)], seed=11,
                             sleep=clock.sleep)
            faults.install(plan)
            try:
                rs = [server.submit(p) for p in _images(8)]
                server.drain()
            finally:
                faults.uninstall()
            return ([(f["site"], f["call"]) for f in plan.log],
                    [r.outcome for r in rs])

        assert run() == run()


# --------------------------------------------------------------------------
# Degradation end to end
# --------------------------------------------------------------------------

class TestDegradation:
    def _stormy_server(self, tiny_engine, **kw):
        # Engine configured one rung above the floor so there is
        # somewhere to demote to.
        eng = PhoneBitEngine(spec=tiny_engine.spec,
                             packed=tiny_engine.packed,
                             input_hw=tiny_engine.input_hw,
                             matmul_mode="xla_pm1")
        kw.setdefault("retry", RetryPolicy(max_attempts=4,
                                           backoff_base_s=0.001,
                                           jitter=0.0))
        return _server(eng, **kw)

    def test_demotes_after_consecutive_failures(self, tiny_engine):
        server, clock = self._stormy_server(tiny_engine, demote_after=2,
                                            probe_after_s=1000.0)
        server.compile_buckets()
        # fault only the configured mode: the demoted floor is healthy
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault",
                      match={"mode": "xla_pm1"})]))
        try:
            rs = [server.submit(p) for p in _images(4)]
            server.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in rs)
        assert server.health.mode == "xla"
        assert server.metrics()["degraded"] == 1
        assert server.metrics()["mode"] == "xla"
        demos = [f for f in server.flight.dump()
                 if f.get("kind") == "demotion"]
        assert len(demos) == 1
        assert demos[0]["from_mode"] == "xla_pm1"
        assert demos[0]["to_mode"] == "xla"
        from repro.obs import metrics as obs_metrics
        evs = obs_metrics.get_registry().events("demotion")
        assert evs and evs[-1]["to_mode"] == "xla"

    def test_reprobe_promotes_after_quarantine(self, tiny_engine):
        server, clock = self._stormy_server(tiny_engine, demote_after=1,
                                            probe_after_s=10.0)
        server.compile_buckets()
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault", times=1,
                      match={"mode": "xla_pm1"})]))
        try:
            rs = [server.submit(p) for p in _images(2)]
            server.drain()
            assert server.health.mode == "xla"       # demoted
            clock.t += 60.0                          # quarantine expires
            # Health is per-bucket (§14.3): the demotion hit the
            # 2-bucket, so the probe needs 2-bucket traffic.
            r2 = [server.submit(p) for p in _images(2)]
            server.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in r2)
        assert server.health.mode == "xla_pm1"       # probe promoted
        promos = [f for f in server.flight.dump()
                  if f.get("kind") == "promotion"]
        assert promos and promos[-1]["to_mode"] == "xla_pm1"

    def test_demotion_serves_same_packed_results(self, tiny_engine):
        """A demoted request's result matches the demoted backend's own
        reference bit-for-bit (resilience never corrupts data)."""
        server, clock = self._stormy_server(tiny_engine, demote_after=1,
                                            probe_after_s=1000.0,
                                            buckets=(1,), max_batch=1)
        server.compile_buckets()
        img = _images(1)[0]
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault",
                      match={"mode": "xla_pm1"})]))
        try:
            r = server.submit(img)
            server.drain()
        finally:
            faults.uninstall()
        assert r.outcome == "served" and server.health.mode == "xla"
        want = np.asarray(server.engine.compile(1, mode="xla")(
            np.asarray(img)[None]))[0]
        np.testing.assert_array_equal(np.asarray(r.result), want)


# --------------------------------------------------------------------------
# Watchdog + drain guard
# --------------------------------------------------------------------------

class TestWatchdogAndDrain:
    def test_watchdog_times_out_wedged_readback(self, tiny_engine):
        server, clock = _server(tiny_engine, watchdog_s=0.2, retry=None,
                                buckets=(1,), max_batch=1)
        server.compile_buckets()
        # a latency spike (real sleep) longer than the watchdog
        faults.install(FaultPlan([
            FaultSpec("server.device", "latency_spike", times=1,
                      duration_s=2.0)], sleep=time.sleep))
        try:
            r = server.submit(_images(1)[0])
            t0 = time.monotonic()
            server.drain()
            elapsed = time.monotonic() - t0
        finally:
            faults.uninstall()
        assert r.done and r.outcome == "error"
        assert "WatchdogTimeout" in r.error
        assert elapsed < 1.5                         # didn't wait the 2s

    def test_watchdog_off_is_direct_call(self, tiny_engine):
        server, _ = _server(tiny_engine, watchdog_s=None)
        server.compile_buckets()
        n0 = threading.active_count()
        rs = [server.submit(p) for p in _images(3)]
        server.drain()
        assert all(r.outcome == "served" for r in rs)
        assert threading.active_count() == n0        # no reader threads

    def test_drain_bounded_when_wedged(self, tiny_engine):
        """Every dispatch faults forever: drain must terminate with all
        requests resolved error, not spin."""
        server, clock = _server(tiny_engine,
                                retry=RetryPolicy(max_attempts=2,
                                                  backoff_base_s=0.001,
                                                  jitter=0.0))
        server.compile_buckets()
        faults.install(FaultPlan([FaultSpec("server.dispatch",
                                            "device_fault")]))
        try:
            rs = [server.submit(p) for p in _images(5)]
            done = server.drain()
        finally:
            faults.uninstall()
        assert len(server.scheduler) == 0 and server._pending is None
        assert all(r.done and r.outcome == "error" for r in rs)
        assert len(done) == len(rs)

    def test_drain_max_steps_abort_records_error(self, tiny_engine):
        server, clock = _server(tiny_engine, retry=None)
        server.compile_buckets()
        rs = [server.submit(p) for p in _images(3)]
        done = server.drain(max_steps=0)             # immediate abort
        assert all(r.outcome == "error" for r in rs)
        assert all("wedged" in r.error for r in rs)
        assert len(done) == len(rs)
        errs = [f for f in server.flight.dump()
                if f.get("outcome") == "error"]
        assert len(errs) == len(rs)


# --------------------------------------------------------------------------
# LM server parity
# --------------------------------------------------------------------------

class TestLMServerParity:
    @pytest.fixture(scope="class")
    def lm(self):
        from repro.distributed.sharding import rules_for_mesh
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer
        from repro.serving.lm_server import LMServer

        cfg = transformer.LMConfig(name="t", n_layers=1, d_model=32,
                                   n_heads=2, n_kv_heads=2, d_head=16,
                                   d_ff=64, vocab=64, tie_embeddings=True)
        mesh = make_host_mesh(data=1, model=1)
        rules = rules_for_mesh(mesh)
        with mesh:
            params = transformer.init_params(jax.random.key(0), cfg, ep=1)
            yield dict(cfg=cfg, rules=rules, params=params, mesh=mesh,
                       LMServer=LMServer)

    def test_rejects_resolve_with_outcome(self, lm):
        with lm["mesh"]:
            s = lm["LMServer"](cfg=lm["cfg"], rules=lm["rules"],
                               params=lm["params"], n_slots=2, max_seq=16,
                               max_queue=1)
            bad = s.submit([])
            assert bad.outcome == "rejected" and "empty" in bad.error
            bad = s.submit([1] * 20, max_new=4)
            assert bad.outcome == "rejected" and "max_seq" in bad.error
            ok = s.submit([1, 2], max_new=1)
            full = s.submit([3, 4], max_new=1)
            assert full.outcome == "rejected" and "queue full" in full.error
            assert s.metrics()["rejected"] == 3
            s.drain()
            assert ok.outcome == "served"
            outs = [f["outcome"] for f in s.flight.dump()]
            assert outs.count("rejected") == 3 and "served" in outs

    def test_faulted_tick_retries_then_errors(self, lm):
        with lm["mesh"]:
            s = lm["LMServer"](cfg=lm["cfg"], rules=lm["rules"],
                               params=lm["params"], n_slots=2, max_seq=16,
                               retry=RetryPolicy(max_attempts=2,
                                                 jitter=0.0))
            r = s.submit([1, 2, 3], max_new=8)
            faults.install(FaultPlan([FaultSpec("lm.step",
                                                "device_fault")]))
            try:
                done = s.drain()
            finally:
                faults.uninstall()
            assert r.done and r.outcome == "error"
            assert len(done) == 1
            m = s.metrics()
            assert m["retries"] >= 1 and m["errors"] == 1
            assert s.manager.active == {}            # slot released
            # and the server still serves afterwards
            r2 = s.submit([1, 2], max_new=1)
            s.drain()
            assert r2.outcome == "served"

    def test_transient_tick_fault_recovers(self, lm):
        with lm["mesh"]:
            s = lm["LMServer"](cfg=lm["cfg"], rules=lm["rules"],
                               params=lm["params"], n_slots=2, max_seq=16,
                               retry=RetryPolicy(max_attempts=3,
                                                 jitter=0.0))
            r = s.submit([1, 2, 3], max_new=4)
            faults.install(FaultPlan([FaultSpec("lm.step", "device_fault",
                                                times=1)]))
            try:
                s.drain()
            finally:
                faults.uninstall()
            assert r.outcome == "served" and len(r.result) >= 1
            assert s.metrics()["retries"] == 1

    def test_drain_bounded(self, lm):
        with lm["mesh"]:
            s = lm["LMServer"](cfg=lm["cfg"], rules=lm["rules"],
                               params=lm["params"], n_slots=1, max_seq=16)
            r1 = s.submit([1, 2], max_new=4)
            r2 = s.submit([3, 4], max_new=4)
            done = s.drain(max_steps=0)              # immediate abort
            assert all(r.done and r.outcome == "error" for r in (r1, r2))
            assert len(done) == 2


# --------------------------------------------------------------------------
# Endurance harness smoke (in-process)
# --------------------------------------------------------------------------

class TestEnduranceSmoke:
    def test_smoke_report_shape_and_invariants(self, tmp_path):
        import sys
        sys.path.insert(0, ".")
        try:
            from benchmarks import endurance_bench
        finally:
            sys.path.pop(0)
        out = tmp_path / "BENCH_endurance.json"
        report = endurance_bench.run(smoke=True, out=str(out))
        assert out.exists()
        assert report["meta"]["schema"] == "bench-meta-v1"
        s = report["summary"]
        assert s["unhandled_exceptions"] == 0
        assert s["all_terminal"] is True
        assert s["steady_flat_trace"] is True
        assert s["storm_availability"] >= 0.95
        assert s["bitexact_ok"] is True
        assert s["ok"] is True
        names = [sc["scenario"] for sc in report["scenarios"]]
        assert names == ["steady", "fault_storm", "kill_recover"]
        storm = report["scenarios"][1]
        assert storm["faults_injected"] > 0
        assert len(storm["demotions"]) >= 1
        killrec = report["scenarios"][2]
        assert killrec["ok"] is True
        assert killrec["killed"] is True
        assert killrec["journaled_unresolved"] > 0
        assert killrec["recovered_fraction"] == 1.0
        assert killrec["unresolved_after"] == 0
        assert killrec["trace_count"] == 0


# --------------------------------------------------------------------------
# Distributed fault matrix: replica-scoped faults (DESIGN.md §13.3)
# --------------------------------------------------------------------------

class TestDistributedFaults:
    """Device faults injected on ONE replica of a :class:`ReplicaGroup`
    must stay replica-scoped: only that replica's ladder demotes and
    quarantines, every non-faulted request stays bit-exact, routing
    steers around the sick replica, and it re-probes/promotes on the
    normal PR 7 ladder schedule.  Replica lanes carry ``tenant=<name>``,
    so fault plans target one replica with ``match={"tenant": "r1"}``."""

    def _group(self, tiny_engine, **kw):
        from repro.distributed import ReplicaGroup

        # One rung above the floor so there is somewhere to demote to.
        eng = PhoneBitEngine(spec=tiny_engine.spec,
                             packed=tiny_engine.packed,
                             input_hw=tiny_engine.input_hw,
                             matmul_mode="xla_pm1")
        clock = FakeClock()
        kw.setdefault("retry", RetryPolicy(max_attempts=4,
                                           backoff_base_s=0.001,
                                           jitter=0.0))
        kw.setdefault("buckets", (1, 2, 4))
        kw.setdefault("max_batch", 4)
        kw.setdefault("max_wait_s", 0.0)
        dev = jax.devices()[0]
        grp = ReplicaGroup(eng, [dev, dev], clock=clock,
                           sleep=clock.sleep, **kw)
        return grp, clock

    @pytest.mark.parametrize("site,kind", [
        ("server.dispatch", "device_fault"),
        ("server.dispatch", "device_oom"),
        ("server.device", "device_fault"),
        ("server.device", "device_oom"),
    ])
    def test_fault_on_one_replica_quarantines_only_it(self, tiny_engine,
                                                      site, kind):
        grp, clock = self._group(tiny_engine, demote_after=1,
                                 probe_after_s=1000.0)
        grp.compile_buckets()
        match = {"tenant": "r1"}
        extra = {}
        if site == "server.dispatch":
            # dispatch carries mode ctx: fault only the configured rung,
            # the demoted floor serves (persistent-fault recovery path)
            match["mode"] = "xla_pm1"
        else:
            # device readback has no mode ctx: cap the fault instead
            # (transient-fault recovery path)
            extra["times"] = 2
        faults.install(FaultPlan([FaultSpec(site, kind, match=match,
                                            **extra)]))
        try:
            imgs = _images(4)
            rs = [grp.submit(p, replica=("r1" if i % 2 else "r0"))
                  for i, p in enumerate(imgs)]
            grp.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in rs)
        r0, r1 = grp.replicas["r0"], grp.replicas["r1"]
        # blast radius: exactly one ladder moved
        assert r1.server.health.mode == "xla"          # demoted
        assert r1.server.metrics()["degraded"] >= 1
        assert not r1.healthy
        assert r0.server.health.mode == "xla_pm1"      # untouched
        assert r0.server.metrics()["degraded"] == 0
        assert r0.server.metrics()["retries"] == 0
        assert r0.healthy
        # the router now steers new work to the healthy replica
        assert grp._route().name == "r0"
        assert grp.metrics()["routing"]["r1"]["healthy"] is False
        # every result — faulted replica included (all modes bit-exact,
        # retries never corrupt data) — matches the engine oracle
        ref = np.asarray(r0.server.engine.compile(4)(
            np.stack([np.asarray(p) for p in imgs])))
        for i, r in enumerate(rs):
            np.testing.assert_array_equal(np.asarray(r.result), ref[i])

    def test_sick_replica_reprobes_and_promotes(self, tiny_engine):
        grp, clock = self._group(tiny_engine, demote_after=1,
                                 probe_after_s=10.0)
        grp.compile_buckets()
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault", times=1,
                      match={"tenant": "r1", "mode": "xla_pm1"})]))
        try:
            rs = [grp.submit(p, replica="r1") for p in _images(2)]
            grp.drain()
            r1 = grp.replicas["r1"]
            assert r1.server.health.mode == "xla" and not r1.healthy
            clock.t += 60.0                      # quarantine expires
            # Health is per-bucket (§14.3): the demotion hit the
            # 2-bucket, so the probe needs 2-bucket traffic.
            r2 = [grp.submit(p, replica="r1") for p in _images(2)]
            grp.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in rs + r2)
        r1 = grp.replicas["r1"]
        assert r1.server.health.mode == "xla_pm1"    # probe promoted
        assert r1.healthy
        assert grp.metrics()["routing"]["r1"]["healthy"] is True
        promos = [f for f in r1.server.flight.dump()
                  if f.get("kind") == "promotion"]
        assert promos and promos[-1]["to_mode"] == "xla_pm1"
        # r0 never saw any of it
        assert grp.replicas["r0"].server.health.mode == "xla_pm1"

    def test_unpinned_traffic_avoids_quarantined_replica(self, tiny_engine):
        grp, clock = self._group(tiny_engine, demote_after=1,
                                 probe_after_s=1000.0)
        grp.compile_buckets()
        faults.install(FaultPlan([
            FaultSpec("server.dispatch", "device_fault",
                      match={"tenant": "r1", "mode": "xla_pm1"})]))
        try:
            warm = [grp.submit(p, replica="r1") for p in _images(2)]
            grp.drain()                          # r1 demotes
            assert not grp.replicas["r1"].healthy
            rs = [grp.submit(p) for p in _images(4)]     # router's choice
            grp.drain()
        finally:
            faults.uninstall()
        assert all(r.outcome == "served" for r in warm + rs)
        m = grp.metrics()
        # all post-demotion traffic landed on the healthy replica
        assert m["replicas"]["r0"]["served"] == 4
        assert m["replicas"]["r0"]["retries"] == 0
