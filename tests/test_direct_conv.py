"""Direct (im2col-free) fused conv kernel + its runtime integration.

Bit-exactness of ``direct_conv_bn_binarize`` against the float BN oracle
and the canonical im2col path across the awkward-shape matrix
(non-block-multiple OH/OW/O, stride 2, pad 0/1, 1x1 pointwise, bit-plane
word weights), the pool-epilogue fusion pass, the ``vpu_direct``/
``vpu_direct_pool`` executor backends, the tile-shape autotuner and its
disk-persisted cache (DESIGN.md §5).
"""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.core import (binary_conv, bitplanes, bnn_model, converter,
                        layer_integration, packing)
from repro.core.bnn_model import BConv, BDense, FloatDense, Pool
from repro import runtime
from repro.kernels.direct_conv_bn_binarize import direct_conv_bn_binarize
from repro.kernels.xnor_popcount_matmul import xnor_popcount_matmul
from repro.runtime import (Autotuner, GraphExecutor, fuse_pool_epilogue,
                           lower_packed, plan_memory)
from repro.serving import PhoneBitEngine


def _float_oracle_packed(x_pm1, w, gamma, beta, mu, sigma, stride, pad):
    """binarize(BN(conv(x, w))) with the -1 padding convention, packed."""
    if pad:
        x_pm1 = jnp.pad(x_pm1, ((0, 0), (pad, pad), (pad, pad), (0, 0)),
                        constant_values=-1.0)
    dot = lax.conv_general_dilated(
        x_pm1, w, (stride, stride), [(0, 0)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    bits = layer_integration.bn_reference(dot, gamma, beta, mu, sigma)
    return packing.pack_bits(bits, axis=-1)


class TestDirectConvKernel:
    """Kernel vs float oracle + im2col path over the shape matrix."""

    @pytest.mark.parametrize("h,c_in,c_out,kh,stride,pad,block_kw", [
        (8, 64, 32, 3, 1, 1, {}),                        # baseline
        (9, 64, 40, 3, 1, 1, {}),                        # O % 32 != 0, odd HW
        (10, 96, 33, 3, 2, 0, {}),                       # stride 2, pad 0
        (7, 64, 64, 1, 1, 0, {}),                        # 1x1 pointwise
        (11, 64, 32, 3, 1, 1, dict(block_h=3, block_w=4)),  # non-multiple
        (8, 33, 32, 3, 1, 1, dict(block_n=2)),           # ragged Cw + batch
        (8, 64, 32, 5, 2, 2, dict(block_o=32)),          # k5 s2 p2
    ])
    def test_vs_float_oracle_and_im2col(self, h, c_in, c_out, kh, stride,
                                        pad, block_kw):
        rng = np.random.default_rng(h * 31 + c_out)
        x = jnp.asarray(rng.choice([-1.0, 1.0], (2, h, h, c_in))
                        .astype(np.float32))
        w = jnp.asarray(rng.choice([-1.0, 1.0], (kh, kh, c_in, c_out))
                        .astype(np.float32))
        gamma = jnp.asarray(rng.uniform(-1.5, 1.5, c_out), jnp.float32)
        beta = jnp.asarray(rng.uniform(-1, 1, c_out), jnp.float32)
        mu = jnp.asarray(rng.uniform(-20, 20, c_out), jnp.float32)
        sigma = jnp.asarray(rng.uniform(0.5, 2, c_out), jnp.float32)
        p = layer_integration.fold_bn(kh * kh * c_in, gamma, beta, mu,
                                      sigma)

        xp = packing.pack_signs(x, axis=-1)
        wp = binary_conv.pack_conv_weights(w)
        got = direct_conv_bn_binarize(
            xp, wp, p.threshold, p.sign_flip, kh=kh, kw=kh, stride=stride,
            pad=pad, interpret=True, **block_kw)

        oracle = _float_oracle_packed(x, w, gamma, beta, mu, sigma,
                                      stride, pad)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))

        im2col = binary_conv.binary_conv2d_fused(xp, wp, p, kh, kh,
                                                 stride, pad)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(im2col))

    def test_bitplane_first_layer_word_weights(self):
        """Eqn-2 bit-plane word weights through the direct kernel."""
        rng = np.random.default_rng(3)
        c_in, c_out, kh, h = 3, 40, 3, 9
        x = jnp.asarray(rng.integers(0, 256, (2, h, h, c_in)), jnp.uint8)
        planes = bitplanes.pack_bitplanes(x)
        n, hh, ww_, np_, cw_ = planes.shape
        flat = planes.reshape(n, hh, ww_, np_ * cw_)
        w = jnp.asarray(rng.choice([-1.0, 1.0], (kh, kh, c_in, c_out))
                        .astype(np.float32))
        wp = packing.pack_signs(w, axis=2)
        wp = jnp.repeat(wp[:, :, None, :, :], bitplanes.NUM_PLANES, axis=2)
        wp = jnp.transpose(wp, (4, 0, 1, 2, 3)).reshape(c_out, -1)
        cw = packing.num_words(c_in)
        ww = jnp.tile(bitplanes.plane_word_weights(cw), kh * kh)
        t = jnp.asarray(rng.integers(0, 255 * kh * kh * c_in, c_out),
                        jnp.int32)
        s = jnp.asarray(rng.integers(0, 2, c_out).astype(bool))
        p = layer_integration.IntegratedParams(t, s)
        ref = binary_conv.binary_conv2d_fused(flat, wp, p, kh, kh, 1, 1,
                                              word_weights=ww)
        got = direct_conv_bn_binarize(flat, wp, t, s, kh=kh, kw=kh,
                                      stride=1, pad=1, word_weights=ww,
                                      interpret=True, block_h=4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @pytest.mark.parametrize("pool,block_kw", [
        ((2, 2, (0, 0)), {}),                         # plain pool
        ((2, 2, (0, 0)), dict(block_h=2, block_w=3)), # tiled pool epilogue
        ((2, 1, (0, 1)), {}),                         # yolo same-pool pad
        ((3, 2, (0, 0)), dict(block_h=2)),            # window 3
    ])
    def test_pool_epilogue(self, pool, block_kw):
        rng = np.random.default_rng(11)
        h, c_in, c_out, kh = 13, 64, 48, 3
        window, pstride, ppad = pool
        x = jnp.asarray(rng.choice([-1.0, 1.0], (2, h, h, c_in))
                        .astype(np.float32))
        w = jnp.asarray(rng.choice([-1.0, 1.0], (kh, kh, c_in, c_out))
                        .astype(np.float32))
        xp = packing.pack_signs(x, axis=-1)
        wp = binary_conv.pack_conv_weights(w)
        kv = kh * kh * c_in
        t = jnp.asarray(rng.integers(0, kv, c_out), jnp.int32)
        s = jnp.asarray(rng.integers(0, 2, c_out).astype(bool))
        p = layer_integration.IntegratedParams(t, s)
        conv = binary_conv.binary_conv2d_fused(xp, wp, p, kh, kh, 1, 1)
        ref = binary_conv.binary_or_maxpool(conv, window, pstride, pad=ppad)
        got = direct_conv_bn_binarize(
            xp, wp, t, s, kh=kh, kw=kh, stride=1, pad=1,
            pool_window=window, pool_stride=pstride, pool_pad=ppad,
            interpret=True, **block_kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


class TestVectorizedReduction:
    """The whole-tile reduction == the legacy per-word loop form."""

    @pytest.mark.parametrize("m,n,k", [(10, 7, 65), (33, 40, 96)])
    def test_loop_vs_vector(self, m, n, k):
        rng = np.random.default_rng(m + n + k)
        a = packing.pack_signs(
            jnp.asarray(rng.choice([-1.0, 1.0], (m, k)).astype(np.float32)))
        b = packing.pack_signs(
            jnp.asarray(rng.choice([-1.0, 1.0], (n, k)).astype(np.float32)))
        v = xnor_popcount_matmul(a, b, block_m=16, block_n=16, block_k=2,
                                 reduction="vector", interpret=True)
        l = xnor_popcount_matmul(a, b, block_m=16, block_n=16, block_k=2,
                                 reduction="loop", interpret=True)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(l))


# --------------------------------------------------------------------------
# Runtime integration
# --------------------------------------------------------------------------

def _pool_net():
    return [
        BConv(c_in=3, c_out=16, kernel=3, stride=1, pad=1, first=True),
        Pool(window=2, stride=2),
        BConv(c_in=16, c_out=40, kernel=3, stride=1, pad=1),
        Pool(window=2, stride=1, pad=(0, 1)),
        BDense(d_in=8 * 8 * 40, d_out=64),
        FloatDense(d_in=64, d_out=10),
    ]


def _randomize_bn(params, seed=42):
    rng = np.random.default_rng(seed)
    for p in params:
        if "mu" in p:
            o = p["mu"].shape[0]
            p["mu"] = jnp.asarray(rng.uniform(-20, 20, o), jnp.float32)
            p["var"] = jnp.asarray(rng.uniform(0.5, 4, o), jnp.float32)
            p["gamma"] = jnp.asarray(rng.uniform(-1.5, 1.5, o), jnp.float32)
            p["beta"] = jnp.asarray(rng.uniform(-1, 1, o), jnp.float32)
    return params


@pytest.fixture(scope="module")
def pooly():
    spec = _pool_net()
    params = _randomize_bn(bnn_model.init_params(jax.random.key(4), spec))
    packed = converter.convert(params, spec, (16, 16))
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(0, 256, (2, 16, 16, 3)), jnp.uint8)
    return spec, params, packed, x


class TestPoolFusionPass:

    def test_rewrites_and_stays_exact(self, pooly):
        spec, _, packed, x = pooly
        g = lower_packed(spec, packed, (16, 16))
        gf = fuse_pool_epilogue(g)
        ops = [gf.nodes[i].op for i in gf.topo_order()]
        assert "or_pool" not in ops
        assert ops.count("packed_conv_pool") == 2
        np.testing.assert_array_equal(
            np.asarray(GraphExecutor(g, "xla")(x)),
            np.asarray(GraphExecutor(gf, "xla")(x)))

    def test_fanout_blocks_fusion(self, pooly):
        spec, _, packed, x = pooly
        g = lower_packed(spec, packed, (16, 16))
        # Give the first conv a second consumer: its unpooled map must
        # stay materialized, so the pool cannot be absorbed.
        conv_id = next(nid for nid in g.topo_order()
                       if g.nodes[nid].op == "packed_conv")
        g.output_id = g.add("concat_packed", [conv_id, conv_id],
                            attrs=dict(channels=32))
        gf = fuse_pool_epilogue(g)
        assert any(n.op == "or_pool" for n in gf.nodes.values())

    def test_peak_bytes_drop_on_conv_heavy_graph(self, pooly):
        """The direct path materializes no im2col buffer and (pool-fused)
        no unpooled conv map: the planned arena must shrink."""
        spec, _, packed, x = pooly
        g = lower_packed(spec, packed, (16, 16))
        gf = fuse_pool_epilogue(g)
        p0 = plan_memory(g, (1, 16, 16, 3)).peak_bytes()
        p1 = plan_memory(gf, (1, 16, 16, 3)).peak_bytes()
        assert p1 < p0

    def test_infer_types_matches_execution(self, pooly):
        spec, _, packed, x = pooly
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        types = runtime.infer_types(gf, x.shape)
        ex = GraphExecutor(gf, "xla")
        out = ex(x)
        assert tuple(out.shape) == types[gf.output_id].shape


class TestDirectBackends:

    def test_all_backends_bit_exact(self, pooly):
        spec, _, packed, x = pooly
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        ref = bnn_model.packed_forward(packed, spec, x[:1])
        for backend in ("xla", "xla_pm1", "vpu_popcount", "vpu_direct",
                        "vpu_direct_pool"):
            got = GraphExecutor(gf, backend)(x[:1])
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                          err_msg=backend)

    def test_backend_validity(self, pooly):
        spec, _, packed, _ = pooly
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        dense = next(nid for nid in gf.topo_order()
                     if gf.nodes[nid].op == "packed_dense")
        with pytest.raises(ValueError):
            GraphExecutor(gf, {dense: "vpu_direct"})
        assert runtime.valid_backends("packed_conv_pool") == runtime.BACKENDS
        assert "vpu_direct_pool" not in runtime.valid_backends("packed_conv")

    def test_tile_configs_are_static_and_exact(self, pooly):
        spec, _, packed, x = pooly
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        convs = [nid for nid in gf.topo_order()
                 if gf.nodes[nid].op == "packed_conv_pool"]
        ex = GraphExecutor(gf, {nid: "vpu_direct_pool" for nid in convs},
                           {convs[0]: dict(block_h=2, block_n=2)})
        ref = bnn_model.packed_forward(packed, spec, x)
        np.testing.assert_array_equal(np.asarray(ex(x)), np.asarray(ref))
        ex(x)
        assert ex.trace_count == 1
        assert any(r["tile"] for r in ex.backend_report())

    def test_engine_direct_modes_cross_check(self, pooly):
        spec, params, _, x = pooly
        for mode in ("vpu_direct", "vpu_direct_pool"):
            engine = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                                 matmul_mode=mode)
            engine.cross_check(x[:1])  # graph path == flat oracle
            report = engine.backend_choices
            assert any(r["op"] == "packed_conv_pool" for r in report)
            assert all(r["backend"] == "vpu_popcount"
                       for r in report if r["op"] == "packed_dense")

    def test_engine_matches_float_oracle(self, pooly):
        spec, params, _, x = pooly
        engine = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                             matmul_mode="vpu_direct_pool")
        got = engine(x[:1])
        ref = bnn_model.float_forward(params, spec, x[:1])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=0, atol=1e-3)


class TestAutotuneTilesAndCache:

    def test_tune_with_tiles_direct_candidates(self, pooly):
        spec, _, packed, x = pooly
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        tuner = Autotuner(candidates=("xla", "vpu_direct",
                                      "vpu_direct_pool"),
                          warmup=0, iters=1)
        choices, tiles = tuner.tune_with_tiles(gf, (1, 16, 16, 3))
        assert choices
        for nid, b in choices.items():
            assert b in runtime.valid_backends(gf.nodes[nid].op)
        # direct candidates were swept with tile configs
        entry = next(iter(tuner.cache.values()))
        assert any("[" in lbl for lbl in entry["timings_ms"])
        ex = GraphExecutor(gf, choices, tiles)
        ref = bnn_model.packed_forward(packed, spec, x)
        np.testing.assert_array_equal(np.asarray(ex(x)), np.asarray(ref))

    def test_disk_cache_roundtrip(self, pooly, tmp_path, monkeypatch):
        from repro.obs import metrics as obs_metrics

        spec, _, packed, x = pooly
        path = tmp_path / "autotune.json"
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        t1 = Autotuner(candidates=("xla", "xla_pm1"), warmup=0, iters=1)
        with obs_metrics.use_registry() as reg1:
            choices, _ = t1.tune_with_tiles(gf, (1, 16, 16, 3))
        # every fresh sweep leaves a structured miss event per signature
        evs = reg1.events("autotune")
        assert [e["outcome"] for e in evs] == ["miss"] * len(t1.cache)
        assert all(e["sweep_size"] >= 2 for e in evs)  # 2+ candidates
        assert {e["signature"] for e in evs} == set(t1.cache)
        assert reg1.counter("autotune.miss").value == len(t1.cache)
        assert path.exists()
        persisted = json.loads(path.read_text())
        # each measurement persists twice: under its exact signature and
        # under the batch-agnostic one (cross-bucket warm start)
        assert len(persisted) == 2 * len(t1.cache)
        assert all(k in persisted for k in t1.cache)
        assert sum(k.startswith("batchless::") for k in persisted) == \
            len(t1.cache)
        assert all(e["winner"] in ("xla", "xla_pm1")
                   for e in persisted.values())
        # A fresh tuner (fresh in-memory cache) warm-starts from disk:
        # same winners, no new timing entries written.
        mtime = path.stat().st_mtime_ns
        t2 = Autotuner(candidates=("xla", "xla_pm1"), warmup=0, iters=1)
        with obs_metrics.use_registry() as reg2:
            choices2, _ = t2.tune_with_tiles(gf, (1, 16, 16, 3))
        assert choices2 == choices
        assert path.stat().st_mtime_ns == mtime
        # ...and the warm start is visible as disk_hit events, no misses
        assert reg2.counter("autotune.disk_hit").value == len(t2.cache)
        assert reg2.counter("autotune.miss").value == 0

    def test_stale_disk_entry_is_disk_miss(self, pooly, tmp_path,
                                           monkeypatch):
        # A disk table written under a different jax/jaxlib must not
        # warm-start: the timings belong to another compiler.  Every
        # stale entry is a structured disk_miss + a fresh sweep, and the
        # re-sweep rewrites the table under the current env stamp.
        from repro.obs import metrics as obs_metrics
        from repro.runtime.autotune import entry_env_ok

        spec, _, packed, _ = pooly
        path = tmp_path / "autotune.json"
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        t1 = Autotuner(candidates=("xla", "xla_pm1"), warmup=0, iters=1)
        t1.tune_with_tiles(gf, (1, 16, 16, 3))
        table = json.loads(path.read_text())
        for e in table.values():
            e["env"] = {"jax": "0.0.1", "jaxlib": "0.0.1"}
        path.write_text(json.dumps(table))

        t2 = Autotuner(candidates=("xla", "xla_pm1"), warmup=0, iters=1)
        with obs_metrics.use_registry() as reg:
            t2.tune_with_tiles(gf, (1, 16, 16, 3))
        assert reg.counter("autotune.disk_hit").value == 0
        assert reg.counter("autotune.disk_miss").value == len(t2.cache)
        assert reg.counter("autotune.miss").value == len(t2.cache)
        assert {e["outcome"] for e in reg.events("autotune")} == \
            {"disk_miss", "miss"}
        # the fresh sweep re-stamped every persisted entry
        rewritten = json.loads(path.read_text())
        assert all(entry_env_ok(e) for e in rewritten.values())

    def test_escape_hatch_disables_persistence(self, pooly, tmp_path,
                                               monkeypatch):
        spec, _, packed, _ = pooly
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "0")
        assert runtime.cache_path() is None
        gf = fuse_pool_epilogue(lower_packed(spec, packed, (16, 16)))
        tuner = Autotuner(candidates=("xla",), warmup=0, iters=1)
        tuner.tune(gf, (1, 16, 16, 3))  # must not write anywhere
        assert not list(tmp_path.iterdir())
