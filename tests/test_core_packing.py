"""Unit + property tests for channel compression and binary algebra."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binary_ops, bitplanes, layer_integration, packing


class TestPacking:
    @pytest.mark.parametrize("c", [1, 3, 31, 32, 33, 64, 100, 256])
    def test_pack_unpack_roundtrip(self, c):
        rng = np.random.default_rng(c)
        bits = rng.integers(0, 2, size=(4, 5, c)).astype(np.int32)
        words = packing.pack_bits(bits)
        assert words.dtype == jnp.int32
        assert words.shape == (4, 5, packing.num_words(c))
        out = packing.unpack_bits(words, c)
        np.testing.assert_array_equal(np.asarray(out), bits)

    def test_pack_axis(self):
        rng = np.random.default_rng(0)
        bits = rng.integers(0, 2, size=(7, 33, 4)).astype(np.int32)
        words = packing.pack_bits(bits, axis=1)
        assert words.shape == (7, 2, 4)
        out = packing.unpack_bits(words, 33, axis=1)
        np.testing.assert_array_equal(np.asarray(out), bits)

    def test_pack_signs_msb_channel(self):
        x = np.array([[0.5, -0.5, 0.0, -1.0]], dtype=np.float32)
        words = packing.pack_signs(x)
        # bits: 1, 0, 1 (>=0), 0 -> 0b0101 = 5
        assert int(words[0, 0]) == 0b0101

    def test_unpack_to_pm1(self):
        x = np.array([[1.0, -2.0, 3.0]], dtype=np.float32)
        w = packing.pack_signs(x)
        pm1 = packing.unpack_to_pm1(w, 3, dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(pm1), [[1.0, -1.0, 1.0]])

    @given(st.integers(1, 200), st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_property(self, c, seed):
        rng = np.random.default_rng(seed)
        bits = rng.integers(0, 2, size=(2, c)).astype(np.int32)
        out = packing.unpack_bits(packing.pack_bits(bits), c)
        np.testing.assert_array_equal(np.asarray(out), bits)


class TestBinaryMatmul:
    @pytest.mark.parametrize("m,n,k", [(4, 8, 32), (3, 5, 7), (16, 16, 257),
                                       (1, 1, 1), (8, 40, 96)])
    def test_dot_matches_pm1_reference(self, m, n, k):
        rng = np.random.default_rng(m * 1000 + n * 10 + k)
        a = rng.choice([-1.0, 1.0], size=(m, k)).astype(np.float32)
        b = rng.choice([-1.0, 1.0], size=(n, k)).astype(np.float32)
        ref = a @ b.T
        ap = packing.pack_signs(a)
        bp = packing.pack_signs(b)
        dot = binary_ops.packed_matmul_dot(ap, bp, k_valid=k)
        np.testing.assert_array_equal(np.asarray(dot), ref.astype(np.int32))

    def test_mxu_pm1_path_matches(self):
        rng = np.random.default_rng(7)
        a = rng.choice([-1.0, 1.0], size=(6, 130)).astype(np.float32)
        b = rng.choice([-1.0, 1.0], size=(9, 130)).astype(np.float32)
        ap, bp = packing.pack_signs(a), packing.pack_signs(b)
        vpu = binary_ops.packed_matmul_dot(ap, bp, k_valid=130)
        mxu = binary_ops.mxu_pm1_matmul(ap, bp, k_valid=130, channels=130)
        np.testing.assert_array_equal(np.asarray(vpu), np.asarray(mxu))

    def test_chunked_matmul(self):
        rng = np.random.default_rng(3)
        a = rng.choice([-1.0, 1.0], size=(50, 64)).astype(np.float32)
        b = rng.choice([-1.0, 1.0], size=(4, 64)).astype(np.float32)
        ap, bp = packing.pack_signs(a), packing.pack_signs(b)
        full = binary_ops.packed_matmul_counts(ap, bp)
        chunked = binary_ops.packed_matmul_counts(ap, bp, chunk=16)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(chunked))

    def test_word_weighted_counts(self):
        # weighted popcount == per-word popcount dot weights
        rng = np.random.default_rng(11)
        a = rng.integers(-2**31, 2**31, size=(3, 5), dtype=np.int32)
        b = rng.integers(-2**31, 2**31, size=(2, 5), dtype=np.int32)
        ww = jnp.asarray([1, 2, 4, 8, 16], dtype=jnp.int32)
        got = binary_ops.packed_matmul_counts(jnp.asarray(a), jnp.asarray(b),
                                              word_weights=ww)
        exp = np.zeros((3, 2), np.int32)
        for i in range(3):
            for j in range(2):
                x = np.bitwise_xor(a[i], b[j])
                pc = np.array([bin(int(v) & 0xFFFFFFFF).count("1") for v in x])
                exp[i, j] = int((pc * np.asarray(ww)).sum())
        np.testing.assert_array_equal(np.asarray(got), exp)


class TestLayerIntegration:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_integer_threshold_matches_float_bn(self, seed):
        """Property: (cnt <= t) xor s == binarize(BN(K - 2cnt)) for all cnt."""
        rng = np.random.default_rng(seed)
        o = 16
        k_valid = int(rng.integers(1, 512))
        gamma = rng.uniform(-2, 2, o).astype(np.float32)
        gamma[np.abs(gamma) < 1e-3] = 1.0  # paper footnote: gamma != 0
        beta = rng.uniform(-1, 1, o).astype(np.float32)
        mu = rng.uniform(-k_valid, k_valid, o).astype(np.float32)
        sigma = rng.uniform(0.1, 3.0, o).astype(np.float32)
        p = layer_integration.fold_bn(k_valid, jnp.asarray(gamma),
                                      jnp.asarray(beta), jnp.asarray(mu),
                                      jnp.asarray(sigma))
        cnt = jnp.arange(k_valid + 1, dtype=jnp.int32)[:, None] * jnp.ones(
            (1, o), jnp.int32)
        got = layer_integration.apply_threshold(cnt, p)
        x1 = (k_valid - 2 * cnt).astype(jnp.float32)
        x3 = gamma * (x1 - mu) / sigma + beta
        exp = (x3 >= 0).astype(np.int32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))

    def test_first_layer_fold_matches_eqn2(self):
        """wcnt <= t  ==  binarize(BN(sum_n 2^(n-1) dot_n)) on random data."""
        rng = np.random.default_rng(5)
        k, c, o = 3, 3, 8
        k_valid = k * k * c
        w = rng.choice([-1.0, 1.0], size=(k, k, c, o)).astype(np.float32)
        w_sum = w.sum(axis=(0, 1, 2))
        gamma = rng.uniform(0.1, 2, o).astype(np.float32)
        beta = rng.uniform(-1, 1, o).astype(np.float32)
        mu = rng.uniform(-100, 100, o).astype(np.float32)
        sigma = rng.uniform(0.5, 2, o).astype(np.float32)
        p = layer_integration.fold_bn_first_layer(
            k_valid, jnp.asarray(w_sum), jnp.asarray(gamma),
            jnp.asarray(beta), jnp.asarray(mu), jnp.asarray(sigma))
        # random uint8 patch, direct integer conv reference
        patch = rng.integers(0, 256, size=(k, k, c))
        s_ref = np.tensordot(patch.astype(np.float64), w, axes=3)  # (o,)
        bit_ref = ((gamma * (s_ref - mu) / sigma + beta) >= 0).astype(np.int32)
        # engine path: weighted popcount
        planes = np.stack([((patch >> n) & 1) for n in range(8)], axis=-2)
        wcnt = np.zeros(o, np.int64)
        for n in range(8):
            for oo in range(o):
                agree = (planes[..., n, :] == (w[..., oo] > 0))
                wcnt[oo] += (1 << n) * int((~agree).sum())
        got = layer_integration.apply_threshold(
            jnp.asarray(wcnt, jnp.int32), p)
        np.testing.assert_array_equal(np.asarray(got), bit_ref)


class TestBitplanes:
    def test_split_recombine_identity(self):
        rng = np.random.default_rng(1)
        x = rng.integers(0, 256, size=(2, 4, 4, 3)).astype(np.uint8)
        planes = bitplanes.split_bitplanes(jnp.asarray(x))
        assert planes.shape == (2, 4, 4, 8, 3)
        v = bitplanes.recombine_planes(planes, axis=-2)
        np.testing.assert_array_equal(np.asarray(v), x.astype(np.int32))

    def test_pack_bitplanes_shape(self):
        x = jnp.zeros((2, 4, 4, 3), jnp.uint8)
        p = bitplanes.pack_bitplanes(x)
        assert p.shape == (2, 4, 4, 8, 1)

    def test_plane_word_weights(self):
        ww = bitplanes.plane_word_weights(2)
        np.testing.assert_array_equal(
            np.asarray(ww), [1, 1, 2, 2, 4, 4, 8, 8, 16, 16, 32, 32, 64, 64,
                             128, 128])
