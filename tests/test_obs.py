"""Observability-layer tests (DESIGN.md §10).

The two contracts everything else hangs off:

* **disabled == free**: with no tracer installed, every instrumentation
  site is one global read returning a shared no-op — no allocation, no
  retrace, no measurable serve-path cost;
* **enabled == harmless**: spans are host-side only, so served results
  stay bit-exact and ``engine.trace_count`` stays flat while a traced
  burst flows.

Plus the canonical percentile math (pinned values — the one
implementation the servers, benchmarks, and summaries all share), the
registry primitives, the flight recorder ring, trace export/validation,
and benchmark provenance stamping.
"""

import json

import jax
import numpy as np
import pytest

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.obs import flight, metrics, provenance, trace
from repro.serving import InferenceServer, PhoneBitEngine


@pytest.fixture(scope="module")
def tiny_engine():
    spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
            Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    return PhoneBitEngine.from_trained(params, spec, (16, 16))


def _images(n, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(n)]


@pytest.fixture
def tracer():
    """Install a fresh tracer for one test; always uninstall after."""
    t = trace.install()
    yield t
    trace.uninstall()


# --------------------------------------------------------------------------
# Canonical percentile math
# --------------------------------------------------------------------------

class TestPercentile:
    def test_nearest_rank_pinned(self):
        vals = list(range(1, 21))                    # 1..20, sorted
        assert metrics.percentile(vals, 0.50) == 10
        assert metrics.percentile(vals, 0.95) == 19
        assert metrics.percentile(vals, 0.0) == 1
        assert metrics.percentile(vals, 1.0) == 20

    def test_empty_and_singleton(self):
        assert metrics.percentile([], 0.5) is None
        assert metrics.percentile([7.0], 0.5) == 7.0
        assert metrics.percentile([7.0], 0.95) == 7.0

    def test_summarize(self):
        s = metrics.summarize(range(1, 21))
        assert s == {"count": 20, "min": 1, "max": 20, "mean": 10.5,
                     "p50": 10, "p95": 19}
        assert metrics.summarize([])["p50"] is None

    def test_servers_use_canonical_math(self):
        """ServingMetrics percentiles == the canonical function (the
        dedupe satellite: no second latency-math implementation)."""
        sm = metrics.ServingMetrics(clock=lambda: 0.0)
        lats = [i / 1000 for i in range(1, 21)]
        sm.mark_dispatch()
        sm.record(lats)
        snap = sm.snapshot(dropped=0, queue_depth=0)
        assert snap["p50_ms"] == metrics.percentile(sorted(lats), .5) * 1e3
        assert snap["p95_ms"] == metrics.percentile(sorted(lats), .95) * 1e3


# --------------------------------------------------------------------------
# Registry primitives
# --------------------------------------------------------------------------

class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = metrics.MetricsRegistry()
        reg.counter("a").inc()
        reg.counter("a").inc(2)
        reg.gauge("g").set(42)
        reg.histogram("h").observe_many([1.0, 2.0, 3.0])
        snap = reg.snapshot()
        assert snap["a"] == 3 and snap["g"] == 42
        assert snap["h"]["count"] == 3 and snap["h"]["p50"] == 2.0

    def test_type_conflict_raises(self):
        reg = metrics.MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x")

    def test_events_bounded_and_filtered(self):
        reg = metrics.MetricsRegistry(max_events=3)
        for i in range(5):
            reg.event("tick", i=i)
        reg.event("other")
        assert len(reg.events()) == 3                # ring bounded
        assert [e["i"] for e in reg.events("tick")] == [3, 4]

    def test_use_registry_isolates(self):
        outer = metrics.get_registry()
        with metrics.use_registry() as reg:
            assert metrics.get_registry() is reg
            metrics.get_registry().counter("only.here").inc()
        assert metrics.get_registry() is outer
        assert "only.here" not in outer.snapshot()

    def test_reset(self):
        reg = metrics.MetricsRegistry()
        reg.counter("c").inc()
        reg.event("e")
        reg.reset()
        assert reg.snapshot() == {} and reg.events() == []


# --------------------------------------------------------------------------
# Flight recorder
# --------------------------------------------------------------------------

class TestFlightRecorder:
    def test_ring_bounds_and_order(self):
        fr = flight.FlightRecorder(capacity=3)
        for i in range(5):
            fr.record(i=i)
        assert len(fr) == 3
        assert [r["i"] for r in fr.dump()] == [2, 3, 4]  # oldest→newest
        assert fr.last(2)[-1]["i"] == 4

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            flight.FlightRecorder(capacity=0)

    def test_clear(self):
        fr = flight.FlightRecorder(capacity=4)
        fr.record(a=1)
        fr.clear()
        assert len(fr) == 0 and fr.dump() == []


# --------------------------------------------------------------------------
# Tracer + Chrome export
# --------------------------------------------------------------------------

class TestTracer:
    def test_disabled_returns_shared_null_span(self):
        assert trace.get_tracer() is None
        assert trace.span("anything", "serve", k=1) is trace.NULL_SPAN
        trace.instant("nothing")                     # no-op, no error
        with trace.span("scope") as s:
            assert s.set(x=1) is s                   # chainable no-op

    def test_spans_nest_and_export(self, tracer, tmp_path):
        with trace.span("outer", "test", a=1):
            with trace.span("inner", "test"):
                pass
        trace.instant("mark", "test", b=2)
        doc = tracer.export(tmp_path / "t.json")
        complete = trace.validate_trace(doc)
        assert [e["name"] for e in complete] == ["outer", "inner"]
        outer, inner = complete
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] \
            + 1e-6
        on_disk = json.loads((tmp_path / "t.json").read_text())
        assert {e["name"] for e in on_disk["traceEvents"]} == \
            {"outer", "inner", "mark"}
        assert on_disk["metadata"]["schema"] == provenance.META_SCHEMA

    def test_span_set_attrs(self, tracer):
        with trace.span("s", "test") as sp:
            sp.set(shape=[1, 2])
        (ev,) = tracer.spans("s")
        assert ev["args"]["shape"] == [1, 2]

    def test_event_cap_counts_drops(self):
        t = trace.Tracer(max_events=2)
        for i in range(4):
            t.instant(f"e{i}")
        assert len(t.events) == 2 and t.dropped_events == 2

    def test_validate_rejects_partial_overlap(self):
        bad = [{"ph": "X", "name": "a", "ts": 0.0, "dur": 10.0,
                "pid": 0, "tid": 0},
               {"ph": "X", "name": "b", "ts": 5.0, "dur": 10.0,
                "pid": 0, "tid": 0}]
        with pytest.raises(ValueError, match="overlaps"):
            trace.validate_trace(bad)
        with pytest.raises(ValueError, match="name"):
            trace.validate_trace([{"ph": "X", "ts": 0, "dur": 1}])
        with pytest.raises(ValueError, match="dur"):
            trace.validate_trace([{"ph": "X", "name": "x", "ts": 0}])

    def test_uninstall_restores_fast_path(self):
        trace.install()
        try:
            assert trace.span("x") is not trace.NULL_SPAN
        finally:
            trace.uninstall()
        assert trace.span("x") is trace.NULL_SPAN


# --------------------------------------------------------------------------
# Provenance
# --------------------------------------------------------------------------

class TestProvenance:
    def test_meta_fields(self):
        m = provenance.provenance_meta()
        for k in ("schema", "git_sha", "jax", "jaxlib", "backend",
                  "device_kind", "n_devices", "backends", "timestamp"):
            assert k in m, k
        assert m["schema"] == provenance.META_SCHEMA
        assert m["jax"] == jax.__version__
        assert m["n_devices"] == len(jax.devices())
        assert "xla" in m["backends"]

    def test_write_bench_stamps(self, tmp_path):
        out = tmp_path / "BENCH_x.json"
        ret = provenance.write_bench(out, {"rows": [1, 2]})
        doc = json.loads(out.read_text())
        assert doc["rows"] == [1, 2]
        assert doc["meta"]["schema"] == provenance.META_SCHEMA
        assert ret["meta"] == doc["meta"]
        assert out.read_text().endswith("\n")


# --------------------------------------------------------------------------
# Serve-path integration: zero overhead off, harmless on
# --------------------------------------------------------------------------

class TestServeTracing:
    def test_disabled_serving_never_touches_tracer(self, tiny_engine):
        """Tracing off: the serve path sees NULL_SPAN only and the
        retrace contract holds exactly as before the obs layer."""
        assert trace.get_tracer() is None
        server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                 max_batch=4)
        server.compile_buckets()
        before = tiny_engine.trace_count
        for img in _images(6):
            server.submit(img)
        server.drain()
        assert tiny_engine.trace_count == before
        assert server.metrics()["served"] == 6

    def test_traced_serving_bit_exact_and_no_retrace(self, tiny_engine,
                                                     tracer):
        """Tracing on: serve spans appear, results stay bit-exact vs the
        flat-path oracle, and trace_count stays flat — enabling
        observability is invisible to the compiled path."""
        server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                 max_batch=4)
        server.compile_buckets()
        before = tiny_engine.trace_count
        imgs = _images(4)
        reqs = [server.submit(img) for img in imgs]
        server.drain()
        assert tiny_engine.trace_count == before     # flat under tracing
        ref = tiny_engine.cross_check(np.stack(imgs))
        for r, row in zip(reqs, np.asarray(ref)):
            np.testing.assert_array_equal(np.asarray(r.result), row)
        names = {e["name"] for e in tracer.events}
        assert {"serve.submit", "serve.assemble", "serve.stage",
                "serve.dispatch", "serve.device",
                "serve.scatter"} <= names
        trace.validate_trace(tracer.events)

    def test_flight_recorder_sees_served_and_shed(self, tiny_engine):
        t = {"now": 0.0}
        server = InferenceServer(tiny_engine, buckets=(1, 2),
                                 max_batch=2, clock=lambda: t["now"])
        server.compile_buckets()
        server.submit(_images(1)[0], deadline_s=1.0)   # will expire
        ok = server.submit(_images(1)[0])
        t["now"] = 2.0
        server.drain()
        assert ok.done
        outcomes = [r["outcome"] for r in server.flight.dump()]
        assert sorted(outcomes) == ["served", "shed"]
        shed = next(r for r in server.flight.dump()
                    if r["outcome"] == "shed")
        assert shed["deadline_s"] == 1.0 and shed["done_s"] == 2.0
        served = next(r for r in server.flight.dump()
                      if r["outcome"] == "served")
        assert served["latency_s"] == pytest.approx(2.0)
        assert served["queue_s"] <= served["latency_s"]

    def test_enabled_overhead_under_two_percent(self, tiny_engine,
                                                tracer):
        """The <2% budget (ISSUE acceptance): measured per-span cost ×
        spans-per-request must sit well inside the measured p50 request
        latency.  Span cost is a min-over-reps estimate (noise only ever
        adds time)."""
        server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                 max_batch=4)
        server.compile_buckets()
        n_before = len(tracer.events)
        reqs = _images(8)
        for img in reqs:
            server.submit(img)
        server.drain()
        p50_s = server.metrics()["p50_ms"] / 1e3
        spans_per_req = (len(tracer.events) - n_before) / len(reqs)
        cost = min(_timed_spans(100) for _ in range(5))
        assert cost * spans_per_req < 0.02 * p50_s, (
            f"span cost {cost * 1e6:.2f}us x {spans_per_req:.1f} "
            f"spans/req vs p50 {p50_s * 1e3:.2f}ms")


def _timed_spans(n):
    """Mean seconds per open/close span cycle over ``n`` spans."""
    import time
    t0 = time.perf_counter()
    for _ in range(n):
        with trace.span("overhead.probe", "test"):
            pass
    return (time.perf_counter() - t0) / n


# --------------------------------------------------------------------------
# Per-node executor spans (traced_call)
# --------------------------------------------------------------------------

class TestTracedCall:
    def test_traced_call_bit_exact_no_retrace(self, tiny_engine, tracer):
        exe = tiny_engine.compile(2)
        x = np.stack(_images(2))
        ref = np.asarray(exe(x))
        before = tiny_engine.trace_count
        got = exe.traced_call(x)
        np.testing.assert_array_equal(np.asarray(got), ref)
        assert tiny_engine.trace_count == before     # own jit cache
        node_spans = tracer.spans("node.")
        assert len(node_spans) >= 3                  # conv_pool/dense/...
        assert all("dur" in e and e["dur"] >= 0 for e in node_spans)
        (walk,) = tracer.spans("executor.traced_call")
        assert walk["args"]["nodes"] >= len(node_spans)

    def test_traced_call_region_spans(self, tracer):
        """A vpu_chain executor reports fused regions as region.* spans
        and still matches the fused __call__ bit for bit."""
        spec = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
                BConv(16, 16, kernel=3, stride=1, pad=1),
                Pool(2, 2), FloatDense(8 * 8 * 16, 4)]
        params = bnn_model.init_params(jax.random.key(1), spec)
        eng = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                          matmul_mode="vpu_chain")
        exe = eng.compile(1)
        x = np.stack(_images(1))
        got = exe.traced_call(x)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(exe(x)))
        regions = tracer.spans("region.")
        assert len(regions) == len(exe.regions) >= 1
        assert all(e["args"]["op"] == "chain" for e in regions)

    def test_fused_call_whole_span_when_enabled(self, tiny_engine,
                                                tracer):
        exe = tiny_engine.compile(1)
        exe(np.stack(_images(1)))
        (ev,) = tracer.spans("executor.call")
        assert ev["args"]["nodes"] > 0


# --------------------------------------------------------------------------
# Runtime-wide metrics series
# --------------------------------------------------------------------------

class TestRuntimeSeries:
    def test_retrace_counter_and_arena_gauge(self):
        spec = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
                Pool(2, 2), FloatDense(8 * 8 * 16, 4)]
        params = bnn_model.init_params(jax.random.key(2), spec)
        with metrics.use_registry() as reg:
            eng = PhoneBitEngine.from_trained(params, spec, (16, 16))
            x = np.stack(_images(2))
            jax.block_until_ready(eng(x))
            jax.block_until_ready(eng(x))            # cached: no retrace
            assert reg.counter("runtime.retraces").value == 1
            assert reg.gauge("runtime.arena_peak_bytes").value > 0

    def test_autotune_events(self, tmp_path, monkeypatch):
        """The structured autotune audit trail: fresh sweeps emit miss
        events with a sweep size, a second engine over the same graph
        hits in memory."""
        from repro.runtime.autotune import Autotuner

        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                           str(tmp_path / "cache.json"))
        spec = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
                Pool(2, 2), FloatDense(8 * 8 * 16, 4)]
        params = bnn_model.init_params(jax.random.key(3), spec)
        eng = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                          matmul_mode="auto")
        with metrics.use_registry() as reg:
            t1 = Autotuner(warmup=0, iters=1)
            t1.tune(eng._graph, eng._plan_shape(1))
            misses = reg.events("autotune")
            assert misses and all(e["outcome"] == "miss" for e in misses)
            assert all(e["sweep_size"] >= 1 for e in misses)
            assert reg.counter("autotune.miss").value == len(misses)
            # same tuner, same graph → pure in-memory hits
            t1.tune(eng._graph, eng._plan_shape(1))
            assert reg.counter("autotune.hit").value == len(misses)
            # new tuner, same disk cache → disk warm-start
            t2 = Autotuner(warmup=0, iters=1)
            t2.tune(eng._graph, eng._plan_shape(1))
            assert reg.counter("autotune.disk_hit").value == len(misses)


# --------------------------------------------------------------------------
# LM / BNN metrics parity
# --------------------------------------------------------------------------

def test_lm_metrics_parity_with_inference_server(tiny_engine):
    """Both servers emit the same core metrics vocabulary with the same
    semantics (the §7 protocol contract, now enforced through the one
    shared ServingMetrics)."""
    from repro.distributed.sharding import rules_for_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer
    from repro.serving.lm_server import LMServer

    server = InferenceServer(tiny_engine, buckets=(1, 2), max_batch=2)
    for img in _images(3):
        server.submit(img)
    server.drain()
    bnn_m = server.metrics()

    cfg = transformer.LMConfig(
        name="parity-demo", n_layers=1, d_model=64, n_heads=2,
        n_kv_heads=1, d_head=32, d_ff=128, vocab=128,
        tie_embeddings=True)
    mesh = make_host_mesh(data=1, model=1)
    with mesh:
        params = transformer.init_params(jax.random.key(0), cfg, ep=1)
        lm = LMServer(cfg=cfg, rules=rules_for_mesh(mesh), params=params,
                      n_slots=2, max_seq=32)
        rng = np.random.default_rng(0)
        for _ in range(3):
            lm.submit(list(rng.integers(1, cfg.vocab, 4)), max_new=2)
        lm.drain()
        lm_m = lm.metrics()

    core = {"served", "dropped", "queue_depth", "p50_ms", "p95_ms",
            "throughput"}
    assert core <= set(bnn_m) and core <= set(lm_m)
    for m in (bnn_m, lm_m):
        assert m["served"] == 3 and m["dropped"] == 0
        assert m["queue_depth"] == 0
        assert m["p50_ms"] is not None and m["p50_ms"] <= m["p95_ms"]
        assert m["throughput"] is None or m["throughput"] > 0
    # the registries behind both expose the same series names
    assert set(server.metrics_registry.snapshot()) == \
        set(lm.metrics_registry.snapshot())
