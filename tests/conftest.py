"""Test bootstrap: src/ on sys.path + a hypothesis fallback.

Keeps `python -m pytest` working from the repo root even without an
installed package (pyproject's `pythonpath = ["src"]` does the same for
pytest >= 7; this also covers direct module imports).  When the real
``hypothesis`` package is unavailable in the environment, installs the
deterministic stub from ``tests/_hypothesis_stub.py`` so the
property-based modules still collect and run.
"""

import os
import pathlib
import sys
import tempfile

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Keep the autotuner's disk cache hermetic: never read/write the real
# ~/.cache/repro/autotune.json from the test suite (individual tests
# override this per-case via monkeypatch).
os.environ.setdefault(
    "REPRO_AUTOTUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-autotune-"),
                 "autotune.json"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="rewrite tests/golden/ fixtures from today's outputs "
             "(workload conformance harness) instead of comparing")


import pytest  # noqa: E402


@pytest.fixture
def regen_golden(request) -> bool:
    """True when the run should regenerate golden fixtures in place."""
    return request.config.getoption("--regen-golden")
