"""Unit test for the shared benchmark timers (benchmarks/timing.py)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks import timing  # noqa: E402


class _FakeClock:
    """Deterministic perf_counter: consecutive calls return the given
    instants, so each timed iteration sees a scripted duration."""

    def __init__(self, instants):
        self.instants = list(instants)

    def __call__(self):
        return self.instants.pop(0)


def test_time_stable_min_budget_and_cap(monkeypatch):
    # Three scripted iterations of 5s, 3s, 7s.  With a 10s budget the
    # loop runs while spent < budget: 5 (spent 5), 3 (spent 8), 7 (spent
    # 15, loop exits) — and returns the MINIMUM, not mean/median.
    monkeypatch.setattr(timing.time, "perf_counter",
                        _FakeClock([0, 5, 5, 8, 8, 15]))
    assert timing.time_stable(lambda: 0, budget_s=10, warmup=0) == 3

    # max_iters caps the repeat count even with budget left.
    monkeypatch.setattr(timing.time, "perf_counter",
                        _FakeClock([0, 2, 2, 3]))
    assert timing.time_stable(lambda: 0, budget_s=100, max_iters=2,
                              warmup=0) == 1

    # time_fn is the median estimator: durations 5, 1, 9 -> 5.
    monkeypatch.setattr(timing.time, "perf_counter",
                        _FakeClock([0, 5, 5, 6, 6, 15]))
    assert timing.time_fn(lambda: 0, warmup=0, iters=3) == 5

    # common.py re-exports both (back-compat import surface)
    from benchmarks import common
    assert common.time_fn is timing.time_fn
    assert common.time_stable is timing.time_stable
