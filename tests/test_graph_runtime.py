"""Graph runtime: IR lowering, passes, memory planner, executor, autotune,
engine integration, and artifact→graph round-trips (DESIGN.md §4)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bnn_model, converter, packing
from repro.core.bnn_model import BConv, BDense, FloatConv, FloatDense, Pool
from repro import runtime
from repro.runtime import (Autotuner, GraphExecutor, Graph, assign_layouts,
                           absorb_pools, default_pipeline, fuse_epilogues,
                           infer_types, integrate_bn, lower_packed,
                           lower_trained, plan_memory)
from repro.serving import PhoneBitEngine


def tiny_net():
    return [
        BConv(c_in=3, c_out=16, kernel=3, stride=1, pad=1, first=True),
        Pool(window=2, stride=2),
        BConv(c_in=16, c_out=40, kernel=3, stride=1, pad=1),
        Pool(window=2, stride=2),
        BDense(d_in=4 * 4 * 40, d_out=64),
        FloatDense(d_in=64, d_out=10),
    ]


def conv_net():
    """≥6-layer all-conv net with a stride-1 padded pool (YOLO-style) and a
    float-conv head — exercises pool padding and the unpack→conv tail."""
    return [
        BConv(c_in=3, c_out=16, kernel=3, stride=1, pad=1, first=True),
        Pool(window=2, stride=2),
        BConv(c_in=16, c_out=32, kernel=3, stride=1, pad=1),
        BConv(c_in=32, c_out=32, kernel=3, stride=1, pad=1),
        Pool(window=2, stride=1, pad=(0, 1)),
        BConv(c_in=32, c_out=48, kernel=3, stride=1, pad=1),
        FloatConv(c_in=48, c_out=8, kernel=1, stride=1, pad=0),
    ]


def _randomize_bn(params, seed=42):
    rng = np.random.default_rng(seed)
    for p in params:
        if "mu" in p:
            o = p["mu"].shape[0]
            p["mu"] = jnp.asarray(rng.uniform(-20, 20, o), jnp.float32)
            p["var"] = jnp.asarray(rng.uniform(0.5, 4, o), jnp.float32)
            p["gamma"] = jnp.asarray(rng.uniform(-1.5, 1.5, o), jnp.float32)
            p["beta"] = jnp.asarray(rng.uniform(-1, 1, o), jnp.float32)
    return params


@pytest.fixture(scope="module")
def tiny():
    spec = tiny_net()
    params = _randomize_bn(bnn_model.init_params(jax.random.key(0), spec))
    packed = converter.convert(params, spec, (16, 16))
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, 256, (3, 16, 16, 3)), jnp.uint8)
    return spec, params, packed, x


@pytest.fixture(scope="module")
def convy():
    spec = conv_net()
    params = _randomize_bn(bnn_model.init_params(jax.random.key(1), spec),
                           seed=5)
    packed = converter.convert(params, spec, (16, 16))
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.integers(0, 256, (2, 16, 16, 3)), jnp.uint8)
    return spec, params, packed, x


# --------------------------------------------------------------------------
# IR + lowering
# --------------------------------------------------------------------------

class TestGraphIR:

    def test_lower_packed_structure(self, tiny):
        spec, _, packed, _ = tiny
        g = lower_packed(spec, packed, (16, 16))
        ops = [g.nodes[i].op for i in g.topo_order()]
        assert ops == ["input", "bitplane_expand", "packed_conv", "or_pool",
                       "packed_conv", "or_pool", "packed_dense",
                       "unpack_pm1", "float_dense"]

    def test_topo_order_is_deterministic_and_valid(self, tiny):
        spec, _, packed, _ = tiny
        g = lower_packed(spec, packed, (16, 16))
        order = g.topo_order()
        assert order == g.topo_order()
        pos = {nid: i for i, nid in enumerate(order)}
        for node in g.nodes.values():
            for src in node.inputs:
                assert pos[src] < pos[node.id]

    def test_cycle_detection(self):
        g = Graph()
        a = g.add("input", attrs=dict(channels=3))
        b = g.add("or_pool", [a], attrs=dict(window=2, stride=2,
                                             channels=3))
        g.nodes[a].inputs = (b,)  # manufacture a cycle
        g.input_id, g.output_id = a, b
        with pytest.raises(ValueError):
            g.topo_order()

    def test_infer_types_matches_execution(self, tiny):
        spec, _, packed, x = tiny
        g = lower_packed(spec, packed, (16, 16))
        types = infer_types(g, x.shape)
        ex = GraphExecutor(g, "xla")
        # run an unjitted pass collecting actual shapes
        env = {}
        for nid in g.topo_order():
            node = g.nodes[nid]
            if node.op == "input":
                env[nid] = x
            else:
                from repro.runtime.executor import eval_node
                env[nid] = eval_node(node.op, node.attrs, node.params,
                                     [env[i] for i in node.inputs])
            assert tuple(env[nid].shape) == types[nid].shape, node.op
            assert env[nid].dtype == types[nid].dtype, node.op


# --------------------------------------------------------------------------
# Executor: bit-exactness across backends, flat path, float oracle
# --------------------------------------------------------------------------

class TestExecutor:

    @pytest.mark.parametrize("backend", ["xla", "xla_pm1", "mxu_pm1"])
    def test_fused_graph_matches_flat_path(self, tiny, backend):
        spec, _, packed, x = tiny
        g = lower_packed(spec, packed, (16, 16))
        got = GraphExecutor(g, backend)(x)
        ref = bnn_model.packed_forward(packed, spec, x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_fused_graph_matches_flat_path_pallas(self, tiny):
        spec, _, packed, x = tiny
        g = lower_packed(spec, packed, (16, 16))
        got = GraphExecutor(g, "vpu_popcount")(x[:1])
        ref = bnn_model.packed_forward(packed, spec, x[:1])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_graph_matches_float_oracle(self, tiny):
        spec, params, packed, x = tiny
        g = lower_packed(spec, packed, (16, 16))
        got = GraphExecutor(g, "xla")(x)
        ref = bnn_model.float_forward(params, spec, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=0, atol=1e-3)

    def test_conv_head_net_all_backends(self, convy):
        spec, _, packed, x = convy
        g = lower_packed(spec, packed, (16, 16))
        ref = bnn_model.packed_forward(packed, spec, x)
        for backend in ("xla", "xla_pm1"):
            got = GraphExecutor(g, backend)(x)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_no_retrace_on_repeat_calls(self, tiny):
        spec, _, packed, x = tiny
        ex = GraphExecutor(lower_packed(spec, packed, (16, 16)), "xla")
        ex(x)
        assert ex.trace_count == 1
        ex(x)
        ex(x)
        assert ex.trace_count == 1

    def test_branching_graph_concat(self):
        """Two parallel conv branches concat'd — inexpressible as a flat
        LayerSpec list; cross-checked against manual composition."""
        rng = np.random.default_rng(3)
        spec1 = [BConv(3, 32, 3, 1, 1, first=True)]
        spec2 = [BConv(3, 64, 3, 1, 1, first=True)]
        p1 = _randomize_bn(bnn_model.init_params(jax.random.key(2), spec1))
        p2 = _randomize_bn(bnn_model.init_params(jax.random.key(3), spec2))
        pk1 = converter.convert(p1, spec1, (8, 8))
        pk2 = converter.convert(p2, spec2, (8, 8))
        x = jnp.asarray(rng.integers(0, 256, (2, 8, 8, 3)), jnp.uint8)

        g = Graph(input_hw=(8, 8))
        inp = g.add("input", attrs=dict(channels=3))
        g.input_id = inp
        bp = g.add("bitplane_expand", [inp], attrs=dict(c_in=3, channels=3))
        conv_attrs = dict(kernel=3, stride=1, pad=1, first=True)
        b1 = g.add("packed_conv", [bp],
                   attrs=dict(channels=32, **conv_attrs),
                   params=dict(w_packed=pk1[0]["w_packed"],
                               thresh=pk1[0]["thresh"],
                               word_weights=pk1[0]["word_weights"]))
        b2 = g.add("packed_conv", [bp],
                   attrs=dict(channels=64, **conv_attrs),
                   params=dict(w_packed=pk2[0]["w_packed"],
                               thresh=pk2[0]["thresh"],
                               word_weights=pk2[0]["word_weights"]))
        cat = g.add("concat_packed", [b1, b2], attrs=dict(channels=96))
        g.output_id = cat
        got = GraphExecutor(g, "xla")(x)

        r1 = bnn_model.packed_forward(pk1, spec1, x)
        r2 = bnn_model.packed_forward(pk2, spec2, x)
        ref = jnp.concatenate([r1, r2], axis=-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# --------------------------------------------------------------------------
# Passes
# --------------------------------------------------------------------------

class TestPasses:

    def test_layout_pass_inserts_adapters(self, tiny):
        spec, params, _, _ = tiny
        g = lower_trained(spec, params, (16, 16))
        ops_before = {n.op for n in g.nodes.values()}
        assert "bitplane_expand" not in ops_before
        assert "unpack_pm1" not in ops_before
        g2 = assign_layouts(g)
        ops_after = [g2.nodes[i].op for i in g2.topo_order()]
        assert "bitplane_expand" in ops_after
        assert "unpack_pm1" in ops_after
        # adapters are wired, not appended: expand feeds the first conv
        for node in g2.nodes.values():
            if node.op == "conv_counts" and node.attrs["first"]:
                assert g2.nodes[node.inputs[0]].op == "bitplane_expand"

    def test_unfused_graph_matches_float_oracle(self, tiny):
        spec, params, _, x = tiny
        g = assign_layouts(lower_trained(spec, params, (16, 16)))
        got = GraphExecutor(g, "xla")(x)
        ref = bnn_model.float_forward(params, spec, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=0, atol=1e-3)

    def test_integrate_bn_is_exact(self, tiny):
        spec, params, _, x = tiny
        g = assign_layouts(lower_trained(spec, params, (16, 16)))
        gi = integrate_bn(g)
        assert all(n.op != "bn_binarize" for n in gi.nodes.values())
        np.testing.assert_array_equal(
            np.asarray(GraphExecutor(g, "xla")(x)),
            np.asarray(GraphExecutor(gi, "xla")(x)))

    def test_fuse_epilogues(self, tiny):
        spec, params, _, x = tiny
        g = integrate_bn(assign_layouts(lower_trained(spec, params,
                                                      (16, 16))))
        gf = fuse_epilogues(g)
        ops = {n.op for n in gf.nodes.values()}
        assert "conv_counts" not in ops and "threshold_pack" not in ops
        assert "packed_conv" in ops and "packed_dense" in ops
        np.testing.assert_array_equal(
            np.asarray(GraphExecutor(g, "xla")(x)),
            np.asarray(GraphExecutor(gf, "xla")(x)))

    def test_absorb_pools(self, tiny):
        spec, params, _, x = tiny
        g = fuse_epilogues(integrate_bn(assign_layouts(
            lower_trained(spec, params, (16, 16)))))
        ga = absorb_pools(g)
        assert all(n.op != "maxpool_pm1" for n in ga.nodes.values())
        assert any(n.op == "or_pool" for n in ga.nodes.values())
        np.testing.assert_array_equal(
            np.asarray(GraphExecutor(g, "xla")(x)),
            np.asarray(GraphExecutor(ga, "xla")(x)))

    def test_pipeline_converges_to_artifact_lowering(self, tiny):
        """lower_trained + passes == lower_packed(converter.convert(...))."""
        spec, params, packed, x = tiny
        g_pass = default_pipeline(lower_trained(spec, params, (16, 16)))
        g_art = lower_packed(spec, packed, (16, 16))
        assert ([g_pass.nodes[i].op for i in g_pass.topo_order()] ==
                [g_art.nodes[i].op for i in g_art.topo_order()])
        np.testing.assert_array_equal(
            np.asarray(GraphExecutor(g_pass, "xla")(x)),
            np.asarray(GraphExecutor(g_art, "xla")(x)))

    def test_pipeline_on_conv_head_net(self, convy):
        spec, params, packed, x = convy
        g_pass = default_pipeline(lower_trained(spec, params, (16, 16)))
        ref = bnn_model.packed_forward(packed, spec, x)
        np.testing.assert_array_equal(
            np.asarray(GraphExecutor(g_pass, "xla")(x)), np.asarray(ref))


# --------------------------------------------------------------------------
# Memory planner
# --------------------------------------------------------------------------

class TestMemoryPlanner:

    def test_reuse_beats_naive_on_deep_net(self, convy):
        spec, _, packed, x = convy
        g = lower_packed(spec, packed, (16, 16))
        assert len([l for l in spec if isinstance(l, (BConv, FloatConv))]) >= 5
        plan = plan_memory(g, x.shape)
        assert plan.peak_bytes() < plan.naive_bytes()
        assert plan.peak_bytes() >= plan.live_peak_bytes() > 0

    def test_no_overlap_for_live_buffers(self, convy):
        spec, _, packed, x = convy
        g = lower_packed(spec, packed, (16, 16))
        plan = plan_memory(g, x.shape)
        bufs = list(plan.buffers.values())
        for i, a in enumerate(bufs):
            for b in bufs[i + 1:]:
                lifetimes_overlap = not (a.death < b.birth or
                                         b.death < a.birth)
                space_overlap = not (a.offset + a.nbytes <= b.offset or
                                     b.offset + b.nbytes <= a.offset)
                assert not (lifetimes_overlap and space_overlap), (a, b)

    def test_arena_bounded_by_two_largest(self, tiny):
        """For a pure chain, peak is at most the two largest adjacent
        buffers (producer + consumer live simultaneously)."""
        spec, _, packed, x = tiny
        g = lower_packed(spec, packed, (16, 16))
        plan = plan_memory(g, x.shape)
        sizes = sorted((b.nbytes for b in plan.buffers.values()),
                       reverse=True)
        assert plan.peak_bytes() <= sizes[0] + sizes[1]

    def test_report_rows(self, tiny):
        spec, _, packed, x = tiny
        plan = plan_memory(lower_packed(spec, packed, (16, 16)), x.shape)
        rows = plan.report()
        assert rows and all(
            {"node", "op", "bytes", "offset", "birth", "death"} <= set(r)
            for r in rows)


# --------------------------------------------------------------------------
# Autotune
# --------------------------------------------------------------------------

class TestAutotune:

    def test_selects_caches_and_stays_exact(self, tiny):
        spec, _, packed, x = tiny
        g = lower_packed(spec, packed, (16, 16))
        cache = {}
        tuner = Autotuner(cache=cache, candidates=("xla", "xla_pm1"),
                          warmup=1, iters=1)
        choices = tuner.tune(g, x.shape)
        assert choices and all(b in ("xla", "xla_pm1")
                               for b in choices.values())
        assert len(cache) == len(choices)
        # second tune hits the cache (no new entries, same winners)
        assert tuner.tune(g, x.shape) == choices
        assert len(cache) == len(choices)
        ex = GraphExecutor(g, choices)
        ref = bnn_model.packed_forward(packed, spec, x)
        np.testing.assert_array_equal(np.asarray(ex(x)), np.asarray(ref))

    def test_no_recompile_at_serve_time(self, tiny):
        spec, _, packed, x = tiny
        g = lower_packed(spec, packed, (16, 16))
        tuner = Autotuner(candidates=("xla", "xla_pm1"), warmup=1, iters=1)
        ex = tuner.tuned_executor(g, x.shape)
        ex(x)
        n = ex.trace_count
        for _ in range(3):
            ex(x)
        assert ex.trace_count == n == 1


# --------------------------------------------------------------------------
# Engine integration + artifact round-trips (satellites)
# --------------------------------------------------------------------------

class TestEngineGraphPath:

    def test_engine_runs_graph_and_matches_legacy(self, tiny, tmp_path):
        spec, params, _, x = tiny
        for mode in ("xla", "xla_pm1"):
            engine = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                                 matmul_mode=mode)
            engine.cross_check(x)  # asserts graph == flat internally

    def test_engine_prepare_is_explicit_and_order_independent(self, tiny):
        spec, params, _, x = tiny
        e1 = PhoneBitEngine.from_trained(params, spec, (16, 16))
        arrays, meta = e1.prepare()  # before any inference
        assert len(arrays) == len(meta) == len(spec)
        assert all("c_per_pos" not in a for a in arrays)
        assert any("c_per_pos" in m for m in meta)
        out1 = e1(x)
        # calling prepare() after inference gives the same split
        arrays2, meta2 = e1.prepare()
        assert meta2 == meta
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), arrays, arrays2)
        # inference-first engine agrees with prepare-first engine
        e2 = PhoneBitEngine.from_trained(params, spec, (16, 16))
        out2 = e2(x)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))

    def test_engine_memory_plan_and_backends(self, tiny):
        spec, params, _, x = tiny
        engine = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                             batch_size=3)
        plan = engine.memory_plan()
        assert plan.peak_bytes() < plan.naive_bytes()
        assert all(r["backend"] == "xla" for r in engine.backend_choices)

    def test_engine_autotune_mode(self, tiny):
        spec, params, _, x = tiny
        engine = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                             matmul_mode="auto",
                                             batch_size=3)
        engine.cross_check(x)
        assert all(r["backend"] in runtime.BACKENDS
                   for r in engine.backend_choices)

    def test_artifact_graph_roundtrip_all_backends(self, tiny, tmp_path):
        """save_artifact → load_artifact → graph lowering → executor is
        bit-exact vs the legacy flat path and the float oracle."""
        spec, params, packed, x = tiny
        path = str(tmp_path / "m.npz")
        converter.save_artifact(path, packed)
        loaded = converter.load_artifact(path)
        g = converter.to_graph(loaded, spec, (16, 16))
        flat_ref = bnn_model.packed_forward(packed, spec, x)
        float_ref = bnn_model.float_forward(params, spec, x)
        for backend in ("xla", "xla_pm1"):
            got = np.asarray(GraphExecutor(g, backend)(x))
            np.testing.assert_array_equal(got, np.asarray(flat_ref))
            np.testing.assert_allclose(got, np.asarray(float_ref),
                                       rtol=0, atol=1e-3)
        got = np.asarray(GraphExecutor(g, "vpu_popcount")(x[:1]))
        np.testing.assert_array_equal(got, np.asarray(flat_ref)[:1])

    def test_core_to_graph_hooks(self, tiny):
        spec, params, packed, x = tiny
        ga = converter.to_graph(packed, spec, (16, 16))
        gt = default_pipeline(bnn_model.to_graph(params, spec, (16, 16)))
        np.testing.assert_array_equal(
            np.asarray(GraphExecutor(ga, "xla")(x)),
            np.asarray(GraphExecutor(gt, "xla")(x)))


# --------------------------------------------------------------------------
# Differential backend fuzz (workload-conformance satellite)
# --------------------------------------------------------------------------

def _random_spec(rng: np.random.Generator) -> tuple[list, int]:
    """A random small-but-legal network: bit-plane first conv, 1-3 hidden
    packed conv blocks (random kernel/channels, optional pool), then
    either a packed-dense + float-dense tail or a 1x1 float-conv head.
    Returns (spec, input_hw)."""
    hw0 = hw = int(rng.choice([8, 16]))
    c = int(rng.choice([16, 24, 32]))
    spec = [BConv(3, c, kernel=3, stride=1, pad=1, first=True)]
    for _ in range(int(rng.integers(1, 4))):
        kernel = int(rng.choice([1, 3]))
        c_out = int(rng.choice([16, 32, 40]))
        spec.append(BConv(c, c_out, kernel=kernel, stride=1,
                          pad=kernel // 2))
        c = c_out
        if hw >= 8 and rng.random() < 0.5:
            spec.append(Pool(2, 2))
            hw //= 2
    if rng.random() < 0.5:
        spec.append(BDense(hw * hw * c, 32))
        spec.append(FloatDense(32, 10))
    else:
        spec.append(FloatConv(c, 8, kernel=1, stride=1, pad=0))
    return spec, hw0


class TestDifferentialFuzz:
    """Random graph specs executed on every valid backend, asserting
    bit-exactness pairwise (via the shared xla reference — equality is
    transitive).  The Pallas backends run in interpret mode off-TPU, so
    shapes stay small."""

    @given(st.integers(0, 10**9))
    @settings(max_examples=4, deadline=None)
    def test_random_spec_all_backend_pairs(self, seed):
        rng = np.random.default_rng(seed)
        spec, hw0 = _random_spec(rng)
        params = _randomize_bn(
            bnn_model.init_params(jax.random.key(seed % (2**31)), spec),
            seed=seed % 7919)
        packed = converter.convert(params, spec, (hw0, hw0))
        # Pool-fused graph so conv+pool pairs exercise packed_conv_pool
        # (every backend accepts it; string modes degrade where needed).
        g = runtime.fuse_pool_epilogue(lower_packed(spec, packed,
                                                    (hw0, hw0)))
        x = jnp.asarray(rng.integers(0, 256, (2, hw0, hw0, 3)), jnp.uint8)
        ref = np.asarray(GraphExecutor(g, "xla")(x))
        np.testing.assert_array_equal(        # graph == flat oracle
            ref, np.asarray(bnn_model.packed_forward(packed, spec, x)))
        for backend in ("xla_pm1", "mxu_pm1"):
            np.testing.assert_array_equal(
                np.asarray(GraphExecutor(g, backend)(x)), ref,
                err_msg=f"{backend} diverges on spec {spec}")
        # interpret-mode Pallas backends: batch 1 keeps them fast
        for backend in ("vpu_popcount", "vpu_direct", "vpu_direct_pool"):
            got = np.asarray(GraphExecutor(g, backend)(x[:1]))
            np.testing.assert_array_equal(
                got, ref[:1], err_msg=f"{backend} diverges on spec {spec}")

    @given(st.integers(0, 10**9))
    @settings(max_examples=4, deadline=None)
    def test_random_chain_splits_bit_exact(self, seed):
        """Chain-fusion axis (DESIGN.md §9): the same random graphs, but
        executed through megakernel regions split at *random* chain
        boundaries — every split must stay bit-exact vs the per-node xla
        reference (each cut boundary spills to HBM; the fused interiors
        live in the VMEM arena)."""
        rng = np.random.default_rng(seed)
        spec, hw0 = _random_spec(rng)
        params = _randomize_bn(
            bnn_model.init_params(jax.random.key(seed % (2**31)), spec),
            seed=seed % 7919)
        packed = converter.convert(params, spec, (hw0, hw0))
        g = runtime.fuse_pool_epilogue(lower_packed(spec, packed,
                                                    (hw0, hw0)))
        x = jnp.asarray(rng.integers(0, 256, (1, hw0, hw0, 3)), jnp.uint8)
        ref = np.asarray(GraphExecutor(g, "xla")(x))

        split = []
        for chain in runtime.partition_chains(g, x.shape, min_nodes=1):
            ids = chain.node_ids
            cuts = {0, len(ids)}
            if len(ids) > 1:
                cuts.update(int(rng.integers(1, len(ids)))
                            for _ in range(int(rng.integers(0, 3))))
            cuts = sorted(cuts)
            split += [runtime.build_chain(g, ids[a:b], x.shape)
                      for a, b in zip(cuts, cuts[1:])]
        assert split, f"no chainable run in spec {spec}"
        ex = GraphExecutor(g, "vpu_chain", regions=split)
        np.testing.assert_array_equal(
            np.asarray(ex(x)), ref,
            err_msg=f"chain split {[c.node_ids for c in split]} diverges "
                    f"on spec {spec}")

    # ---- forced-mesh placement sweeps (DESIGN.md §13) --------------------
    # Placement is a backend choice like any other: the same random specs
    # the backend-pair fuzz runs must agree when sharded over a mesh axis
    # or cut into pipeline stages.  Multi-device needs
    # --xla_force_host_platform_device_count, which must be set before
    # jax imports and must never leak into this process — so each sweep
    # runs in one subprocess covering several seeds.  Bar: packed int32
    # tails bit-exact, float heads 1e-4.

    _PLACEMENT_SWEEP = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
os.environ["REPRO_AUTOTUNE_CACHE"] = "0"
import sys
sys.path.insert(0, {src!r})
sys.path.insert(0, {tests!r})
try:
    import hypothesis  # noqa: F401  (stub keeps the import below legal)
except ImportError:
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
import jax, jax.numpy as jnp
import numpy as np
from test_graph_runtime import _random_spec, _randomize_bn
from repro.core import bnn_model
from repro.core.bnn_model import BConv, BDense, FloatConv, FloatDense
from repro.serving import PhoneBitEngine

N = {n_dev}
assert len(jax.devices()) == N, jax.devices()
for seed in {seeds}:
    rng = np.random.default_rng(seed)
    spec, hw0 = _random_spec(rng)
    # Two variants per spec: the original float head (1e-4 bar) and a
    # packed-tail derivative (bit-exact bar) — drop a FloatDense tail,
    # swap a FloatConv head for a BDense.
    last = spec[-1]
    if isinstance(last, FloatDense):
        spec_p = spec[:-1]
    else:
        hw_c = last.c_in
        hw_sp = hw0
        for l in spec:
            if type(l).__name__ == "Pool":
                hw_sp //= l.stride
        spec_p = spec[:-1] + [BDense(hw_sp * hw_sp * hw_c, 32)]
    for sp, exact in ((spec, False), (spec_p, True)):
        params = _randomize_bn(
            bnn_model.init_params(jax.random.key(seed % (2**31)), sp),
            seed=seed % 7919)
        engine = PhoneBitEngine.from_trained(params, sp, (hw0, hw0))
        bs = 2 * N
        x = jnp.asarray(rng.integers(0, 256, (bs, hw0, hw0, 3)),
                        jnp.uint8)
        ref = np.asarray(engine.compile(bs)(x))
        # data-parallel: batch dim sharded over the forced mesh
        got_dp = np.asarray(engine.compile(bs, data_parallel=N)(x))
        # pipeline-parallel: schedule cut into per-device stages
        got_pp = np.asarray(engine.compile(
            bs, pipeline=jax.devices())(x))
        # zero-padded bucket traffic (ragged batch padded up)
        pad = np.zeros_like(x)
        pad[: bs // 2] = np.asarray(x[: bs // 2])
        ref_pad = np.asarray(engine.compile(bs)(jnp.asarray(pad)))
        dp_pad = np.asarray(engine.compile(bs, data_parallel=N)(
            jnp.asarray(pad)))
        pp_pad = np.asarray(engine.compile(bs, pipeline=jax.devices())(
            jnp.asarray(pad)))
        for name, got, want in (("dp", got_dp, ref),
                                ("pp", got_pp, ref),
                                ("dp-pad", dp_pad, ref_pad),
                                ("pp-pad", pp_pad, ref_pad)):
            if exact:
                np.testing.assert_array_equal(
                    got, want, err_msg=f"{{name}} seed={{seed}} {{sp}}")
            else:
                np.testing.assert_allclose(
                    got, want, atol=1e-4,
                    err_msg=f"{{name}} seed={{seed}} {{sp}}")
    print("seed", seed, "ok")
print("placement-fuzz-ok")
"""

    @pytest.mark.parametrize("n_dev", [2, 4])
    def test_placement_parity_sweep_forced_mesh(self, n_dev):
        import pathlib
        import subprocess
        import sys as _sys

        tests = pathlib.Path(__file__).resolve().parent
        rng = np.random.default_rng(1000 + n_dev)
        seeds = [int(s) for s in rng.integers(0, 10**9, 3)]
        script = self._PLACEMENT_SWEEP.format(
            n_dev=n_dev, src=str(tests.parent / "src"),
            tests=str(tests), seeds=seeds)
        r = subprocess.run([_sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=560)
        assert r.returncode == 0, \
            f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
        assert "placement-fuzz-ok" in r.stdout
