"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import layers


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2)])
    def test_vs_oracle(self, causal, h, kvh):
        b, s, hd = 2, 128, 32
        ks = jax.random.split(jax.random.key(h + causal), 3)
        q = _rand(ks[0], (b, s, h, hd))
        k = _rand(ks[1], (b, s, kvh, hd))
        v = _rand(ks[2], (b, s, kvh, hd))
        out = flash_attention(q, k, v, causal, 32, 32, True)
        ref = layers.reference_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("block_q,block_k", [(128, 128), (64, 32),
                                                 (32, 64)])
    def test_block_shape_sweep(self, block_q, block_k):
        b, s, h, hd = 1, 128, 2, 16
        ks = jax.random.split(jax.random.key(0), 3)
        q, k, v = (_rand(ks[i], (b, s, h, hd)) for i in range(3))
        out = flash_attention(q, k, v, True, block_q, block_k, True)
        ref = layers.reference_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_dtype_bf16(self):
        b, s, h, hd = 1, 64, 2, 16
        ks = jax.random.split(jax.random.key(1), 3)
        q, k, v = (_rand(ks[i], (b, s, h, hd)).astype(jnp.bfloat16)
                   for i in range(3))
        out = flash_attention(q, k, v, True, 32, 32, True)
        ref = layers.reference_attention(q, k, v, causal=True)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=5e-2, atol=5e-2)

    def test_gradients_match_reference(self):
        b, s, h, hd = 1, 64, 2, 16
        ks = jax.random.split(jax.random.key(2), 3)
        q, k, v = (_rand(ks[i], (b, s, h, hd)) for i in range(3))

        def f_kernel(q, k, v):
            return jnp.sum(flash_attention(q, k, v, True, 32, 32, True)
                           ** 2)

        def f_ref(q, k, v):
            return jnp.sum(layers.reference_attention(
                q, k, v, causal=True) ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-3, atol=1e-3)
