"""AOT artifact tests (DESIGN.md §12).

Export/load roundtrip (bit-exact, zero traces after load), the
per-bucket compatibility protocol (every COMPAT field mismatch falls
back to live compile with a structured ``artifact.miss`` event),
integrity failures raising a clean :class:`ArtifactError` instead of an
XLA abort, the autotune winner table riding along, and the end-to-end
pin: a **fresh subprocess** boots ``InferenceServer(artifact=...)`` and
serves submit→result with ``trace_count == 0``.
"""

import hashlib
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.obs import metrics as obs_metrics
from repro.serving import (ArtifactError, InferenceServer, PhoneBitEngine,
                           export_artifact, load_artifact, read_meta)

REPO = pathlib.Path(__file__).resolve().parent.parent

SPEC = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
        Pool(2, 2), FloatDense(8 * 8 * 16, 10)]


def _engine(mode: str = "xla") -> PhoneBitEngine:
    params = bnn_model.init_params(jax.random.key(0), SPEC)
    return PhoneBitEngine.from_trained(params, SPEC, (16, 16),
                                       matmul_mode=mode)


def _imgs(n: int) -> np.ndarray:
    rng = np.random.default_rng(0)
    return rng.integers(0, 256, (n, 16, 16, 3), dtype=np.uint8)


# --------------------------------------------------------------------------
# roundtrip
# --------------------------------------------------------------------------

class TestRoundtrip:
    def test_bitexact_and_zero_traces(self, tmp_path):
        src = _engine()
        meta = export_artifact(src, tmp_path / "art", buckets=(1, 2))
        assert meta["schema"] == "phonebit-aot-v1"
        assert sorted(meta["buckets"]) == ["1", "2"]

        dst = _engine()
        with obs_metrics.use_registry() as reg:
            rep = load_artifact(dst, tmp_path / "art")
        assert rep["loaded"] == [1, 2] and not rep["missed"]
        assert reg.counter("artifact.hit").value == 2
        assert [e["outcome"] for e in reg.events("artifact")] == \
            ["hit", "hit"]

        x = _imgs(2)
        want = np.asarray(src.compile(2, donate_input=True)(
            jnp.asarray(x)))
        got = np.asarray(dst.compile(2, donate_input=True)(
            jnp.asarray(x)))
        np.testing.assert_array_equal(got, want)
        assert dst.trace_count == 0     # never traced anything

    def test_server_artifact_kwarg(self, tmp_path):
        export_artifact(_engine(), tmp_path / "art", buckets=(1, 2))
        eng = _engine()
        server = InferenceServer(eng, artifact=str(tmp_path / "art"),
                                 buckets=(1, 2), max_batch=2,
                                 max_wait_s=0.0)
        assert server.artifact_report["loaded"] == [1, 2]
        rs = [server.submit(i) for i in _imgs(3)]
        server.drain()
        assert [r.outcome for r in rs] == ["served"] * 3
        assert eng.trace_count == 0

    def test_read_meta_missing_dir(self, tmp_path):
        with pytest.raises(ArtifactError, match="not an artifact"):
            read_meta(tmp_path / "nope")


# --------------------------------------------------------------------------
# compatibility: every COMPAT field mismatch is a per-bucket miss
# --------------------------------------------------------------------------

class TestCompatFallback:
    @pytest.mark.parametrize("field,value", [
        ("schema", "phonebit-aot-v0"),
        ("device_kind", "tpu:TPU v9"),
        ("jax", "0.0.1"),
        ("mode", "vpu"),
        ("donate_input", False),
    ])
    def test_meta_mismatch_falls_back_per_bucket(self, tmp_path, field,
                                                 value):
        export_artifact(_engine(), tmp_path / "art", buckets=(1, 2))
        meta_path = tmp_path / "art" / "meta.json"
        meta = json.loads(meta_path.read_text())
        meta[field] = value
        meta_path.write_text(json.dumps(meta))

        dst = _engine()
        with obs_metrics.use_registry() as reg:
            rep = load_artifact(dst, tmp_path / "art")
        assert rep["loaded"] == []
        assert sorted(rep["missed"]) == [1, 2]
        assert all(any(field in reason for reason in reasons)
                   for reasons in rep["missed"].values())
        evs = reg.events("artifact")
        assert [e["outcome"] for e in evs] == ["miss", "miss"]
        assert {e["bucket"] for e in evs} == {1, 2}
        assert reg.counter("artifact.miss").value == 2
        # Boot still succeeds: the bucket live-compiles on first use.
        out = dst.compile(1, donate_input=True)(jnp.asarray(_imgs(1)))
        assert np.asarray(out).shape == (1, 10)
        assert dst.trace_count == 1     # the fallback traced once

    def test_graph_fingerprint_mismatch(self, tmp_path):
        export_artifact(_engine(), tmp_path / "art", buckets=(1,))
        other_spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
                      Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
        params = bnn_model.init_params(jax.random.key(0), other_spec)
        dst = PhoneBitEngine.from_trained(params, other_spec, (16, 16))
        rep = load_artifact(dst, tmp_path / "art")
        assert rep["loaded"] == []
        assert any("fingerprint" in r for r in rep["missed"][1])

    def test_bucket_subset_load(self, tmp_path):
        export_artifact(_engine(), tmp_path / "art", buckets=(1, 2, 4))
        dst = _engine()
        rep = load_artifact(dst, tmp_path / "art", buckets=(2,))
        assert rep["loaded"] == [2] and not rep["missed"]


# --------------------------------------------------------------------------
# integrity: corrupt bytes never reach XLA
# --------------------------------------------------------------------------

class TestIntegrity:
    def test_corrupted_bytes_raise_artifact_error(self, tmp_path):
        export_artifact(_engine(), tmp_path / "art", buckets=(1,))
        blob = tmp_path / "art" / "b1.fwd.bin"
        data = bytearray(blob.read_bytes())
        data[len(data) // 2] ^= 0xFF
        blob.write_bytes(bytes(data))
        with pytest.raises(ArtifactError, match="corrupted"):
            load_artifact(_engine(), tmp_path / "art")

    def test_undeserializable_bytes_raise_artifact_error(self, tmp_path):
        # sha-valid garbage: the checksum passes, unpickling must not
        # escape as a raw exception (and never abort into XLA).
        export_artifact(_engine(), tmp_path / "art", buckets=(1,))
        blob = tmp_path / "art" / "b1.fwd.bin"
        blob.write_bytes(b"not a pickle at all")
        meta_path = tmp_path / "art" / "meta.json"
        meta = json.loads(meta_path.read_text())
        meta["buckets"]["1"]["sha256"] = hashlib.sha256(
            b"not a pickle at all").hexdigest()
        meta_path.write_text(json.dumps(meta))
        with pytest.raises(ArtifactError, match="undeserializable"):
            load_artifact(_engine(), tmp_path / "art")

    def test_missing_executable_raises(self, tmp_path):
        export_artifact(_engine(), tmp_path / "art", buckets=(1,))
        (tmp_path / "art" / "b1.fwd.bin").unlink()
        with pytest.raises(ArtifactError, match="missing"):
            load_artifact(_engine(), tmp_path / "art")


# --------------------------------------------------------------------------
# autotune winner table rides along
# --------------------------------------------------------------------------

def test_autotune_table_rides_along(tmp_path, monkeypatch):
    from repro.runtime.autotune import Autotuner
    from repro.serving.artifact import load_autotune_table

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "0")   # no disk warm start
    src = _engine(mode="auto")
    export_artifact(src, tmp_path / "art", buckets=(1,))
    assert (tmp_path / "art" / "autotune.json").exists()
    # Adoption is checked against an ISOLATED tuner: the engine's own
    # tuner shares the process-wide module caches, which a same-process
    # load already holds (the table matters on a fresh boot).
    tuner = Autotuner(cache={}, agnostic_cache={}, persist=False)
    adopted = load_autotune_table(tmp_path / "art", tuner)
    assert adopted > 0
    assert tuner.cache and tuner.agnostic_cache
    assert all(e.get("env") for e in tuner.cache.values())
    # A stale-environment table is skipped entirely, like a stale disk.
    table_path = tmp_path / "art" / "autotune.json"
    table = json.loads(table_path.read_text())
    for e in table.values():
        e["env"] = {"jax": "0.0.1", "jaxlib": "0.0.1"}
    table_path.write_text(json.dumps(table))
    assert load_autotune_table(tmp_path / "art",
                               Autotuner(cache={}, agnostic_cache={},
                                         persist=False)) == 0


# --------------------------------------------------------------------------
# the zero-warmup pin, end to end in a fresh process
# --------------------------------------------------------------------------

def test_fresh_subprocess_serves_with_zero_traces(tmp_path):
    export_artifact(_engine(), tmp_path / "art", buckets=(1, 2))
    script = textwrap.dedent("""
        import os
        os.environ["REPRO_AUTOTUNE_CACHE"] = "0"
        import sys; sys.path.insert(0, {src!r})
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import bnn_model
        from repro.core.bnn_model import BConv, FloatDense, Pool
        from repro.serving import InferenceServer, PhoneBitEngine

        SPEC = [BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
                Pool(2, 2), FloatDense(8 * 8 * 16, 10)]
        params = bnn_model.init_params(jax.random.key(0), SPEC)
        eng = PhoneBitEngine.from_trained(params, SPEC, (16, 16))
        server = InferenceServer(eng, artifact={art!r}, buckets=(1, 2),
                                 max_batch=2, max_wait_s=0.0)
        assert server.artifact_report["loaded"] == [1, 2], \\
            server.artifact_report

        rng = np.random.default_rng(0)
        imgs = [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
                for _ in range(3)]
        rs = [server.submit(i) for i in imgs]
        server.drain()
        assert all(r.outcome == "served" for r in rs), \\
            [r.outcome for r in rs]
        # THE pin: submit -> result in a process that never traced.
        assert eng.trace_count == 0, eng.trace_count

        # Bit-exact vs a live-compiled reference engine (same seed).
        # Bucket-matched: 3 requests through max_batch=2 serve as a
        # batch of 2 then a batch of 1, and float accumulation order
        # differs across batch shapes — so each request is compared
        # against a reference computed at its own bucket.
        ref_eng = PhoneBitEngine.from_trained(
            bnn_model.init_params(jax.random.key(0), SPEC), SPEC,
            (16, 16))
        ref2 = np.asarray(ref_eng.compile(2)(
            jnp.asarray(np.stack(imgs[:2]))))
        ref1 = np.asarray(ref_eng.compile(1)(
            jnp.asarray(np.stack(imgs[2:]))))
        np.testing.assert_array_equal(np.asarray(rs[0].result), ref2[0])
        np.testing.assert_array_equal(np.asarray(rs[1].result), ref2[1])
        np.testing.assert_array_equal(np.asarray(rs[2].result), ref1[0])
        assert eng.trace_count == 0    # the reference traced, not us
        print("zero-warmup-ok")
    """).format(src=str(REPO / "src"), art=str(tmp_path / "art"))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=420,
                       env=dict(os.environ))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "zero-warmup-ok" in r.stdout
