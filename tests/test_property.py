"""Property-based tests (hypothesis) for system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import binary_conv, binary_ops, packing
from repro.distributed.straggler import StragglerMonitor
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.serving import BatchScheduler


class TestBinaryAlgebra:
    @given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 300),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pm1_impl_equals_xor_impl(self, m, n, k, seed):
        """The matmul-engine reformulation is exact for any shape."""
        rng = np.random.default_rng(seed)
        a = packing.pack_signs(jnp.asarray(
            rng.choice([-1.0, 1.0], (m, k)).astype(np.float32)))
        b = packing.pack_signs(jnp.asarray(
            rng.choice([-1.0, 1.0], (n, k)).astype(np.float32)))
        cx = binary_ops.packed_matmul_counts(a, b, impl="xor")
        cp = binary_ops.packed_matmul_counts(a, b, impl="pm1")
        np.testing.assert_array_equal(np.asarray(cx), np.asarray(cp))

    @given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_or_pool_equals_maxpool(self, hw, win, seed):
        """sign is monotone: OR-pooling packed bits == maxpool-then-pack."""
        rng = np.random.default_rng(seed)
        win = min(win, hw)
        x = rng.choice([-1.0, 1.0], (1, hw, hw, 64)).astype(np.float32)
        xp = packing.pack_signs(jnp.asarray(x), axis=-1)
        pooled_packed = binary_conv.binary_or_maxpool(xp, win, win)
        from jax import lax
        pooled_float = lax.reduce_window(
            jnp.asarray(x), -jnp.inf, lax.max, (1, win, win, 1),
            (1, win, win, 1), "VALID")
        expect = packing.pack_signs(pooled_float, axis=-1)
        np.testing.assert_array_equal(np.asarray(pooled_packed),
                                      np.asarray(expect))

    @given(st.integers(1, 4), st.integers(1, 64), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_pack_axis_invariance(self, lead, c, seed):
        """Packing along any axis round-trips."""
        rng = np.random.default_rng(seed)
        bits = rng.integers(0, 2, (lead, c, 3)).astype(np.int32)
        for axis in range(3):
            w = packing.pack_bits(jnp.asarray(bits), axis=axis)
            out = packing.unpack_bits(w, bits.shape[axis], axis=axis)
            np.testing.assert_array_equal(np.asarray(out), bits)


class TestChunkedCE:
    @given(st.integers(1, 3), st.sampled_from([8, 12, 24]),
           st.integers(10, 80), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_matches_dense(self, b, s, v, seed):
        mesh = make_host_mesh(1, 1)
        rules = rules_for_mesh(mesh)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(b, s, 16)).astype(np.float32))
        head = jnp.asarray(rng.normal(size=(16, v)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        with mesh:
            dense = transformer.cross_entropy(x @ head, labels)
            chunked = transformer.chunked_ce(x, head, labels, rules, v)
        np.testing.assert_allclose(float(dense), float(chunked),
                                   rtol=1e-5)

    @given(st.integers(1, 7), st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_vocab_padding_invariant(self, pad, seed):
        """Extra (masked) vocab columns never change the loss."""
        mesh = make_host_mesh(1, 1)
        rules = rules_for_mesh(mesh)
        rng = np.random.default_rng(seed)
        b, s, d, v = 2, 8, 8, 17
        x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
        head = jnp.asarray(rng.normal(size=(d, v)).astype(np.float32))
        head_pad = jnp.pad(head, ((0, 0), (0, pad)))
        labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        with mesh:
            base = transformer.chunked_ce(x, head, labels, rules, v)
            padded = transformer.chunked_ce(x, head_pad, labels, rules, v)
        np.testing.assert_allclose(float(base), float(padded), rtol=1e-5)


class TestServingInvariants:
    @given(st.integers(1, 30), st.integers(1, 8), st.integers(0, 999))
    @settings(max_examples=20, deadline=None)
    def test_scheduler_conserves_requests(self, n_req, max_batch, seed):
        """Every submitted request is served exactly once, in order."""
        s = BatchScheduler(max_batch=max_batch, max_wait_s=0.0,
                           buckets=(1, 2, 4, 8))
        for i in range(n_req):
            s.submit(i)
        served = []
        while len(s):
            done = s.drain(lambda ps: [p * 2 for p in ps])
            served.extend(r.payload for r in done)
            assert all(r.result == r.payload * 2 for r in done)
        assert served == list(range(n_req))

    @given(st.floats(0.001, 0.2), st.integers(20, 60))
    @settings(max_examples=15, deadline=None)
    def test_straggler_constant_never_flags(self, dt, n):
        mon = StragglerMonitor(min_samples=5)
        assert not any(mon.observe(i, dt) for i in range(n))


class TestRulesInvariants:
    @given(st.integers(1, 512))
    @settings(max_examples=30, deadline=None)
    def test_shard_if_divisibility(self, dim):
        mesh = make_host_mesh(1, 1)
        rules = rules_for_mesh(mesh)
        got = rules.shard_if(dim, rules.model)
        # tp == 1: everything is "divisible", axis returned
        assert got == rules.model

    @given(st.integers(1, 100), st.integers(1, 16))
    @settings(max_examples=30, deadline=None)
    def test_padded_vocab(self, vocab, mult):
        vp = transformer.padded_vocab(vocab, mult)
        assert vp >= vocab and vp % mult == 0 and vp - vocab < mult
