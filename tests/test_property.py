"""Property-based tests (hypothesis) for system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import binary_conv, binary_ops, packing
from repro.distributed.straggler import StragglerMonitor
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.serving import BatchScheduler
from repro.serving.kv_cache import KVCacheManager


class TestBinaryAlgebra:
    @given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 300),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pm1_impl_equals_xor_impl(self, m, n, k, seed):
        """The matmul-engine reformulation is exact for any shape."""
        rng = np.random.default_rng(seed)
        a = packing.pack_signs(jnp.asarray(
            rng.choice([-1.0, 1.0], (m, k)).astype(np.float32)))
        b = packing.pack_signs(jnp.asarray(
            rng.choice([-1.0, 1.0], (n, k)).astype(np.float32)))
        cx = binary_ops.packed_matmul_counts(a, b, impl="xor")
        cp = binary_ops.packed_matmul_counts(a, b, impl="pm1")
        np.testing.assert_array_equal(np.asarray(cx), np.asarray(cp))

    @given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_or_pool_equals_maxpool(self, hw, win, seed):
        """sign is monotone: OR-pooling packed bits == maxpool-then-pack."""
        rng = np.random.default_rng(seed)
        win = min(win, hw)
        x = rng.choice([-1.0, 1.0], (1, hw, hw, 64)).astype(np.float32)
        xp = packing.pack_signs(jnp.asarray(x), axis=-1)
        pooled_packed = binary_conv.binary_or_maxpool(xp, win, win)
        from jax import lax
        pooled_float = lax.reduce_window(
            jnp.asarray(x), -jnp.inf, lax.max, (1, win, win, 1),
            (1, win, win, 1), "VALID")
        expect = packing.pack_signs(pooled_float, axis=-1)
        np.testing.assert_array_equal(np.asarray(pooled_packed),
                                      np.asarray(expect))

    @given(st.integers(1, 4), st.integers(1, 64), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_pack_axis_invariance(self, lead, c, seed):
        """Packing along any axis round-trips."""
        rng = np.random.default_rng(seed)
        bits = rng.integers(0, 2, (lead, c, 3)).astype(np.int32)
        for axis in range(3):
            w = packing.pack_bits(jnp.asarray(bits), axis=axis)
            out = packing.unpack_bits(w, bits.shape[axis], axis=axis)
            np.testing.assert_array_equal(np.asarray(out), bits)


class TestChunkedCE:
    @given(st.integers(1, 3), st.sampled_from([8, 12, 24]),
           st.integers(10, 80), st.integers(0, 10_000))
    @settings(max_examples=15, deadline=None)
    def test_matches_dense(self, b, s, v, seed):
        mesh = make_host_mesh(1, 1)
        rules = rules_for_mesh(mesh)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(b, s, 16)).astype(np.float32))
        head = jnp.asarray(rng.normal(size=(16, v)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        with mesh:
            dense = transformer.cross_entropy(x @ head, labels)
            chunked = transformer.chunked_ce(x, head, labels, rules, v)
        np.testing.assert_allclose(float(dense), float(chunked),
                                   rtol=1e-5)

    @given(st.integers(1, 7), st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_vocab_padding_invariant(self, pad, seed):
        """Extra (masked) vocab columns never change the loss."""
        mesh = make_host_mesh(1, 1)
        rules = rules_for_mesh(mesh)
        rng = np.random.default_rng(seed)
        b, s, d, v = 2, 8, 8, 17
        x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
        head = jnp.asarray(rng.normal(size=(d, v)).astype(np.float32))
        head_pad = jnp.pad(head, ((0, 0), (0, pad)))
        labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
        with mesh:
            base = transformer.chunked_ce(x, head, labels, rules, v)
            padded = transformer.chunked_ce(x, head_pad, labels, rules, v)
        np.testing.assert_allclose(float(base), float(padded), rtol=1e-5)


class TestServingInvariants:
    @given(st.integers(1, 30), st.integers(1, 8), st.integers(0, 999))
    @settings(max_examples=20, deadline=None)
    def test_scheduler_conserves_requests(self, n_req, max_batch, seed):
        """Every submitted request is served exactly once, in order."""
        s = BatchScheduler(max_batch=max_batch, max_wait_s=0.0,
                           buckets=(1, 2, 4, 8))
        for i in range(n_req):
            s.submit(i)
        served = []
        while len(s):
            done = s.drain(lambda ps: [p * 2 for p in ps])
            served.extend(r.payload for r in done)
            assert all(r.result == r.payload * 2 for r in done)
        assert served == list(range(n_req))

    @given(st.floats(0.001, 0.2), st.integers(20, 60))
    @settings(max_examples=15, deadline=None)
    def test_straggler_constant_never_flags(self, dt, n):
        mon = StragglerMonitor(min_samples=5)
        assert not any(mon.observe(i, dt) for i in range(n))


class TestKVSlotLifecycle:
    """Slot-lifecycle invariants of the paged-lite KVCacheManager under
    random admit/step/release interleavings (the state crash recovery
    snapshots and rebuilds, DESIGN.md §14.2): slots are conserved and
    disjoint, utilization stays in [0, 1], and no slot is ever
    double-freed."""

    @given(st.integers(1, 6), st.integers(4, 24),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_random_interleaving_invariants(self, n_slots, max_seq, seed):
        rng = np.random.default_rng(seed)
        mgr = KVCacheManager(n_slots, max_seq)
        eos = 3
        for _ in range(60):
            assert 0.0 <= mgr.utilization <= 1.0
            slots = mgr.active_slots()
            assert len(slots) == len(set(slots))            # disjoint
            assert len(set(mgr._free)) == len(mgr._free)    # no dup free
            assert sorted(slots + mgr._free) == list(range(n_slots))
            op = int(rng.integers(0, 3))
            if op == 0 and mgr.can_admit():
                plen = int(rng.integers(1, max_seq))
                mgr.admit(plen, int(rng.integers(1, max_seq - plen + 1)))
            elif op == 1 and mgr.active:
                sid = int(rng.choice(list(mgr.active)))
                mgr.record_token(sid, int(rng.integers(0, 16)), eos)
            elif op == 2 and mgr.active:
                sid = int(rng.choice(list(mgr.active)))
                mgr.release(sid)
                with np.testing.assert_raises(KeyError):
                    mgr.release(sid)                        # no double free
        for sid in list(mgr.active):
            mgr.release(sid)
        assert mgr.utilization == 0.0
        assert sorted(mgr._free) == list(range(n_slots))

    @given(st.sampled_from(["eos", "max_new", "max_seq"]),
           st.integers(1, 6), st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_each_termination_releases_exactly_once(self, how, max_new,
                                                    seed):
        """EOS, max_new exhaustion, and the max_seq guard each finish a
        sequence through exactly one release — even when two conditions
        trigger on the same token."""
        rng = np.random.default_rng(seed)
        eos, max_seq = 3, 32
        if how == "max_seq":
            # saturate the window so length hits max_seq on the last
            # generated token (simultaneous with max_new — still one
            # release)
            plen = max_seq - max_new
        else:
            plen = int(rng.integers(1, max_seq - max_new + 1))
        mgr = KVCacheManager(1, max_seq)
        seq = mgr.admit(plen, max_new)
        done = False
        for i in range(max_new):
            last = i == max_new - 1
            if how == "eos" and last:
                tok = eos
            else:
                tok = int(rng.integers(4, 16))   # never eos by accident
            done = mgr.record_token(seq.seq_id, tok, eos)
            if how == "eos" and last:
                break
        assert done
        assert seq.seq_id not in mgr.active
        assert mgr._free == [0] and mgr.utilization == 0.0
        if how == "max_seq":
            assert seq.length == max_seq
        with np.testing.assert_raises(KeyError):
            mgr.release(seq.seq_id)

    @given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_adopt_respects_window_and_disjointness(self, plen, gen,
                                                    extra):
        """Adopted (restored/migrated) sequences obey the same window
        arithmetic: length + remaining ≤ max_seq, fresh slot, fresh id."""
        max_seq = 32
        mgr = KVCacheManager(2, max_seq)
        a = mgr.admit(plen, gen + extra)
        tokens = list(range(gen))
        b = mgr.adopt(plen + gen, gen + extra, gen, tokens,
                      prompt=list(range(plen)))
        assert b.seq_id != a.seq_id and b.slot != a.slot
        assert b.tokens == tokens and b.generated == gen
        # the adopted sequence finishes after exactly `extra` tokens
        done = False
        for _ in range(extra):
            done = mgr.record_token(b.seq_id, 5, None)
        assert done and b.seq_id not in mgr.active


class TestRulesInvariants:
    @given(st.integers(1, 512))
    @settings(max_examples=30, deadline=None)
    def test_shard_if_divisibility(self, dim):
        mesh = make_host_mesh(1, 1)
        rules = rules_for_mesh(mesh)
        got = rules.shard_if(dim, rules.model)
        # tp == 1: everything is "divisible", axis returned
        assert got == rules.model

    @given(st.integers(1, 100), st.integers(1, 16))
    @settings(max_examples=30, deadline=None)
    def test_padded_vocab(self, vocab, mult):
        vp = transformer.padded_vocab(vocab, mult)
        assert vp >= vocab and vp % mult == 0 and vp - vocab < mult
