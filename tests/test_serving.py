"""Serving-subsystem tests (DESIGN.md §7).

Scheduler policy under an injected fake clock (max_wait firing, bucket
rounding, zero-padding, deadline shedding, the run-only-at-bucket-sizes
contract), the InferenceServer (per-bucket executable cache → zero
serve-time retraces, async == sync results, bit-exactness vs the engine
cross-check oracle, metrics), cross-bucket autotune reuse, data-parallel
batch sharding (in a subprocess with placeholder devices), and the LM
server speaking the same protocol.
"""

import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.serving import (BatchScheduler, InferenceServer, PhoneBitEngine,
                           Server)

REPO = pathlib.Path(__file__).resolve().parent.parent


# --------------------------------------------------------------------------
# Scheduler policy (fake clock)
# --------------------------------------------------------------------------

class TestSchedulerPolicy:
    def test_max_wait_fires(self):
        s = BatchScheduler(max_batch=4, max_wait_s=0.005)
        s.submit("a", now=100.0)
        assert s.next_batch(now=100.004) is None      # still waiting
        batch = s.next_batch(now=100.006)             # max_wait passed
        assert [r.payload for r in batch] == ["a"]

    def test_full_batch_fires_immediately(self):
        s = BatchScheduler(max_batch=2, max_wait_s=10.0, buckets=(1, 2))
        s.submit("a", now=0.0)
        s.submit("b", now=0.0)
        assert len(s.next_batch(now=0.0)) == 2

    def test_bucket_rounding(self):
        s = BatchScheduler(max_batch=8, max_wait_s=0.0, buckets=(1, 4, 8))
        assert [s.bucket_for(n) for n in (1, 2, 3, 4, 5, 8)] == \
            [1, 4, 4, 4, 8, 8]

    def test_drain_zero_pads_and_slices(self):
        s = BatchScheduler(max_batch=8, max_wait_s=0.0, buckets=(1, 4, 8))
        for i in range(3):
            s.submit(np.full((2, 2), i + 1, np.int32), now=0.0)
        seen = {}

        def run(payloads):
            seen["n"] = len(payloads)
            seen["pad"] = payloads[3]
            return [p * 10 for p in payloads]

        done = s.drain(run, now=0.0)
        assert seen["n"] == 4                        # padded 3 -> bucket 4
        np.testing.assert_array_equal(seen["pad"],
                                      np.zeros((2, 2), np.int32))
        assert len(done) == 3                        # pad row discarded
        np.testing.assert_array_equal(done[0].result,
                                      np.full((2, 2), 10, np.int32))

    def test_deadline_shedding(self):
        s = BatchScheduler(max_batch=4, max_wait_s=0.0, buckets=(1, 2, 4))
        patient = s.submit("p", now=0.0)                  # no deadline
        hasty = s.submit("h", deadline_s=1.0, now=0.0)    # expires at 1.0
        shed = s.shed_expired(now=0.5)
        assert shed == [] and len(s) == 2
        batch = s.next_batch(now=2.0)                     # hasty expired
        assert [r.payload for r in batch] == ["p"]
        assert hasty.done and hasty.result is None
        assert s.dropped == 1 and not patient.done

    def test_expired_mid_queue_is_shed(self):
        s = BatchScheduler(max_batch=8, max_wait_s=0.0, buckets=(1, 2, 4, 8))
        s.submit("a", now=0.0)
        doomed = s.submit("b", deadline_s=0.5, now=0.0)
        s.submit("c", now=0.0)
        batch = s.next_batch(now=1.0)
        assert [r.payload for r in batch] == ["a", "c"]
        assert doomed.done and s.dropped == 1

    def test_deadline_shedding_wall_clock(self):
        """Smoke the shed policy against the REAL monotonic clock (every
        other policy test injects a fake one, so a regression in the
        default clock path could hide).  Margins are generous — the
        doomed deadline (50 ms) is 5x shorter than the sleep (250 ms),
        and the patient deadline (60 s) is ~240x longer — so scheduler
        slowness cannot flip the outcome."""
        import time as _time

        s = BatchScheduler(max_batch=4, max_wait_s=0.0, buckets=(1, 2, 4))
        patient = s.submit("p", deadline_s=60.0)
        doomed = s.submit("d", deadline_s=0.05)
        _time.sleep(0.25)
        batch = s.next_batch()                     # no now=: real clock
        assert [r.payload for r in batch] == ["p"]
        assert doomed.done and doomed.result is None
        assert s.dropped == 1 and not patient.result

    def test_max_wait_wall_clock(self):
        """ready() flips from False to True by real elapsed time."""
        import time as _time

        s = BatchScheduler(max_batch=8, max_wait_s=0.1, buckets=(1, 8))
        s.submit("a")
        assert not s.ready()          # 100 ms cannot have elapsed yet
        _time.sleep(0.3)
        assert s.ready()

    def test_drain_only_calls_run_at_bucket_sizes(self):
        buckets = (1, 2, 4, 8)
        s = BatchScheduler(max_batch=8, max_wait_s=0.0, buckets=buckets)
        sizes = []

        def run(payloads):
            sizes.append(len(payloads))
            return payloads

        for n in (1, 3, 5, 8, 2, 7, 6, 4):
            for i in range(n):
                s.submit(i, now=0.0)
            while len(s):
                s.drain(run, now=0.0)
        assert sizes and all(n in buckets for n in sizes)


# --------------------------------------------------------------------------
# InferenceServer over a tiny BNN engine
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine():
    spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
            Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    return PhoneBitEngine.from_trained(params, spec, (16, 16))


def _images(n, rng=None):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(n)]


class TestInferenceServer:
    def test_protocol(self, tiny_engine):
        server = InferenceServer(tiny_engine, buckets=(1, 2), max_batch=2)
        assert isinstance(server, Server)

    def test_zero_recompiles_after_bucket_precompile(self, tiny_engine):
        server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                 max_batch=4, max_wait_s=0.0)
        server.compile_buckets()
        before = tiny_engine.trace_count
        # mixed-size stream: singles, pairs, odd group padded to 4
        for group in (1, 2, 3, 4, 1, 3):
            for img in _images(group):
                server.submit(img)
            server.drain()
        assert tiny_engine.trace_count == before     # the serve contract
        assert server.metrics()["served"] == 14

    def test_results_bit_exact_vs_cross_check(self, tiny_engine):
        server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                 max_batch=4, max_wait_s=0.0)
        server.compile_buckets()
        for group in (4, 2, 1):           # full buckets: no padding rows
            imgs = _images(group, np.random.default_rng(group))
            reqs = [server.submit(i) for i in imgs]
            server.drain()
            ref = tiny_engine.cross_check(jnp.asarray(np.stack(imgs)))
            for i, r in enumerate(reqs):
                np.testing.assert_array_equal(r.result,
                                              np.asarray(ref)[i])

    def test_padded_results_match_unpadded_rows(self, tiny_engine):
        """A request served in a padded bucket gets the same row it would
        in the explicitly padded batch (pad rows are zeros)."""
        server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                 max_batch=4, max_wait_s=0.0)
        imgs = _images(3, np.random.default_rng(7))
        reqs = [server.submit(i) for i in imgs]
        server.drain()
        padded = np.stack(imgs + [np.zeros_like(imgs[0])])
        ref = np.asarray(tiny_engine(jnp.asarray(padded)))
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(r.result, ref[i])

    def test_async_matches_sync(self, tiny_engine):
        outs = {}
        for mode in (True, False):
            server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                     max_batch=4, async_dispatch=mode)
            reqs = [server.submit(i)
                    for i in _images(9, np.random.default_rng(3))]
            done = server.drain()
            assert len(done) == 9 and all(r.done for r in reqs)
            outs[mode] = [r.result for r in reqs]
        for a, b in zip(outs[True], outs[False]):
            np.testing.assert_array_equal(a, b)

    def test_deadline_shed_through_server(self, tiny_engine):
        t = {"now": 0.0}
        server = InferenceServer(tiny_engine, buckets=(1, 2), max_batch=2,
                                 clock=lambda: t["now"])
        kept = server.submit(_images(1)[0], now=0.0)
        shed = server.submit(_images(1)[0], deadline_s=1.0, now=0.0)
        t["now"] = 5.0
        server.drain(now=5.0)
        assert kept.done and kept.result is not None
        assert shed.done and shed.result is None
        m = server.metrics()
        assert m["dropped"] == 1 and m["served"] == 1

    def test_metrics_shape(self, tiny_engine):
        server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                 max_batch=4)
        for img in _images(6):
            server.submit(img)
        server.drain()
        m = server.metrics()
        assert m["served"] == 6 and m["dropped"] == 0
        assert m["queue_depth"] == 0
        assert 0 < m["p50_ms"] <= m["p95_ms"]
        assert m["throughput"] > 0
        assert m["async_dispatch"] is True

    def test_compile_rejects_unshardable_bucket(self, tiny_engine):
        with pytest.raises(ValueError, match="divisible"):
            tiny_engine.compile(5, data_parallel=2)

    def test_preprocess_hook(self, tiny_engine):
        """Payloads arrive at 32x32 and the preprocess hook (2x2 mean
        pool to the engine's 16x16) runs at batch staging — identically
        under sync and async dispatch, pads included."""
        def pool2(img):
            x = img.astype(np.uint16)
            x = (x[0::2, 0::2] + x[1::2, 0::2]
                 + x[0::2, 1::2] + x[1::2, 1::2]) // 4
            return x.astype(np.uint8)

        rng = np.random.default_rng(11)
        raw = [rng.integers(0, 256, (32, 32, 3), dtype=np.uint8)
               for _ in range(3)]
        outs = {}
        for mode in (True, False):
            server = InferenceServer(tiny_engine, buckets=(1, 2, 4),
                                     max_batch=4, async_dispatch=mode,
                                     preprocess=pool2)
            reqs = [server.submit(r) for r in raw]
            server.drain()        # 3 requests -> bucket 4, zero pad
            outs[mode] = [r.result for r in reqs]
        ref = np.asarray(tiny_engine(jnp.asarray(np.stack(
            [pool2(r) for r in raw] + [np.zeros((16, 16, 3), np.uint8)]))))
        for mode in (True, False):
            for i, got in enumerate(outs[mode]):
                np.testing.assert_array_equal(got, ref[i])


# --------------------------------------------------------------------------
# Cross-bucket autotune reuse
# --------------------------------------------------------------------------

class TestCrossBucketAutotune:
    def test_second_bucket_reuses_first(self, monkeypatch):
        from repro import runtime
        from repro.runtime.autotune import Autotuner

        spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
                Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
        params = bnn_model.init_params(jax.random.key(0), spec)
        from repro.core import converter
        packed = converter.convert(params, spec, (16, 16))
        g = runtime.fuse_pool_epilogue(
            runtime.lower_packed(spec, packed, (16, 16)))

        timed = []
        orig = Autotuner._time_node

        def counting(self, node, x, backend, tile):
            timed.append(x.shape)
            return orig(self, node, x, backend, tile)

        monkeypatch.setattr(Autotuner, "_time_node", counting)
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "0")
        tuner = Autotuner(candidates=("xla", "xla_pm1"), warmup=0, iters=1)
        c1, _ = tuner.tune_with_tiles(g, (1, 16, 16, 3))
        n_first = len(timed)
        assert n_first > 0
        c4, _ = tuner.tune_with_tiles(g, (4, 16, 16, 3))
        assert len(timed) == n_first        # batch 4: zero new timings
        assert c4 == c1                     # same winners, transferred
        reused = [e for e in tuner.cache.values()
                  if e.get("reused_across_batch")]
        assert reused

    def test_block_n_tile_does_not_transfer(self):
        from repro.runtime.autotune import Autotuner

        tuner = Autotuner(candidates=("xla",), persist=False)
        tuner.agnostic_cache["batchless::k"] = {
            "winner": "vpu_direct", "tile": {"block_n": 4}}
        assert tuner._cross_batch_entry("batchless::k") is None
        tuner.agnostic_cache["batchless::k2"] = {
            "winner": "vpu_direct", "tile": {"block_h": 8}}
        assert tuner._cross_batch_entry("batchless::k2") is not None


# --------------------------------------------------------------------------
# Data-parallel batch sharding (subprocess: placeholder devices)
# --------------------------------------------------------------------------

def test_sharded_serving_matches_single_device():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["REPRO_AUTOTUNE_CACHE"] = "0"
        import sys; sys.path.insert(0, {src!r})
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import bnn_model
        from repro.core.bnn_model import BConv, FloatDense, Pool
        from repro.launch.mesh import make_host_mesh
        from repro.serving import InferenceServer, PhoneBitEngine

        spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
                Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
        params = bnn_model.init_params(jax.random.key(0), spec)
        engine = PhoneBitEngine.from_trained(params, spec, (16, 16))
        mesh = make_host_mesh(data=4, model=1)

        sharded = InferenceServer(engine, buckets=(1, 2, 4, 8),
                                  max_batch=8, mesh=mesh)
        # buckets rounded up to shard evenly over data=4
        assert sharded.scheduler.buckets == (4, 8), \\
            sharded.scheduler.buckets
        assert sharded.data_parallel == 4
        single = InferenceServer(engine, buckets=(4, 8), max_batch=8)
        sharded.compile_buckets()
        single.compile_buckets()
        before = engine.trace_count

        rng = np.random.default_rng(0)
        imgs = [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
                for _ in range(8)]
        rs = [sharded.submit(i) for i in imgs]
        ru = [single.submit(i) for i in imgs]
        sharded.drain(); single.drain()
        assert engine.trace_count == before    # both paths precompiled
        for a, b in zip(rs, ru):
            np.testing.assert_array_equal(a.result, b.result)
        m = sharded.metrics()
        assert m["served"] == 8 and m["data_parallel"] == 4
        print("sharded-serving-ok")
    """).format(src=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=420,
                       env=dict(os.environ))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "sharded-serving-ok" in r.stdout


# --------------------------------------------------------------------------
# LM server speaks the same protocol
# --------------------------------------------------------------------------

def test_lm_server_protocol():
    from repro.distributed.sharding import rules_for_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer
    from repro.serving.lm_server import LMServer

    cfg = transformer.LMConfig(
        name="proto-demo", n_layers=1, d_model=64, n_heads=2, n_kv_heads=1,
        d_head=32, d_ff=128, vocab=128, tie_embeddings=True)
    mesh = make_host_mesh(data=1, model=1)
    rules = rules_for_mesh(mesh)
    with mesh:
        params = transformer.init_params(jax.random.key(0), cfg, ep=1)
        server = LMServer(cfg=cfg, rules=rules, params=params, n_slots=2,
                          max_seq=32)
        assert isinstance(server, Server)
        rng = np.random.default_rng(0)
        reqs = [server.submit(list(rng.integers(1, cfg.vocab, 4)),
                              max_new=3) for _ in range(3)]
        done = server.drain()
        assert len(done) == 3 and all(r.done for r in reqs)
        assert all(1 <= len(r.result) <= 3 for r in reqs)
        m = server.metrics()
        assert m["served"] == 3 and m["dropped"] == 0
        assert m["queue_depth"] == 0 and m["p50_ms"] is not None
        # invalid requests resolve ``rejected`` at the protocol edge —
        # structured outcome, not an exception (DESIGN.md §11.2)
        bad = server.submit(list(range(1, 31)), max_new=8)
        assert bad.done and bad.outcome == "rejected"
        assert "max_seq" in bad.error
        bad = server.submit([])
        assert bad.done and bad.outcome == "rejected"
        assert "empty" in bad.error
        assert server.metrics()["rejected"] == 2
        assert server.queue_depth == 0       # rejects never enqueue

    # deadline shedding at admission — including mid-queue behind a
    # patient request while all KV slots are busy
    with mesh:
        server = LMServer(cfg=cfg, rules=rules, params=params, n_slots=1,
                          max_seq=32, clock=lambda: 100.0)
        patient1 = server.submit([1, 2], max_new=1, now=0.0)   # admitted
        patient2 = server.submit([3, 4], max_new=1, now=0.0)   # queued
        hasty = server.submit([5], max_new=1, deadline_s=1.0, now=0.0)
        server.drain()
        assert hasty.done and hasty.result is None    # shed mid-queue
        assert patient1.result and patient2.result
        m = server.metrics()
        assert m["dropped"] == 1 and m["served"] == 2
