"""Chain-fusion megakernel regions (DESIGN.md §9): kernel bit-exactness,
region formation + VMEM budgeting, executor integration, chain autotune,
and the memory-plan report regression."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import binary_conv, bnn_model, converter, layer_integration, \
    packing
from repro.core.bnn_model import BConv, BDense, FloatDense, Pool
from repro import runtime
from repro.kernels import ops as kops
from repro.kernels.chain_conv import StageSpec, chain_geometry
from repro.runtime import (Autotuner, GraphExecutor, build_chain,
                           chain_executor, lower_packed, partition_chains,
                           plan_memory, vmem_plan)
from repro.runtime import regions as regions_mod
from repro.serving import PhoneBitEngine


def _randomize_bn(params, seed=42):
    rng = np.random.default_rng(seed)
    for p in params:
        if "mu" in p:
            o = p["mu"].shape[0]
            p["mu"] = jnp.asarray(rng.uniform(-20, 20, o), jnp.float32)
            p["var"] = jnp.asarray(rng.uniform(0.5, 4, o), jnp.float32)
            p["gamma"] = jnp.asarray(rng.uniform(-1.5, 1.5, o), jnp.float32)
            p["beta"] = jnp.asarray(rng.uniform(-1, 1, o), jnp.float32)
    return params


def _fused_graph(spec, hw, seed=0, bn_seed=11):
    params = _randomize_bn(bnn_model.init_params(jax.random.key(seed), spec),
                           seed=bn_seed)
    packed = converter.convert(params, spec, hw)
    return runtime.fuse_pool_epilogue(lower_packed(spec, packed, hw)), packed


def _conv_pair(rng, c_in, c_out, k):
    w = jnp.asarray(rng.choice([-1.0, 1.0],
                               (k, k, c_in, c_out)).astype(np.float32))
    wp = binary_conv.pack_conv_weights(w)
    t = jnp.asarray(rng.integers(0, k * k * c_in, c_out), jnp.int32)
    s = jnp.asarray(rng.integers(0, 2, c_out).astype(bool))
    return wp, layer_integration.IntegratedParams(t, s)


CHAIN_NET = [
    BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
    Pool(2, 2),
    BConv(16, 40, kernel=3, stride=2, pad=1),   # stride-2, non-mult-32 O
    Pool(2, 1, pad=(0, 1)),                     # darknet 'same' pool
    BConv(40, 32, kernel=1, stride=1, pad=0),   # 1x1, pad 0
]


# --------------------------------------------------------------------------
# Kernel level: chain_conv vs per-node composition
# --------------------------------------------------------------------------

class TestChainKernel:

    @pytest.fixture(scope="class")
    def three_stage(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.choice([-1.0, 1.0],
                                   (2, 10, 10, 32)).astype(np.float32))
        xp = packing.pack_signs(x, axis=-1)
        wp1, p1 = _conv_pair(rng, 32, 48, 3)
        wp2, p2 = _conv_pair(rng, 48, 64, 3)
        y1 = kops.fused_binary_conv2d(xp, wp1, p1, 3, 3, 1, 1, mode="xla")
        y1p = binary_conv.binary_or_maxpool(y1, 2, 2)
        ref = kops.fused_binary_conv2d(y1p, wp2, p2, 3, 3, 1, 1, mode="xla")
        stages = (StageSpec("conv", 3, 1, 1, 1, channels=48),
                  StageSpec("pool", 2, 2, channels=48),
                  StageSpec("conv", 3, 1, 1, 1, channels=64))
        arrays = (wp1, None, p1.threshold, p1.sign_flip,
                  wp2, None, p2.threshold, p2.sign_flip)
        return xp, stages, arrays, np.asarray(ref)

    def test_single_tile_matches_per_node(self, three_stage):
        xp, stages, arrays, ref = three_stage
        got = kops.chain_forward(xp, stages, arrays)
        np.testing.assert_array_equal(np.asarray(got), ref)

    @pytest.mark.parametrize("tile", [
        dict(block_h=2), dict(block_h=3, block_w=2),
        dict(block_h=2, block_n=2), dict(block_h=5, block_w=5)])
    def test_tiled_halo_matches_per_node(self, three_stage, tile):
        """Spatial tiling grows every stage's tile backwards through the
        chain (halo coupling); border tiles cover pad-region coordinates
        that must read as zero words."""
        xp, stages, arrays, ref = three_stage
        got = kops.chain_forward(xp, stages, arrays, **tile)
        np.testing.assert_array_equal(np.asarray(got), ref)

    def test_padded_pool_tail(self, three_stage):
        xp, stages, arrays, ref = three_stage
        stages = stages + (StageSpec("pool", 2, 1, 0, 1, channels=64),)
        want = binary_conv.binary_or_maxpool(jnp.asarray(ref), 2, 1,
                                             pad=(0, 1))
        got = kops.chain_forward(xp, stages, arrays, block_h=3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_planner_offsets_reuse_arena(self, three_stage):
        """Passing vmem_plan offsets (ping-pong reuse) is bit-identical to
        the dense default layout — the plan is load-bearing, not lossy."""
        xp, stages, arrays, ref = three_stage
        stages = stages + (StageSpec("conv", 1, 1, 0, 0, channels=32),)
        rng = np.random.default_rng(5)
        wp3, p3 = _conv_pair(rng, 64, 32, 1)
        arrays = arrays + (wp3, None, p3.threshold, p3.sign_flip)
        plan = regions_mod.plan_chain_vmem(stages, xp.shape)
        # three interior buffers with lifetimes [k, k+1]: first and third
        # must share space, so the planned arena beats the no-reuse sum
        assert len(plan.offsets) == 3
        assert plan.arena_bytes < plan.naive_bytes()
        assert plan.offsets[0] == plan.offsets[2]
        got = kops.chain_forward(
            xp, stages, arrays,
            arena_offsets=tuple(o // 4 for o in plan.offsets),
            arena_words=plan.arena_bytes // 4)
        want = kops.chain_forward(xp, stages, arrays)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_geometry_halo_growth(self):
        """Entry tile = final tile grown through every window and stride."""
        stages = (StageSpec("conv", 3, 1, 1, 1, channels=32),
                  StageSpec("pool", 2, 2, channels=32),
                  StageSpec("conv", 3, 1, 1, 1, channels=32))
        geo = chain_geometry(stages, 16, 16, 4, 4)
        assert geo.out_tile[-1] == (4, 4)
        # conv3 tile 4 needs 6 pool rows; pool needs (6-1)*2+2 = 12 conv1
        # rows; conv1 needs (12-1)*1+3 = 14 entry rows
        assert geo.out_tile[1] == (6, 6)
        assert geo.out_tile[0] == (12, 12)
        assert geo.entry_tile == (14, 14)
        # origin affine: steps multiply through strides, offsets add pads
        assert geo.entry_step == (8, 8)
        assert geo.entry_off == (3, 3)


# --------------------------------------------------------------------------
# Region formation + vmem planning
# --------------------------------------------------------------------------

class TestRegions:

    @pytest.fixture(scope="class")
    def net(self):
        g, packed = _fused_graph(CHAIN_NET, (16, 16), seed=1)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.integers(0, 256, (2, 16, 16, 3)), jnp.uint8)
        ref = np.asarray(GraphExecutor(g, "xla")(x))
        return g, x, ref

    def test_partition_forms_maximal_chain(self, net):
        g, x, _ = net
        chains = partition_chains(g, x.shape)
        assert len(chains) == 1
        chain = chains[0]
        ops = [g.nodes[nid].op for nid in chain.node_ids]
        assert all(o in regions_mod.CHAIN_OPS for o in ops)
        assert len(chain.stages) == 5          # 2 fused pools decompose
        assert chain.plan.fits()
        assert chain.hbm_bytes_avoided() > 0

    def test_executor_regions_bit_exact_no_retrace(self, net):
        g, x, ref = net
        ex = chain_executor(g, x.shape)
        np.testing.assert_array_equal(np.asarray(ex(x)), ref)
        n = ex.trace_count
        ex(x)
        ex(x)
        assert ex.trace_count == n == 1
        rows = [r for r in ex.backend_report() if r["op"] == "chain"]
        assert rows and rows[0]["backend"] == "vpu_chain"

    def test_budget_splits_chain_and_stays_exact(self, net):
        """A tiny budget forces the run to split into shorter regions —
        the cut boundaries spill to HBM, results unchanged."""
        g, x, ref = net
        full = partition_chains(g, x.shape)[0]
        budget = full.plan.total_bytes() - 1
        chains = partition_chains(g, x.shape, vmem_budget=budget,
                                  min_nodes=1)
        assert len(chains) > 1
        assert all(c.plan.total_bytes() <= budget for c in chains)
        ex = GraphExecutor(g, "vpu_chain", regions=chains)
        np.testing.assert_array_equal(np.asarray(ex(x)), ref)

    def test_explicit_split_points_stay_exact(self, net):
        """build_chain at arbitrary boundaries (the fuzz axis's tool)."""
        g, x, ref = net
        ids = partition_chains(g, x.shape)[0].node_ids
        for cut in range(1, len(ids)):
            chains = [build_chain(g, ids[:cut], x.shape),
                      build_chain(g, ids[cut:], x.shape)]
            ex = GraphExecutor(g, "vpu_chain", regions=chains)
            np.testing.assert_array_equal(np.asarray(ex(x)), ref,
                                          err_msg=f"split at {cut}")

    def test_fanout_breaks_chain(self):
        """A branching consumer forces materialization: the branch point
        may head a region but never sit inside one."""
        g, packed = _fused_graph(CHAIN_NET, (16, 16), seed=1)
        chains = partition_chains(g, (1, 16, 16, 3))
        mid = chains[0].node_ids[1]
        # add a second consumer of `mid`
        g.add("or_pool", [mid], attrs=dict(window=2, stride=2,
                                           channels=g.nodes[mid]
                                           .attrs["channels"]))
        chains2 = partition_chains(g, (1, 16, 16, 3), min_nodes=1)
        for c in chains2:
            assert mid not in c.node_ids[:-1], c.node_ids

    def test_overlapping_regions_rejected(self, net):
        g, x, _ = net
        ids = partition_chains(g, x.shape)[0].node_ids
        a = build_chain(g, ids[:2], x.shape)
        b = build_chain(g, ids[1:], x.shape)
        with pytest.raises(ValueError, match="overlap"):
            GraphExecutor(g, "vpu_chain", regions=[a, b])

    def test_vmem_plan_invariants(self):
        plan = vmem_plan([1000, 2000, 3000, 500], budget=10_000,
                         fixed_bytes=100)
        # adjacent lifetimes overlap -> disjoint; i and i+2 may share
        for i in range(len(plan.offsets) - 1):
            a = (plan.offsets[i], plan.offsets[i] + plan.sizes[i])
            b = (plan.offsets[i + 1],
                 plan.offsets[i + 1] + plan.sizes[i + 1])
            assert a[1] <= b[0] or b[1] <= a[0]
        assert plan.arena_bytes < plan.naive_bytes()
        assert plan.total_bytes() == plan.arena_bytes + 100
        assert plan.fits()
        assert not vmem_plan([2 ** 24], budget=2 ** 20).fits()

    def test_nonpacked_maxpool_not_chainable(self):
        g = runtime.Graph(input_hw=(8, 8))
        inp = g.add("input", attrs=dict(channels=3))
        g.input_id = inp
        mp = g.add("maxpool_pm1", [inp], attrs=dict(window=2, stride=2,
                                                    channels=3))
        g.output_id = mp
        assert not regions_mod._chainable(g, mp)


# --------------------------------------------------------------------------
# Engine + serving integration
# --------------------------------------------------------------------------

class TestEngineChainMode:

    def test_engine_vpu_chain_cross_check(self):
        spec = CHAIN_NET + [BDense(4 * 4 * 32, 32), FloatDense(32, 10)]
        params = _randomize_bn(
            bnn_model.init_params(jax.random.key(4), spec), seed=9)
        engine = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                             matmul_mode="vpu_chain")
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.integers(0, 256, (2, 16, 16, 3)), jnp.uint8)
        engine.cross_check(x)  # asserts graph == legacy flat internally
        assert any(r["op"] == "chain" for r in engine.backend_choices)

    def test_served_buckets_chain_zero_retrace(self):
        """The serve path with regions enabled: every bucket bit-exact vs
        the cross_check oracle, trace_count flat while requests flow."""
        from tests import harness

        wl = harness.conformance_workload("yolov2_tiny_voc",
                                          matmul_mode="vpu_chain")
        harness.sweep_served_buckets(wl, buckets=(1, 2), n_requests=3)


# --------------------------------------------------------------------------
# Chain autotune: tile sweep + chain-shaped signature persistence
# --------------------------------------------------------------------------

class TestChainAutotune:

    def test_tile_winner_cached_and_exact(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                           str(tmp_path / "tune.json"))
        g, _ = _fused_graph(CHAIN_NET, (16, 16), seed=1)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.integers(0, 256, (1, 16, 16, 3)), jnp.uint8)
        ref = np.asarray(GraphExecutor(g, "xla")(x))

        from repro.obs import metrics as obs_metrics

        chains = partition_chains(g, x.shape)
        tuner = Autotuner(warmup=0, iters=1)
        with obs_metrics.use_registry() as reg:
            tuner.tune_chains(g, chains)
        keys = [k for k in tuner.cache if k.startswith("chain::")]
        assert len(keys) == len(chains) == 1
        # one structured miss event per freshly swept chain signature
        evs = reg.events("autotune")
        assert [e["outcome"] for e in evs] == ["miss"]
        assert evs[0]["op"] == "chain" and evs[0]["signature"] == keys[0]
        assert evs[0]["sweep_size"] >= 1
        assert reg.counter("autotune.miss").value == 1
        entry = tuner.cache[keys[0]]
        assert entry["winner"] == "vpu_chain"
        assert any(lbl.startswith("vpu_chain")
                   for lbl in entry["timings_ms"])

        # winner tile executes bit-exactly through the executor
        ex = GraphExecutor(g, "vpu_chain", regions=chains)
        np.testing.assert_array_equal(np.asarray(ex(x)), ref)

        # a fresh tuner warm-starts from disk: no re-timing
        tuner2 = Autotuner(warmup=0, iters=1)
        calls = []
        monkeypatch.setattr(
            Autotuner, "_tune_chain",
            lambda self, c, g: calls.append(c) or {"winner": "vpu_chain",
                                                   "tile": {}})
        chains2 = partition_chains(g, x.shape)
        with obs_metrics.use_registry() as reg2:
            tuner2.tune_chains(g, chains2)
        assert not calls, "disk-cached chain winner was re-timed"
        assert chains2[0].tile == chains[0].tile
        assert reg2.counter("autotune.disk_hit").value == 1
        assert reg2.counter("autotune.miss").value == 0

    def test_candidates_respect_budget(self):
        g, _ = _fused_graph(CHAIN_NET, (16, 16), seed=1)
        chain = partition_chains(g, (1, 16, 16, 3))[0]
        from repro.runtime.autotune import _chain_tile_candidates

        cands = _chain_tile_candidates(chain)
        assert {} in cands and len(cands) >= 2
        for tile in cands:
            assert regions_mod.plan_chain_vmem(
                chain.stages, chain.in_shape, tile=tile,
                budget=chain.plan.budget).fits()


# --------------------------------------------------------------------------
# Memory-plan report regression (satellite): pool-fused outputs count
# against the *producing* node's schedule index, not the consumer's
# --------------------------------------------------------------------------

class TestMemoryReportBirth:

    def test_births_match_hand_schedule(self):
        g, _ = _fused_graph(CHAIN_NET, (16, 16), seed=1)
        schedule = g.topo_order()
        pos = {nid: i for i, nid in enumerate(schedule)}
        cons = g.consumers()
        plan = plan_memory(g, (1, 16, 16, 3))
        fused = [b for b in plan.buffers.values()
                 if b.op == "packed_conv_pool"]
        assert fused, "expected pool-fused intermediates in the plan"
        for b in plan.buffers.values():
            assert b.birth == pos[b.node_id], (
                f"{b.op} (node {b.node_id}) born at {b.birth}, "
                f"produced at schedule index {pos[b.node_id]}")
            assert b.death == max(pos[u] for u in cons[b.node_id])
        # and report() rows carry the same indices
        by_node = {r["node"]: r for r in plan.report()}
        for b in plan.buffers.values():
            assert by_node[b.node_id]["birth"] == pos[b.node_id]
