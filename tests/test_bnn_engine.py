"""End-to-end: packed PhoneBit engine == float oracle, + converter artifact."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bnn_model, binary_conv, converter, packing
from repro.core.bnn_model import BConv, BDense, FloatDense, Pool


def tiny_net():
    """A miniature AlexNet-style BNN: conv1(first) -> pool -> conv2 -> pool
    -> bdense -> float head."""
    return [
        BConv(c_in=3, c_out=16, kernel=3, stride=1, pad=1, first=True),
        Pool(window=2, stride=2),
        BConv(c_in=16, c_out=40, kernel=3, stride=1, pad=1),
        Pool(window=2, stride=2),
        BDense(d_in=4 * 4 * 40, d_out=64),
        FloatDense(d_in=64, d_out=10),
    ]


@pytest.fixture(scope="module")
def trained():
    spec = tiny_net()
    params = bnn_model.init_params(jax.random.key(0), spec)
    # randomize BN stats so thresholds are non-trivial
    rng = np.random.default_rng(42)
    for p in params:
        if "mu" in p:
            o = p["mu"].shape[0]
            p["mu"] = jnp.asarray(rng.uniform(-20, 20, o), jnp.float32)
            p["var"] = jnp.asarray(rng.uniform(0.5, 4, o), jnp.float32)
            p["gamma"] = jnp.asarray(rng.uniform(-1.5, 1.5, o), jnp.float32)
            p["beta"] = jnp.asarray(rng.uniform(-1, 1, o), jnp.float32)
    return spec, params


def test_packed_engine_matches_float_oracle(trained):
    spec, params = trained
    packed = converter.convert(params, spec, input_hw=(16, 16))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, size=(4, 16, 16, 3)), jnp.uint8)
    ref = bnn_model.float_forward(params, spec, x)
    got = bnn_model.packed_forward(packed, spec, x)
    assert got.shape == ref.shape == (4, 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0, atol=1e-3)


def test_intermediate_binary_activations_match(trained):
    """Layerwise: the packed bits equal the oracle's sign bits."""
    spec, params = trained
    packed = converter.convert(params, spec, input_hw=(16, 16))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 256, size=(2, 16, 16, 3)), jnp.uint8)

    # float path up to after conv2+pool2
    f = bnn_model.float_forward(params, spec[:4], x)  # runs conv1,pool,conv2,pool
    # packed path same prefix
    from repro.core import bitplanes
    planes = bitplanes.pack_bitplanes(x)
    n, h, w, np_, cw = planes.shape
    flat = planes.reshape(n, h, w, np_ * cw)
    l0 = spec[0]
    y = binary_conv.binary_conv2d_fused(
        flat, packed[0]["w_packed"], packed[0]["thresh"], l0.kernel, l0.kernel,
        l0.stride, l0.pad, word_weights=packed[0]["word_weights"])
    y = binary_conv.binary_or_maxpool(y, 2, 2)
    l2 = spec[2]
    y = binary_conv.binary_conv2d_fused(
        y, packed[2]["w_packed"], packed[2]["thresh"], l2.kernel, l2.kernel,
        l2.stride, l2.pad)
    y = binary_conv.binary_or_maxpool(y, 2, 2)
    bits = packing.unpack_bits(y, 40)
    ref_bits = (np.asarray(f) >= 0).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(bits), ref_bits)


def test_training_step_decreases_loss(trained):
    """STE training on the float path actually learns (tiny synthetic task)."""
    spec, params = trained
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(0, 256, size=(32, 16, 16, 3)), jnp.uint8)
    labels = jnp.asarray(rng.integers(0, 10, size=(32,)), jnp.int32)

    def loss_fn(ps):
        logits = bnn_model.float_forward(ps, spec, x, train=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    # gradient wrt latent conv weights must be nonzero (STE passes through)
    g = grads[0]["w"]
    assert float(jnp.abs(g).sum()) > 0
    params2 = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, grads)
    loss1 = loss_fn(params2)
    assert float(loss1) < float(loss0)


def test_artifact_roundtrip(tmp_path, trained):
    spec, params = trained
    packed = converter.convert(params, spec, input_hw=(16, 16))
    path = str(tmp_path / "model.npz")
    converter.save_artifact(path, packed)
    loaded = converter.load_artifact(path)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 256, size=(2, 16, 16, 3)), jnp.uint8)
    a = bnn_model.packed_forward(packed, spec, x)
    b = bnn_model.packed_forward(loaded, spec, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_ratio(trained):
    """Tab II: packed model is much smaller (conv-dominated nets ~<1/19.6)."""
    spec, params = trained
    packed = converter.convert(params, spec, input_hw=(16, 16))
    fp = converter.float_model_bytes(params)
    bp = converter.model_bytes(packed)
    assert bp * 8 < fp  # at least 8x for this tiny net (dense head is float)
