"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates its REDUCED same-family config and runs
one forward / train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only by the dry-run (no allocation here).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import (convnext, dit, efficientnet, transformer, vit)
from repro.optim import adamw_init, sgdm_init

LM_ARCHS = ["granite-moe-3b-a800m", "qwen3-moe-30b-a3b", "minitron-8b",
            "command-r-35b"]
DIT_ARCHS = ["dit-l2", "dit-xl2"]
VIT_ARCHS = ["vit-l16", "vit-h14"]


@pytest.fixture(scope="module")
def mesh_rules():
    mesh = make_host_mesh(data=1, model=1)
    with mesh:
        yield rules_for_mesh(mesh)


def _no_nan(x):
    assert not bool(jnp.isnan(x).any()), "NaNs in output"


# --------------------------------------------------------------------------
# LM family
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_train(arch, mesh_rules):
    rules = mesh_rules
    cfg = configs.get(arch).smoke
    params = transformer.init_params(jax.random.key(0), cfg, ep=rules.tp)
    b, s = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    logits, aux = jax.jit(
        lambda p, t: transformer.forward(p, t, cfg, rules))(params, tokens)
    assert logits.shape == (b, s, cfg.vocab)
    _no_nan(logits)

    step = jax.jit(transformer.make_train_step(cfg, rules))
    opt = adamw_init(params)
    batch = {"tokens": tokens, "labels": tokens}
    p2, o2, m = step(params, opt, batch)
    assert float(m["loss"]) > 0 and np.isfinite(float(m["loss"]))
    # params actually moved
    delta = jax.tree.map(
        lambda a, b_: float(jnp.abs(a - b_).max()), params, p2)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", LM_ARCHS[:2])  # the two MoE archs
def test_lm_smoke_decode(arch, mesh_rules):
    rules = mesh_rules
    cfg = configs.get(arch).smoke
    params = transformer.init_params(jax.random.key(0), cfg, ep=rules.tp)
    b, max_seq = 2, 16
    cache = transformer.init_cache(cfg, b, max_seq)
    step = jax.jit(transformer.make_decode_step(cfg, rules, max_seq))
    tok = jnp.ones((b, 1), jnp.int32)
    for pos in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
    assert logits.shape == (b, cfg.vocab)
    _no_nan(logits)
    # the cache filled the first 3 positions of every layer
    assert float(jnp.abs(cache["k"][:, :, :, :3]).sum()) > 0
    assert float(jnp.abs(cache["k"][:, :, :, 3:]).sum()) == 0


def test_lm_decode_matches_forward(mesh_rules):
    """Greedy decode logits == full-forward logits position by position."""
    rules = mesh_rules
    cfg = configs.get("minitron-8b").smoke
    params = transformer.init_params(jax.random.key(0), cfg, ep=rules.tp)
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    full_logits, _ = transformer.forward(params, tokens, cfg, rules)

    cache = transformer.init_cache(cfg, b, s)
    step = jax.jit(transformer.make_decode_step(cfg, rules, s))
    for pos in range(s):
        logits, cache = step(params, cache, tokens[:, pos:pos + 1],
                             jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, pos, :], np.float32),
            rtol=2e-2, atol=2e-2)


def test_lm_prefill_matches_decode_cache(mesh_rules):
    rules = mesh_rules
    cfg = configs.get("command-r-35b").smoke
    params = transformer.init_params(jax.random.key(0), cfg, ep=rules.tp)
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    prefill = jax.jit(transformer.make_prefill_step(cfg, rules, s))
    logits_p, cache_p = prefill(params, tokens)

    cache_d = transformer.init_cache(cfg, b, s)
    step = jax.jit(transformer.make_decode_step(cfg, rules, s))
    for pos in range(s):
        logits_d, cache_d = step(params, cache_d, tokens[:, pos:pos + 1],
                                 jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(cache_p["k"], np.float32),
                               np.asarray(cache_d["k"], np.float32),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(logits_d, np.float32),
                               rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------------
# Diffusion family
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", DIT_ARCHS)
def test_dit_smoke(arch, mesh_rules):
    rules = mesh_rules
    cfg = configs.get(arch).smoke
    params = dit.init_params(jax.random.key(0), cfg)
    b = 2
    lat = cfg.latent_res()
    x = jax.random.normal(jax.random.key(1),
                          (b, lat, lat, cfg.latent_channels))
    t = jnp.array([3, 7])
    labels = jnp.zeros((b,), jnp.int32)
    eps, sigma = jax.jit(
        lambda p, x_: dit.forward(p, x_, t, labels, cfg, rules))(params, x)
    assert eps.shape == x.shape and sigma.shape == x.shape
    _no_nan(eps)

    step = jax.jit(dit.make_train_step(cfg, rules))
    batch = {"latents": x, "labels": labels, "t": t,
             "noise": jax.random.normal(jax.random.key(2), x.shape)}
    _, _, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))

    sample = jax.jit(dit.make_sample_step(cfg, rules))
    x2 = sample(params, x.astype(jnp.bfloat16), t, t - 1, labels)
    assert x2.shape == x.shape
    _no_nan(x2.astype(jnp.float32))


# --------------------------------------------------------------------------
# Vision family
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", VIT_ARCHS)
def test_vit_smoke(arch, mesh_rules):
    rules = mesh_rules
    cfg = configs.get(arch).smoke
    params = vit.init_params(jax.random.key(0), cfg)
    b = 2
    imgs = jax.random.uniform(jax.random.key(1),
                              (b, cfg.img_res, cfg.img_res, 3))
    logits = jax.jit(
        lambda p, x: vit.forward(p, x, cfg, rules))(params, imgs)
    assert logits.shape == (b, cfg.n_classes)
    _no_nan(logits)
    step = jax.jit(vit.make_train_step(cfg, rules))
    _, _, m = step(params, adamw_init(params),
                   {"images": imgs, "labels": jnp.zeros((b,), jnp.int32)})
    assert np.isfinite(float(m["loss"]))


def test_convnext_smoke(mesh_rules):
    rules = mesh_rules
    cfg = configs.get("convnext-b").smoke
    params = convnext.init_params(jax.random.key(0), cfg)
    b = 2
    imgs = jax.random.uniform(jax.random.key(1),
                              (b, cfg.img_res, cfg.img_res, 3))
    logits = jax.jit(
        lambda p, x: convnext.forward(p, x, cfg, rules))(params, imgs)
    assert logits.shape == (b, cfg.n_classes)
    _no_nan(logits)
    step = jax.jit(convnext.make_train_step(cfg, rules))
    _, _, m = step(params, adamw_init(params),
                   {"images": imgs, "labels": jnp.zeros((b,), jnp.int32)})
    assert np.isfinite(float(m["loss"]))


def test_efficientnet_smoke(mesh_rules):
    rules = mesh_rules
    cfg = configs.get("efficientnet-b7").smoke
    params, state = efficientnet.init_params(jax.random.key(0), cfg)
    b = 2
    imgs = jax.random.uniform(jax.random.key(1),
                              (b, cfg.img_res, cfg.img_res, 3))
    logits, _ = jax.jit(
        lambda p, s, x: efficientnet.apply(p, s, x, cfg, rules,
                                           train=False))(params, state,
                                                         imgs)
    assert logits.shape == (b, cfg.n_classes)
    _no_nan(logits)
    step = jax.jit(efficientnet.make_train_step(cfg, rules))
    p2, s2, o2, m = step(params, state, sgdm_init(params),
                         {"images": imgs,
                          "labels": jnp.zeros((b,), jnp.int32)})
    assert np.isfinite(float(m["loss"]))
    # BN running stats updated
    assert float(jnp.abs(s2["stem_bn"]["mean"]
                         - state["stem_bn"]["mean"]).sum()) > 0


def test_unroll_matches_scan(mesh_rules):
    """The dry-run's unrolled probe path is numerically identical."""
    rules = mesh_rules
    import dataclasses
    cfg = configs.get("minitron-8b").smoke
    params = transformer.init_params(jax.random.key(0), cfg, ep=rules.tp)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    lg1, _ = jax.jit(
        lambda p, t: transformer.forward(p, t, cfg, rules))(params, tokens)
    cfg_u = dataclasses.replace(cfg, unroll=True)
    lg2, _ = jax.jit(
        lambda p, t: transformer.forward(p, t, cfg_u, rules))(params,
                                                              tokens)
    # bf16 compute: scan vs unroll fuse/reorder differently
    np.testing.assert_allclose(np.asarray(lg1, np.float32),
                               np.asarray(lg2, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_binary_variants_run(mesh_rules):
    """PhoneBit-technique variants of the applicable archs (DESIGN §6)."""
    import dataclasses
    rules = mesh_rules
    b = 2
    vcfg = dataclasses.replace(configs.get("vit-l16").smoke,
                               binary_dense=True)
    params = vit.init_params(jax.random.key(0), vcfg)
    imgs = jax.random.uniform(jax.random.key(1),
                              (b, vcfg.img_res, vcfg.img_res, 3))
    logits = vit.forward(params, imgs, vcfg, rules)
    _no_nan(logits)
    # gradient flows through the STE
    step = jax.jit(vit.make_train_step(vcfg, rules))
    p2, _, m = step(params, adamw_init(params),
                    {"images": imgs, "labels": jnp.zeros((b,), jnp.int32)})
    assert np.isfinite(float(m["loss"]))
    assert float(jnp.abs(p2["layers"]["wqkv"]
                         - params["layers"]["wqkv"]).max()) > 0

    ccfg = dataclasses.replace(configs.get("convnext-b").smoke,
                               binary_pointwise=True)
    cparams = convnext.init_params(jax.random.key(0), ccfg)
    cl = convnext.forward(cparams, imgs, ccfg, rules)
    _no_nan(cl)
