"""Cross-backend workload conformance harness (DESIGN.md §8.4).

The machinery behind ``tests/test_workloads.py``:

* **conformance workloads** — the three paper nets at conformance scale
  (tiny topology-preserving variants; the real YOLOv2-Tiny spec is also
  swept at reduced resolution since it is fully convolutional), built
  from seeded checkpoints so every run reconstructs identical bits;
* **backend sweep** — run one workload's raw network output and decoded
  predictions under every executor backend and assert bit-exactness
  against the ``xla`` reference (pairwise equality follows);
* **served-bucket sweep** — stream requests through an
  ``InferenceServer`` at every bucket size and assert each served row is
  bit-exact vs the engine's ``cross_check`` oracle (which itself asserts
  graph == legacy-flat), with zero serve-time retraces;
* **golden fixtures** — tiny seeded inputs and expected outputs per net
  in ``tests/golden/*.npz``.  The *packed* artifact (the last packed
  layer's channel-packed words — integer end to end) is compared
  bit-exactly; the float head and decoded predictions use tight
  tolerances so fixtures survive BLAS/XLA version changes.  Regenerate
  with ``pytest tests/test_workloads.py --regen-golden``.
"""

from __future__ import annotations

import pathlib

import jax.numpy as jnp
import numpy as np

from repro import workloads
from repro.core import bnn_model, converter
from repro.runtime.executor import ALL_MODES, BACKENDS  # noqa: F401
from repro.workloads import DetectConfig

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"

# Low-threshold detect config so seeded random weights still yield boxes.
CONFORMANCE_DETECT = DetectConfig(score_thresh=0.02, iou_thresh=0.45,
                                  max_det=8)

SEED = 7


def conformance_workload(name: str, *, matmul_mode: str = "xla"
                         ) -> workloads.Workload:
    """One conformance-scale workload, deterministic in (name, SEED)."""
    kw: dict = dict(variant="tiny", seed=SEED, matmul_mode=matmul_mode)
    if name == "yolov2_tiny_voc":
        kw["detect"] = CONFORMANCE_DETECT
    return workloads.get(name, **kw)


CONFORMANCE_NAMES = ("alexnet_imagenet", "vgg16_imagenet",
                     "yolov2_tiny_voc")


def seeded_batch(wl: workloads.Workload, batch: int = 2,
                 seed: int = SEED) -> jnp.ndarray:
    """Network-size uint8 inputs, deterministic in (shape, seed)."""
    h, w = wl.input_hw
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, (batch, h, w, 3)), jnp.uint8)


def packed_tail(wl: workloads.Workload, x: jnp.ndarray) -> np.ndarray:
    """The last packed layer's output words: the integer (bit-exact)
    engine artifact, before the float head touches anything."""
    spec = wl.spec
    cut = len(spec)
    while cut > 0 and isinstance(spec[cut - 1],
                                 (bnn_model.FloatDense,
                                  bnn_model.FloatConv)):
        cut -= 1
    packed = converter.convert(wl.params, spec, wl.input_hw)
    out = bnn_model.packed_forward(packed[:cut], spec[:cut], x)
    assert out.dtype in (jnp.int32, jnp.uint32), out.dtype  # packed words
    return np.asarray(out)


# --------------------------------------------------------------------------
# Sweeps
# --------------------------------------------------------------------------

def sweep_backends(name: str, x: jnp.ndarray | None = None,
                   backends: tuple[str, ...] = ALL_MODES) -> dict:
    """Every backend's (raw, decoded) outputs for one workload; asserts
    bit-exactness vs the ``xla`` reference and returns the reference."""
    ref_wl = conformance_workload(name, matmul_mode="xla")
    x = seeded_batch(ref_wl) if x is None else x

    def raw_and_decoded(wl):
        # One forward per backend: decode the raw output directly rather
        # than re-running the (interpret-mode-slow) network via engine().
        raw = wl.engine.raw(x)
        return np.asarray(raw), np.asarray(wl.engine._head_jit(raw))

    ref_raw, ref_dec = raw_and_decoded(ref_wl)
    for backend in backends:
        if backend == "xla":
            continue
        got_raw, got_dec = raw_and_decoded(
            conformance_workload(name, matmul_mode=backend))
        np.testing.assert_array_equal(
            got_raw, ref_raw,
            err_msg=f"{name}: raw output diverges on {backend}")
        np.testing.assert_array_equal(
            got_dec, ref_dec,
            err_msg=f"{name}: decoded predictions diverge on {backend}")
    return dict(raw=ref_raw, decoded=ref_dec, x=np.asarray(x))


def sweep_served_buckets(wl: workloads.Workload,
                         buckets: tuple[int, ...] = (1, 2, 4),
                         n_requests: int = 6, raw_hw=(44, 60)) -> None:
    """Serve off-network-size requests through every bucket size and
    assert each decoded row is bit-exact vs the cross_check oracle, with
    zero serve-time retraces.

    The reference reproduces each group's exact padded batch layout
    (same preprocessing hook, same zero-fill rows): XLA float kernels
    may differ in the last ulp between *row positions* within a batch,
    so bit-exactness is defined against the batch the server actually
    executed — which cross_check then also pins against the legacy flat
    path.
    """
    server = wl.server(max_batch=max(buckets), max_wait_s=0.0,
                       buckets=buckets)
    server.compile_buckets()
    before = wl.engine.trace_count
    rng = np.random.default_rng(SEED)
    imgs = [rng.integers(0, 256, (*raw_hw, 3), dtype=np.uint8)
            for _ in range(n_requests)]

    # Mixed group sizes force every bucket — groups that land between
    # bucket sizes serve zero-padded.
    groups: list[tuple[list, list]] = []        # (requests, padded batch)
    served = 0
    for group in (1, 2, n_requests - 3):
        if group <= 0:
            continue
        batch = imgs[served:served + group]
        reqs = [server.submit(im) for im in batch]
        server.drain()
        served += group
        bucket = server.scheduler.bucket_for(group)
        groups.append(
            (reqs, batch + [np.zeros_like(batch[-1])] * (bucket - group)))
    assert served == n_requests
    assert wl.engine.trace_count == before, "serve-time retrace"
    assert server.metrics()["served"] == n_requests

    # References after the trace assertion: cross_check compiles its own
    # (non-donated) executors, which is warmup, not a serve-time retrace.
    for reqs, padded in groups:
        x = jnp.asarray(np.stack([wl.preprocess_hook(p) for p in padded]))
        ref = np.asarray(wl.engine.cross_check(x))
        for req, expect in zip(reqs, ref):
            np.testing.assert_array_equal(np.asarray(req.result), expect)


# --------------------------------------------------------------------------
# Golden fixtures
# --------------------------------------------------------------------------

def golden_path(name: str) -> pathlib.Path:
    return GOLDEN_DIR / f"{name}.npz"


def compute_golden(name: str) -> dict[str, np.ndarray]:
    """The golden payload for one net: seeded input, packed-tail words,
    raw float output, decoded predictions."""
    wl = conformance_workload(name)
    x = seeded_batch(wl)
    return dict(x=np.asarray(x),
                packed_tail=packed_tail(wl, x),
                raw=np.asarray(wl.engine.raw(x)),
                decoded=np.asarray(wl.engine(x)))


def save_golden(name: str, payload: dict[str, np.ndarray]) -> pathlib.Path:
    GOLDEN_DIR.mkdir(exist_ok=True)
    path = golden_path(name)
    np.savez_compressed(path, **payload)
    return path


def load_golden(name: str) -> dict[str, np.ndarray]:
    with np.load(golden_path(name)) as z:
        return {k: z[k] for k in z.files}


def check_golden(name: str, *, regen: bool = False) -> None:
    """Compare today's outputs against the checked-in fixture.

    The input and the packed tail must match bit-for-bit (pure integer
    path).  The float head and decoded boxes/probabilities get 1e-4
    absolute tolerance; decoded class indices and the detection validity
    mask must match exactly.
    """
    fresh = compute_golden(name)
    if regen or not golden_path(name).exists():
        save_golden(name, fresh)
    golden = load_golden(name)
    assert set(golden) == set(fresh), (set(golden), set(fresh))
    np.testing.assert_array_equal(fresh["x"], golden["x"])
    np.testing.assert_array_equal(
        fresh["packed_tail"], golden["packed_tail"],
        err_msg=f"{name}: packed integer artifact regressed")
    np.testing.assert_allclose(fresh["raw"], golden["raw"],
                               rtol=0, atol=1e-4)
    got_d, want_d = fresh["decoded"], golden["decoded"]
    assert got_d.shape == want_d.shape
    if conformance_workload(name).task == "classify":
        # rows are [class_index, probability]: indices exact, probs close
        np.testing.assert_array_equal(got_d[..., 0], want_d[..., 0])
    else:
        # rows are [x1 y1 x2 y2 score class]: the surviving-detection
        # mask and each survivor's class must match exactly
        np.testing.assert_array_equal(got_d[..., 4] > 0,
                                      want_d[..., 4] > 0)
        np.testing.assert_array_equal(got_d[..., 5], want_d[..., 5])
    np.testing.assert_allclose(got_d, want_d, rtol=0, atol=1e-4)
