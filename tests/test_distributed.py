"""Distribution-layer tests that need multiple devices.

Each test runs its scenario in a SUBPROCESS with
``--xla_force_host_platform_device_count=8``: the placeholder-device flag
must never leak into this pytest process (smoke tests see 1 device, per the
dry-run contract).  Scenarios assert internally and exit non-zero on
failure.
"""

import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import _mesh  # AxisType version-compat
mesh = _mesh((2, 4), ("data", "model"))
from repro.distributed.sharding import rules_for_mesh
rules = rules_for_mesh(mesh)
"""


def _run(body: str, timeout: int = 420) -> None:
    script = _PRELUDE.format(src=str(REPO / "src")) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=timeout,
                       env=dict(os.environ))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"


def test_pipeline_matches_sequential():
    _run("""
    from repro.distributed import pipeline as pp

    D, L, B = 8, 4, 16
    key = jax.random.key(0)
    ws = jax.random.normal(key, (L, D, D)) / np.sqrt(D)
    x = jax.random.normal(jax.random.key(1), (B, D))

    def layer(h, w):
        return jnp.tanh(h @ w)

    # sequential oracle
    ref = x
    for i in range(L):
        ref = layer(ref, ws[i])

    # 4-stage pipeline over the model axis, 4 microbatches
    stage_params = pp.stack_stages(ws, 4)
    stage_fn = pp.make_stage_fn(lambda h, w: layer(h, w))
    out = pp.pipeline_apply(stage_fn, stage_params, x, mesh=mesh,
                            axis="model", n_microbatches=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("pipeline OK")
    """)


def test_moe_sharded_matches_reference():
    _run("""
    from repro.models import moe as moe_lib

    t, d, e, k, fe = 64, 16, 8, 2, 32
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, e)) * 0.1
    wg = jax.random.normal(ks[2], (e, d, fe)) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (e, d, fe)) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (e, fe, d)) / np.sqrt(fe)

    with mesh:
        out, aux = jax.jit(lambda *a: moe_lib.moe_apply(
            *a, n_experts=e, top_k=k, capacity_factor=float(e),
            rules=rules, token_axes=("data", "model")))(
                x, router, wg, wu, wd)
    ref = moe_lib.moe_reference(x, router, wg, wu, wd, n_experts=e,
                                top_k=k)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
    # tokens replicated over model (decode path) must agree too
    with mesh:
        out2, _ = jax.jit(lambda *a: moe_lib.moe_apply(
            *a, n_experts=e, top_k=k, capacity_factor=float(e),
            rules=rules, token_axes=("data",)))(x, router, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out2, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
    print("moe OK")
    """)


def test_grad_compression_error_feedback():
    _run("""
    from repro.distributed import compression

    g = {"w": jax.random.normal(jax.random.key(0), (64, 64)),
         "b": jax.random.normal(jax.random.key(1), (64,)) * 1e-3}
    dq1, err1 = compression.compress_decompress(g, None)
    # error feedback: residual + quantized == original (per leaf)
    for k in g:
        np.testing.assert_allclose(
            np.asarray(dq1[k] + err1[k]), np.asarray(g[k]), rtol=1e-5,
            atol=1e-6)
    # repeated application with EF: accumulated quantized sum converges
    # to the true sum (the EF guarantee)
    total_dq = jax.tree.map(jnp.zeros_like, g)
    err = None
    for i in range(32):
        dq, err = compression.compress_decompress(g, err)
        total_dq = jax.tree.map(lambda a, b: a + b, total_dq, dq)
    for k in g:
        np.testing.assert_allclose(np.asarray(total_dq[k]) / 32,
                                   np.asarray(g[k]), rtol=2e-2,
                                   atol=2e-3)
    print("compression OK")
    """)


def test_elastic_restore_different_mesh():
    _run("""
    import tempfile
    from repro.checkpoint import save, restore
    from repro.models import transformer
    from repro import configs
    from repro.launch.mesh import make_host_mesh

    cfg = configs.get("minitron-8b").smoke
    mesh_a = make_host_mesh(data=2, model=4)
    rules_a = rules_for_mesh(mesh_a)
    with mesh_a:
        psh_a = rules_a.tree_shardings(transformer.param_specs(cfg, rules_a))
        params = jax.jit(lambda k: transformer.init_params(k, cfg, ep=4),
                         out_shardings=psh_a)(jax.random.key(0))
    d = tempfile.mkdtemp()
    save(d, 3, params)

    # "node failure": restart on a smaller 4-device mesh
    mesh_b = make_host_mesh(data=4, model=1)
    rules_b = rules_for_mesh(mesh_b)
    with mesh_b:
        psh_b = rules_b.tree_shardings(transformer.param_specs(cfg, rules_b))
        like = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg, ep=4),
            jax.random.key(0))
        restored = restore(d, 3, like, psh_b)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored arrays carry mesh_b shardings
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 4, "model": 1}
    print("elastic OK")
    """)


def test_sharded_lm_matches_single_device():
    """The same smoke LM produces identical logits on (2,4) vs (1,1)."""
    _run("""
    from repro.models import transformer
    from repro import configs
    from repro.launch.mesh import make_host_mesh

    cfg = configs.get("qwen3-moe-30b-a3b").smoke
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = transformer.init_params(jax.random.key(0), cfg, ep=4)
    tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)

    with mesh:  # (2, 4)
        lg_sharded, _ = jax.jit(
            lambda p, t: transformer.forward(p, t, cfg, rules))(params,
                                                                tokens)
    mesh1 = make_host_mesh(data=1, model=1)
    rules1 = rules_for_mesh(mesh1)
    # ep=4-padded weights work on a 1-device mesh too (padding is in E)
    with mesh1:
        lg_single, _ = jax.jit(
            lambda p, t: transformer.forward(p, t, cfg, rules1))(params,
                                                                 tokens)
    a = np.asarray(lg_sharded, np.float32)
    b = np.asarray(lg_single, np.float32)
    # bf16 end-to-end: partitioning changes accumulation order; a small
    # tail of logits drifts ~0.2 abs.  Assert tight agreement in bulk +
    # near-perfect argmax agreement (the decision-relevant quantity).
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.25)
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    # random-init logits have many near-ties; >95% argmax agreement is
    # the bf16-noise-tolerant bar
    assert agree > 0.95, agree
    print("sharded-vs-single OK", agree)
    """, timeout=560)


def test_pod_compressed_mean():
    _run("""
    from repro.distributed import compression

    mesh3 = _mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"w": jax.random.normal(jax.random.key(0), (32, 32))}
    with mesh3:
        out, err = jax.jit(lambda g_: compression.pod_compressed_mean(
            g_, None, mesh3))(g)
    # all pods held identical grads -> mean == dequantized original
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(g["w"]), rtol=2e-2, atol=2e-2)
    print("pod compression OK")
    """)
