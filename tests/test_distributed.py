"""Multi-device scale-out tests on the runtime-IR placement surface
(DESIGN.md §13).

Two tiers:

* **In-process (1 device)** — the placement pass is pure graph math
  and the staged executor runs fine with every stage on one device, so
  cut-candidate/plan properties, stage-subgraph parity, replica
  routing, and straggler deprioritization are all pinned inside the
  normal tier-1 run.
* **Forced-mesh subprocesses** — scenarios that need real multiple
  devices run in a SUBPROCESS with
  ``--xla_force_host_platform_device_count=N``: the placeholder-device
  flag must never leak into this pytest process (smoke tests see 1
  device, per the dry-run contract).  Scenarios assert internally and
  exit non-zero on failure.  The parity bar matches the backend-pair
  fuzz: packed int32 tails bit-exact, float heads 1e-4 (placement —
  like backend choice — may change XLA fusion and thus last-ulp float
  accumulation, never the packed computation).
"""

import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
os.environ["REPRO_AUTOTUNE_CACHE"] = "0"
import sys; sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
import numpy as np
"""

# The tiny nets the serving tests standardize on: a float head (logits)
# and a packed tail (int32 words — the bit-exact surface).
_ENGINES = """
from repro.core import bnn_model
from repro.core.bnn_model import BConv, BDense, FloatDense, Pool
from repro.serving import PhoneBitEngine

def tiny_engine(tail="float"):
    if tail == "float":
        spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
                Pool(2, 2), FloatDense(8 * 8 * 32, 10)]
    else:
        spec = [BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
                BConv(32, 32, kernel=3, stride=1, pad=1),
                Pool(2, 2), BDense(8 * 8 * 32, 64)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    return PhoneBitEngine.from_trained(params, spec, (16, 16))
"""


def _run(body: str, n_dev: int = 8, timeout: int = 420,
         setup: str = "") -> str:
    # setup (unindented module text) and body (indented in the caller)
    # concatenate only after dedent — mixed indents defeat dedent.
    script = (_PRELUDE.format(src=str(REPO / "src"), n_dev=n_dev)
              + setup + textwrap.dedent(body))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=timeout,
                       env=dict(os.environ))
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# --------------------------------------------------------------------------
# Placement pass: pure graph math, in-process
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engines():
    import jax

    from repro.core import bnn_model
    from repro.core.bnn_model import BConv, BDense, FloatDense, Pool
    from repro.serving import PhoneBitEngine

    def build(spec):
        params = bnn_model.init_params(jax.random.key(0), spec)
        return PhoneBitEngine.from_trained(params, spec, (16, 16))

    return {
        "float": build([BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
                        Pool(2, 2), FloatDense(8 * 8 * 32, 10)]),
        "packed": build([BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
                         BConv(32, 32, kernel=3, stride=1, pad=1),
                         Pool(2, 2), BDense(8 * 8 * 32, 64)]),
    }


class TestPlacementPass:
    def test_cut_candidates_are_single_live_crossings(self, engines):
        from repro import runtime

        g = engines["packed"]._graph
        schedule = g.topo_order()
        pos = {nid: i for i, nid in enumerate(schedule)}
        cons = g.consumers()
        cands = runtime.cut_candidates(g)
        assert cands, "a linear BNN graph must offer cut points"
        for i, boundary in cands:
            live = [nid for nid in schedule[:i + 1]
                    if any(pos[c] > i for c in cons[nid])
                    or nid == g.output_id]
            assert live == [boundary]
            # the boundary is the last node of its stage (topo order)
            assert boundary == schedule[i]

    def test_forbidden_interiors_excluded(self, engines):
        from repro import runtime
        from repro.runtime.placement import chain_interiors

        g = engines["packed"]._graph
        chains = runtime.partition_chains(g, (1, 16, 16, 3))
        if not chains:
            pytest.skip("net formed no chains at this budget")
        forbidden = chain_interiors(chains)
        cands = runtime.cut_candidates(g, forbidden)
        for _, boundary in cands:
            assert boundary not in forbidden
        # chain tails stay legal boundaries — they ARE the HBM touch
        # points region formation already identified
        tails = {c.node_ids[-1] for c in chains}
        assert tails & {b for _, b in cands}

    def test_plan_covers_schedule_in_order(self, engines):
        from repro import runtime

        g = engines["float"]._graph
        plan = runtime.plan_pipeline(g, (2, 16, 16, 3), 2)
        flat = [nid for stage in plan.stages for nid in stage]
        assert flat == g.topo_order()
        assert len(plan.boundaries) == plan.n_stages - 1
        for stage, b in zip(plan.stages, plan.boundaries):
            assert b == stage[-1]      # produced by its own stage
        assert len(plan.costs) == plan.n_stages
        assert all(c >= 0 for c in plan.costs)

    def test_plan_degrades_when_graph_offers_fewer_cuts(self, engines):
        from repro import runtime

        g = engines["float"]._graph
        plan = runtime.plan_pipeline(g, (1, 16, 16, 3), 99)
        assert 1 <= plan.n_stages <= len(runtime.cut_candidates(g)) + 1
        assert plan.n_stages < 99

    def test_plan_balances_cost(self, engines):
        from repro import runtime

        g = engines["packed"]._graph
        plan = runtime.plan_pipeline(g, (4, 16, 16, 3), 2)
        if plan.n_stages < 2:
            pytest.skip("no legal 2-stage split")
        # the DP must beat the most lopsided legal split
        worst = sum(plan.costs)
        assert max(plan.costs) < worst
        rep = plan.report()
        assert abs(sum(r["share"] for r in rep) - 1.0) < 1e-6

    def test_stage_subgraphs_validate_and_keep_ids(self, engines):
        from repro import runtime

        g = engines["packed"]._graph
        plan = runtime.plan_pipeline(g, (1, 16, 16, 3), 2)
        sub0 = runtime.stage_subgraph(g, plan.stages[0], None)
        sub1 = runtime.stage_subgraph(g, plan.stages[1],
                                      plan.boundaries[0])
        assert set(sub0.nodes) == set(plan.stages[0])
        assert sub1.input_id == plan.boundaries[0]
        assert sub1.nodes[plan.boundaries[0]].op == "input"
        # intra-stage edges survive untouched (same node ids)
        for nid in plan.stages[1]:
            assert sub1.nodes[nid].inputs == g.nodes[nid].inputs


class TestStagedExecutor:
    def test_packed_tail_bit_exact_vs_single(self, engines):
        import jax

        from repro import runtime

        eng = engines["packed"]
        g = eng._graph
        shape = (4, 16, 16, 3)
        rng = np.random.default_rng(0)
        x = rng.integers(0, 256, shape, dtype=np.uint8)
        ref = np.asarray(runtime.GraphExecutor(g, "xla")(x))
        dev = jax.devices()[0]
        for n_stages in (1, 2, 3):
            exe = runtime.staged_executor(g, shape, (dev,) * n_stages,
                                          mode="xla")
            got = np.asarray(exe(x))
            np.testing.assert_array_equal(got, ref)   # packed: bit-exact

    def test_float_head_matches_cross_check(self, engines):
        import jax

        from repro import runtime

        eng = engines["float"]
        shape = (2, 16, 16, 3)
        rng = np.random.default_rng(1)
        x = rng.integers(0, 256, shape, dtype=np.uint8)
        ref = np.asarray(eng.cross_check(x))
        exe = runtime.staged_executor(eng._graph, shape,
                                      (jax.devices()[0],) * 2, mode="xla")
        np.testing.assert_allclose(np.asarray(exe(x)), ref, atol=1e-4)

    def test_trace_count_and_reports(self, engines):
        import jax

        from repro import runtime

        exe = runtime.staged_executor(engines["float"]._graph,
                                      (2, 16, 16, 3),
                                      (jax.devices()[0],) * 2)
        x = np.zeros((2, 16, 16, 3), np.uint8)
        exe(x)
        t = exe.trace_count
        assert t == exe.plan.n_stages       # one trace per stage
        exe(x); exe(x)
        assert exe.trace_count == t         # serve-time: no retrace
        rows = exe.stage_report()
        assert len(rows) == exe.plan.n_stages
        assert all("device" in r and "share" in r for r in rows)
        assert all("stage" in r for r in exe.backend_report())

    def test_chain_mode_refuses_interior_cuts(self, engines):
        import jax

        from repro import runtime
        from repro.runtime.placement import chain_interiors

        eng = engines["packed"]
        g = eng._graph
        chains = runtime.partition_chains(g, (1, 16, 16, 3))
        if not chains:
            pytest.skip("net formed no chains at this budget")
        forbidden = chain_interiors(chains)
        exe = runtime.StagedExecutor(g, (1, 16, 16, 3),
                                     (jax.devices()[0],) * 2,
                                     mode="vpu_chain")
        for b in exe.plan.boundaries:
            assert b not in forbidden
        x = np.zeros((1, 16, 16, 3), np.uint8)
        ref = np.asarray(runtime.GraphExecutor(g, "xla")(x))
        np.testing.assert_array_equal(np.asarray(exe(x)), ref)


# --------------------------------------------------------------------------
# Replica group: routing + protocol, in-process (1 device)
# --------------------------------------------------------------------------

class TestReplicaGroup:
    def test_serves_bit_exact_and_flat_traces(self, engines):
        import jax

        from repro.distributed import ReplicaGroup

        eng = engines["packed"]
        grp = ReplicaGroup(eng, [jax.devices()[0]] * 2,
                           buckets=(2, 4), max_batch=4)
        grp.compile_buckets()
        before = grp.trace_count
        rng = np.random.default_rng(0)
        imgs = [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
                for _ in range(6)]
        reqs = [grp.submit(i) for i in imgs]
        grp.drain()
        assert grp.trace_count == before
        ref = np.asarray(eng(np.stack(imgs)))
        for i, r in enumerate(reqs):
            assert r.outcome == "served"
            np.testing.assert_array_equal(np.asarray(r.result), ref[i])
        m = grp.metrics()
        assert set(m["replicas"]) == {"r0", "r1"}
        assert all(v["healthy"] for v in m["routing"].values())

    def test_routing_prefers_shallow_queues(self, engines):
        import jax

        from repro.distributed import ReplicaGroup

        grp = ReplicaGroup(engines["packed"], [jax.devices()[0]] * 2,
                           buckets=(2, 4), max_batch=4)
        x = np.zeros((16, 16, 3), np.uint8)
        grp.submit(x, replica="r0")
        grp.submit(x, replica="r0")
        assert grp._route().name == "r1"    # depth 0 beats depth 2
        grp.drain()

    def test_slow_replica_deprioritized_then_recovers(self, engines):
        import jax

        from repro.distributed import ReplicaGroup

        grp = ReplicaGroup(engines["packed"], [jax.devices()[0]] * 2,
                           slow_after=2)
        r1 = grp.replicas["r1"]
        # feed the monitor a stable baseline, then persistent outliers
        for i in range(r1.monitor.min_samples):
            grp._observe_step(r1, 0.01, i)
        for i in range(3):
            grp._observe_step(r1, 10.0, 100 + i)
        assert r1.slow and not r1.healthy
        assert grp._route().name == "r0"
        # a clean step clears the flag — the replica rejoins the pool
        grp._observe_step(r1, 0.01, 200)
        assert not r1.slow and r1.healthy

    def test_shape_validation(self, engines):
        import jax

        from repro.distributed import ReplicaGroup

        dev = jax.devices()[0]
        with pytest.raises(ValueError):
            ReplicaGroup(engines["packed"], [dev] * 3,
                         devices_per_replica=2)
        with pytest.raises(ValueError):
            ReplicaGroup(engines["packed"], [dev] * 2, names=("a",))


# --------------------------------------------------------------------------
# Forced-mesh subprocesses: real multi-device placement
# --------------------------------------------------------------------------

def test_pipelined_serving_matches_single_device():
    """4-stage pipeline on a forced 4-device mesh: params committed to
    distinct devices, serving bit-exact vs the single-device oracle
    (packed tail) and 1e-4 (float head), zero serve-time retraces —
    including zero-padded buckets."""
    out = _run(setup=_ENGINES, body="""
    from repro.distributed import Pipelined
    from repro.serving import InferenceServer

    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(7)]                       # 7 -> padded bucket 8

    for tail, exact in (("packed", True), ("float", False)):
        engine = tiny_engine(tail)
        placement = Pipelined.over(4)
        assert placement.n_stages == 4
        piped = InferenceServer(engine, buckets=(2, 4, 8), max_batch=8,
                                placement=placement)
        single = InferenceServer(engine, buckets=(2, 4, 8), max_batch=8)
        piped.compile_buckets(); single.compile_buckets()
        before = engine.trace_count

        # the realized split really spans devices (the plan may merge
        # stages when the graph is short on cut points)
        exe = piped._executable(8)
        devs = {str(d) for d in exe.devices}
        assert len(devs) == exe.plan.n_stages > 1, devs
        for dev, e in zip(exe.devices, exe.stage_executors):
            for a in jax.tree.leaves(e.arrays):   # params committed
                assert {str(d) for d in a.devices()} == {str(dev)}

        rp = [piped.submit(i) for i in imgs]
        rs = [single.submit(i) for i in imgs]
        piped.drain(); single.drain()
        assert engine.trace_count == before     # serve-time: no retrace
        for a, b in zip(rp, rs):
            assert a.outcome == b.outcome == "served"
            if exact:
                np.testing.assert_array_equal(a.result, b.result)
            else:
                np.testing.assert_allclose(a.result, b.result, atol=1e-4)
        # oracle: the flat packed_forward walk, single device
        ref = np.asarray(engine.cross_check(jnp.asarray(np.stack(imgs))))
        for i, a in enumerate(rp):
            np.testing.assert_allclose(a.result, ref[i], atol=1e-4)
        m = piped.metrics()
        assert m["placement"]["kind"] == "pipeline"
        assert len(m["placement"]["devices"]) == 4
    print("pipelined-parity-ok")
    """, n_dev=4)
    assert "pipelined-parity-ok" in out


def test_replica_group_forced_mesh_parity():
    """4 one-device replicas on a forced mesh: params pinned per
    replica device, group serving bit-exact vs the oracle, traffic
    actually spread over replicas."""
    out = _run(setup=_ENGINES, body="""
    from repro.distributed import ReplicaGroup

    engine = tiny_engine("packed")
    devs = jax.devices()
    grp = ReplicaGroup(engine, devs, buckets=(1, 2), max_batch=2)
    grp.compile_buckets()
    before = grp.trace_count

    # each replica's executables hold params committed to ITS device
    pinned = set()
    for name, rep in grp.replicas.items():
        exe = rep.server._executable(2)
        arrs = jax.tree.leaves(exe.stage_executors[0].arrays)
        dev = {str(list(a.devices())[0]) for a in arrs}
        assert dev == {str(rep.devices[0])}, (name, dev)
        pinned |= dev
    assert len(pinned) == 4

    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(12)]
    reqs = [grp.submit(i) for i in imgs]
    grp.drain()
    assert grp.trace_count == before
    ref = np.asarray(engine(jnp.asarray(np.stack(imgs))))
    for i, r in enumerate(reqs):
        assert r.outcome == "served"
        np.testing.assert_array_equal(np.asarray(r.result), ref[i])
    m = grp.metrics()
    served = {n: v["served"] for n, v in m["replicas"].items()}
    assert sum(served.values()) == 12
    assert sum(1 for v in served.values() if v) >= 2, served
    print("replica-parity-ok")
    """, n_dev=4)
    assert "replica-parity-ok" in out


def test_replicas_of_pipelines_forced_mesh():
    """Both axes composed: 2 replicas x 2-stage pipelines on 4 forced
    devices — the shape one sharded executable cannot express."""
    out = _run(setup=_ENGINES, body="""
    from repro.distributed import ReplicaGroup

    engine = tiny_engine("packed")
    grp = ReplicaGroup(engine, jax.devices(), devices_per_replica=2,
                       buckets=(2, 4), max_batch=4)
    assert set(grp.replicas) == {"r0", "r1"}
    grp.compile_buckets()
    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(8)]
    reqs = [grp.submit(i) for i in imgs]
    grp.drain()
    ref = np.asarray(engine(jnp.asarray(np.stack(imgs))))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(np.asarray(r.result), ref[i])
    for rep in grp.replicas.values():
        exe = rep.server._executable(4)
        if exe.plan.n_stages > 1:     # split realized: distinct devices
            assert len({str(d) for d in exe.devices}) == exe.plan.n_stages
    print("replica-pipeline-ok")
    """, n_dev=4)
    assert "replica-pipeline-ok" in out


def test_data_parallel_placement_matches_mesh_path():
    """DataParallel placement is exactly the mesh= path, through the
    unified placement surface."""
    out = _run(setup=_ENGINES, body="""
    from repro.distributed import DataParallel
    from repro.serving import InferenceServer

    engine = tiny_engine("packed")
    placement = DataParallel.over(4)
    assert placement.n_shards == 4
    sharded = InferenceServer(engine, buckets=(1, 2, 4, 8), max_batch=8,
                              placement=placement)
    assert sharded.scheduler.buckets == (4, 8)    # rounded to shard
    assert sharded.data_parallel == 4
    single = InferenceServer(engine, buckets=(4, 8), max_batch=8)
    sharded.compile_buckets(); single.compile_buckets()
    before = engine.trace_count
    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
            for _ in range(8)]
    rs = [sharded.submit(i) for i in imgs]
    ru = [single.submit(i) for i in imgs]
    sharded.drain(); single.drain()
    assert engine.trace_count == before
    for a, b in zip(rs, ru):
        np.testing.assert_array_equal(a.result, b.result)
    assert sharded.metrics()["placement"] == {"kind": "data", "shards": 4}
    print("dp-placement-ok")
    """, n_dev=4)
    assert "dp-placement-ok" in out


# --------------------------------------------------------------------------
# LM-stack multi-device scenarios (kept from the seed suite)
# --------------------------------------------------------------------------

_LM_PRELUDE = """
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import _mesh  # AxisType version-compat
mesh = _mesh((2, 4), ("data", "model"))
from repro.distributed.sharding import rules_for_mesh
rules = rules_for_mesh(mesh)
"""


def test_moe_sharded_matches_reference():
    _run(setup=_LM_PRELUDE, body="""
    from repro.models import moe as moe_lib

    t, d, e, k, fe = 64, 16, 8, 2, 32
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (t, d), jnp.float32)
    router = jax.random.normal(ks[1], (d, e)) * 0.1
    wg = jax.random.normal(ks[2], (e, d, fe)) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (e, d, fe)) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (e, fe, d)) / np.sqrt(fe)

    with mesh:
        out, aux = jax.jit(lambda *a: moe_lib.moe_apply(
            *a, n_experts=e, top_k=k, capacity_factor=float(e),
            rules=rules, token_axes=("data", "model")))(
                x, router, wg, wu, wd)
    ref = moe_lib.moe_reference(x, router, wg, wu, wd, n_experts=e,
                                top_k=k)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
    # tokens replicated over model (decode path) must agree too
    with mesh:
        out2, _ = jax.jit(lambda *a: moe_lib.moe_apply(
            *a, n_experts=e, top_k=k, capacity_factor=float(e),
            rules=rules, token_axes=("data",)))(x, router, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out2, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)
    print("moe OK")
    """)


def test_elastic_restore_different_mesh():
    _run(setup=_LM_PRELUDE, body="""
    import tempfile
    from repro.checkpoint import save, restore
    from repro.models import transformer
    from repro import configs
    from repro.launch.mesh import make_host_mesh

    cfg = configs.get("minitron-8b").smoke
    mesh_a = make_host_mesh(data=2, model=4)
    rules_a = rules_for_mesh(mesh_a)
    with mesh_a:
        psh_a = rules_a.tree_shardings(transformer.param_specs(cfg, rules_a))
        params = jax.jit(lambda k: transformer.init_params(k, cfg, ep=4),
                         out_shardings=psh_a)(jax.random.key(0))
    d = tempfile.mkdtemp()
    save(d, 3, params)

    # "node failure": restart on a smaller 4-device mesh
    mesh_b = make_host_mesh(data=4, model=1)
    rules_b = rules_for_mesh(mesh_b)
    with mesh_b:
        psh_b = rules_b.tree_shardings(transformer.param_specs(cfg, rules_b))
        like = jax.eval_shape(
            lambda k: transformer.init_params(k, cfg, ep=4),
            jax.random.key(0))
        restored = restore(d, 3, like, psh_b)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored arrays carry mesh_b shardings
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == {"data": 4, "model": 1}
    print("elastic OK")
    """)


def test_sharded_lm_matches_single_device():
    """The same smoke LM produces identical logits on (2,4) vs (1,1)."""
    _run(setup=_LM_PRELUDE, body="""
    from repro.models import transformer
    from repro import configs
    from repro.launch.mesh import make_host_mesh

    cfg = configs.get("qwen3-moe-30b-a3b").smoke
    import dataclasses
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = transformer.init_params(jax.random.key(0), cfg, ep=4)
    tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)

    with mesh:  # (2, 4)
        lg_sharded, _ = jax.jit(
            lambda p, t: transformer.forward(p, t, cfg, rules))(params,
                                                                tokens)
    mesh1 = make_host_mesh(data=1, model=1)
    rules1 = rules_for_mesh(mesh1)
    # ep=4-padded weights work on a 1-device mesh too (padding is in E)
    with mesh1:
        lg_single, _ = jax.jit(
            lambda p, t: transformer.forward(p, t, cfg, rules1))(params,
                                                                 tokens)
    a = np.asarray(lg_sharded, np.float32)
    b = np.asarray(lg_single, np.float32)
    # bf16 end-to-end: partitioning changes accumulation order; a small
    # tail of logits drifts ~0.2 abs.  Assert tight agreement in bulk +
    # near-perfect argmax agreement (the decision-relevant quantity).
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.25)
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    # random-init logits have many near-ties; >95% argmax agreement is
    # the bf16-noise-tolerant bar
    assert agree > 0.95, agree
    print("sharded-vs-single OK", agree)
    """, timeout=560)
