"""Postprocess heads: top-k classification and YOLOv2 decode + NMS.

Both heads are pure ``jnp``/``lax`` functions of statically-shaped inputs
with **fixed-size outputs**, so they jit into the serve path (DESIGN.md
§8.2): one compiled executable per batch bucket covers forward *and*
decode, and the server scatters one dense row per request.

Row formats (everything a plain float32 array so the serving scatter path
stays a single ``np.asarray``):

* classification — ``(k, 2)`` rows ``[class_index, probability]``,
  probability-descending;
* detection      — ``(max_det, 6)`` rows ``[x1, y1, x2, y2, score,
  class_index]`` in network-input pixels, score-descending; rows past the
  surviving detections are all-zero (``score > 0`` is the validity mask).

The detection head implements the YOLOv2 decode (arXiv:1612.08242 §2):
the 13x13x125 map reshapes to 5 anchors x (tx, ty, tw, th, to, 20 class
logits); box centers are ``sigmoid(txy)`` offset by the cell index, sizes
are anchor-scaled ``exp(twh)``, objectness is ``sigmoid(to)`` and class
scores are ``softmax`` — each box scored by its best class (the standard
single-label decode).  NMS is the greedy algorithm on the top-``max_det``
candidates, expressed as a ``fori_loop`` over a precomputed IoU matrix so
it compiles (no data-dependent shapes anywhere).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

# YOLOv2-Tiny VOC anchor priors, in grid-cell units (darknet cfg).
YOLOV2_TINY_VOC_ANCHORS = ((1.08, 1.19), (3.42, 4.41), (6.63, 11.38),
                           (9.42, 5.11), (16.62, 10.52))

VOC_CLASSES = (
    "aeroplane", "bicycle", "bird", "boat", "bottle", "bus", "car", "cat",
    "chair", "cow", "diningtable", "dog", "horse", "motorbike", "person",
    "pottedplant", "sheep", "sofa", "train", "tvmonitor")

# Score assigned to candidates below score_thresh: far below any real
# conf*prob in (0, 1], and recognizable after top_k as "not a detection".
_NEG = jnp.float32(-1e9)


@dataclasses.dataclass(frozen=True)
class DetectConfig:
    """Static decode/NMS parameters (part of the jit closure)."""
    anchors: tuple[tuple[float, float], ...] = YOLOV2_TINY_VOC_ANCHORS
    n_classes: int = 20
    score_thresh: float = 0.3
    iou_thresh: float = 0.45
    max_det: int = 16
    class_names: tuple[str, ...] | None = VOC_CLASSES

    @property
    def channels(self) -> int:
        return len(self.anchors) * (5 + self.n_classes)


# --------------------------------------------------------------------------
# Classification head
# --------------------------------------------------------------------------

def topk_head(logits: jnp.ndarray, k: int) -> jnp.ndarray:
    """(N, n_classes) logits -> (N, k, 2) rows [class_index, probability]."""
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = lax.top_k(probs, min(k, logits.shape[-1]))
    return jnp.stack([idx.astype(jnp.float32), vals], axis=-1)


# --------------------------------------------------------------------------
# YOLOv2 decode
# --------------------------------------------------------------------------

def decode_yolo(feat: jnp.ndarray, cfg: DetectConfig,
                input_hw: tuple[int, int]
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(N, Hg, Wg, A*(5+C)) raw map -> (boxes, scores, classes).

    boxes: (N, Hg*Wg*A, 4) x1y1x2y2 in network-input pixels (clipped);
    scores: (N, Hg*Wg*A) = objectness * best-class probability;
    classes: (N, Hg*Wg*A) int32 best-class index.
    """
    n, hg, wg, ch = feat.shape
    a = len(cfg.anchors)
    assert ch == cfg.channels, (ch, cfg.channels)
    f = feat.reshape(n, hg, wg, a, 5 + cfg.n_classes)

    xy = jax.nn.sigmoid(f[..., 0:2])                     # in-cell offset
    cx = jnp.arange(wg, dtype=jnp.float32)[None, None, :, None]
    cy = jnp.arange(hg, dtype=jnp.float32)[None, :, None, None]
    bx = (xy[..., 0] + cx) / wg                          # normalized center
    by = (xy[..., 1] + cy) / hg
    anchors = jnp.asarray(cfg.anchors, jnp.float32)      # (A, 2) grid units
    bw = anchors[:, 0] * jnp.exp(f[..., 2]) / wg
    bh = anchors[:, 1] * jnp.exp(f[..., 3]) / hg

    conf = jax.nn.sigmoid(f[..., 4])
    probs = jax.nn.softmax(f[..., 5:], axis=-1)
    cls_idx = jnp.argmax(probs, axis=-1).astype(jnp.int32)
    scores = conf * jnp.max(probs, axis=-1)

    ih, iw = input_hw
    x1 = jnp.clip((bx - bw / 2) * iw, 0, iw)
    y1 = jnp.clip((by - bh / 2) * ih, 0, ih)
    x2 = jnp.clip((bx + bw / 2) * iw, 0, iw)
    y2 = jnp.clip((by + bh / 2) * ih, 0, ih)
    boxes = jnp.stack([x1, y1, x2, y2], axis=-1)

    m = hg * wg * a
    return (boxes.reshape(n, m, 4), scores.reshape(n, m),
            cls_idx.reshape(n, m))


# --------------------------------------------------------------------------
# Fixed-size greedy NMS (pure lax)
# --------------------------------------------------------------------------

def iou_matrix(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pairwise IoU of (M, 4) x (K, 4) x1y1x2y2 boxes -> (M, K)."""
    lt = jnp.maximum(a[:, None, :2], b[None, :, :2])
    rb = jnp.minimum(a[:, None, 2:], b[None, :, 2:])
    inter = jnp.prod(jnp.clip(rb - lt, 0, None), axis=-1)
    area_a = jnp.prod(jnp.clip(a[:, 2:] - a[:, :2], 0, None), axis=-1)
    area_b = jnp.prod(jnp.clip(b[:, 2:] - b[:, :2], 0, None), axis=-1)
    union = area_a[:, None] + area_b[None, :] - inter
    return jnp.where(union > 0, inter / union, 0.0)


def nms_fixed(boxes: jnp.ndarray, scores: jnp.ndarray,
              classes: jnp.ndarray | None = None, *,
              iou_thresh: float = 0.45, score_thresh: float = 0.0,
              max_det: int = 16) -> jnp.ndarray:
    """Greedy NMS over one image's (M, 4) boxes -> (max_det, 6) rows
    ``[x1, y1, x2, y2, score, class]``, score-descending, zero-padded.

    Exactly the classic sequential algorithm — candidates visited in
    score order, each kept iff its IoU with every already-kept box is
    <= ``iou_thresh`` — restricted to the top-``max_det`` candidates so
    everything is fixed-size and compiles.  With ``classes`` given, NMS
    is class-aware (boxes of different classes never suppress each other,
    via the per-class coordinate-offset trick).  Invariants (tested):
    kept boxes have pairwise IoU <= ``iou_thresh`` (per class), scores
    >= ``score_thresh`` *and* > 0 (the validity-mask convention), and
    there are at most ``max_det`` of them.
    """
    m = boxes.shape[0]
    k = min(max_det, m)
    if classes is None:
        classes = jnp.zeros((m,), jnp.int32)
    # score > 0 is the row-validity convention, so a zero/negative score
    # can never occupy a survivor slot even at score_thresh=0.
    s = jnp.where((scores >= score_thresh) & (scores > 0), scores, _NEG)
    top_s, idx = lax.top_k(s, k)
    cand = boxes[idx]
    cand_cls = classes[idx]

    # Class-aware: translate each class into its own disjoint region so
    # cross-class IoU is exactly 0 in one shared matrix.
    span = jnp.max(jnp.abs(boxes)) + 1.0
    shifted = cand + (cand_cls.astype(boxes.dtype) * 4.0 * span)[:, None]
    ious = iou_matrix(shifted, shifted)
    valid = top_s > _NEG / 2                  # above score_thresh

    def body(i, keep):
        overlapped = keep & (ious[i] > iou_thresh) & \
            (jnp.arange(k) != i)
        return keep.at[i].set(valid[i] & ~jnp.any(overlapped))

    keep = lax.fori_loop(0, k, body, jnp.zeros((k,), bool))

    rows = jnp.concatenate(
        [cand, top_s[:, None], cand_cls.astype(jnp.float32)[:, None]],
        axis=-1)
    rows = jnp.where(keep[:, None], rows, 0.0)
    # Compact: surviving rows first (they are already score-descending,
    # and jnp.argsort on the drop mask is stable), zeros after.
    rows = rows[jnp.argsort(~keep, stable=True)]
    if k < max_det:
        rows = jnp.pad(rows, ((0, max_det - k), (0, 0)))
    return rows


def detect_head(feat: jnp.ndarray, cfg: DetectConfig,
                input_hw: tuple[int, int]) -> jnp.ndarray:
    """Raw YOLO map -> (N, max_det, 6) decoded detections (see module
    docstring for the row format).  Batched via vmap; jit-able."""
    boxes, scores, classes = decode_yolo(feat, cfg, input_hw)
    return jax.vmap(
        lambda b, s, c: nms_fixed(
            b, s, c, iou_thresh=cfg.iou_thresh,
            score_thresh=cfg.score_thresh, max_det=cfg.max_det)
    )(boxes, scores, classes)


def detections_to_dicts(rows, cfg: DetectConfig) -> list[dict]:
    """One image's (max_det, 6) rows -> readable dicts (valid rows only)."""
    import numpy as np

    out = []
    for x1, y1, x2, y2, score, cls in np.asarray(rows):
        if score <= 0:
            continue
        cls = int(cls)
        name = (cfg.class_names[cls] if cfg.class_names else str(cls))
        out.append(dict(box=[float(x1), float(y1), float(x2), float(y2)],
                        score=float(score), class_id=cls, label=name))
    return out
