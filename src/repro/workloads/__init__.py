"""End-to-end paper workloads (DESIGN.md §8).

preprocess    letterbox / center-crop-resize uint8 transforms (jit-able,
              serving-hook adaptable) + box coordinate mapping
postprocess   top-k classification head, YOLOv2 decode, fixed-size pure
              ``lax`` NMS (compiles into the serve path)
workload      the ``Workload`` bundle (preprocess + engine + postprocess),
              ``WorkloadEngine`` (per-bucket executables serving decoded
              rows), and the registry: ``workloads.get("yolov2_tiny_voc")``
"""

from repro.workloads.postprocess import (DetectConfig, VOC_CLASSES,
                                         YOLOV2_TINY_VOC_ANCHORS,
                                         decode_yolo, detect_head,
                                         detections_to_dicts, iou_matrix,
                                         nms_fixed, topk_head)
from repro.workloads.preprocess import (as_server_hook, center_crop_resize,
                                        letterbox, letterbox_boxes,
                                        letterbox_params, unletterbox_boxes)
from repro.workloads.workload import (Workload, WorkloadEngine,
                                      checkpoint_params, get, names,
                                      register)

__all__ = [
    "Workload", "WorkloadEngine", "get", "names", "register",
    "checkpoint_params",
    "DetectConfig", "VOC_CLASSES", "YOLOV2_TINY_VOC_ANCHORS",
    "decode_yolo", "detect_head", "detections_to_dicts", "iou_matrix",
    "nms_fixed", "topk_head",
    "as_server_hook", "center_crop_resize", "letterbox", "letterbox_boxes",
    "letterbox_params", "unletterbox_boxes",
]
