"""The Workload abstraction: preprocess + model + postprocess as one object.

A :class:`Workload` bundles everything between an arbitrary-size uint8
image and a human-readable prediction (DESIGN.md §8):

* the task's preprocessing transform (letterbox for detection,
  center-crop for classification) — jit-able, and exposed as an
  ``InferenceServer`` ``preprocess=`` hook;
* the paper network (spec + a **seeded checkpoint** so every consumer —
  tests, benchmarks, examples — reconstructs bit-identical parameters
  from ``(name, seed)`` alone), served through the graph runtime via
  :class:`~repro.serving.engine.PhoneBitEngine`;
* the jit-able postprocess head (top-k / YOLO decode + fixed-size NMS),
  fused behind the engine's per-bucket executable surface by
  :class:`WorkloadEngine` so the server scatters *decoded* rows and the
  zero-serve-time-retrace contract covers the head too.

The registry maps workload names to builders::

    wl = workloads.get("yolov2_tiny_voc", input_hw=416)
    server = wl.server(max_batch=4)
    server.submit(any_uint8_image); server.drain()

Each paper entry also has a ``variant="tiny"`` — a topology-preserving
scaled-down network (same layer-type sequence: bit-plane first conv,
packed hidden stack, float head; reduced channels/resolution) used by the
conformance harness and CI, where sweeping interpret-mode Pallas backends
over full ImageNet-size nets is not viable.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnn_model
from repro.core.bnn_model import BConv, BDense, FloatConv, FloatDense, Pool
from repro.models import paper_nets
from repro.serving import InferenceServer, PhoneBitEngine
from repro.workloads import postprocess as post
from repro.workloads import preprocess as pre
from repro.workloads.postprocess import DetectConfig


def checkpoint_params(spec, seed: int = 0) -> list[dict]:
    """The seeded golden checkpoint: deterministic latent-float params.

    ``init_params`` with a seeded key, then BN statistics drawn from a
    seeded numpy generator (identity BN would make half the integer
    thresholds degenerate — randomized BN is what the golden fixtures and
    conformance sweeps need to exercise the threshold math).
    """
    params = bnn_model.init_params(jax.random.key(seed), spec)
    rng = np.random.default_rng(seed)
    for p in params:
        if "mu" in p:
            o = p["mu"].shape[0]
            p["mu"] = jnp.asarray(rng.uniform(-20, 20, o), jnp.float32)
            p["var"] = jnp.asarray(rng.uniform(0.5, 4, o), jnp.float32)
            p["gamma"] = jnp.asarray(rng.uniform(-1.5, 1.5, o), jnp.float32)
            p["beta"] = jnp.asarray(rng.uniform(-1, 1, o), jnp.float32)
    return params


class WorkloadEngine:
    """A PhoneBitEngine with the workload's postprocess head fused onto
    its per-bucket executable surface.

    Speaks the same ``compile(bs, donate_input=, data_parallel=, mode=)`` /
    ``_plan_shape`` / ``trace_count`` contract the ``InferenceServer``
    expects from an engine, so the server serves decoded predictions with
    no special casing.  The head is one jit-compiled function (traced once
    per bucket shape; traces counted like the executor's), dispatched
    after the forward executable — composition at the host level keeps
    the engine's input-buffer donation intact.
    """

    def __init__(self, engine: PhoneBitEngine,
                 head: Callable[[jnp.ndarray], jnp.ndarray]):
        self.engine = engine
        self.head = head
        self._head_trace_count = 0

        def traced_head(y):
            self._head_trace_count += 1   # trace time only
            return head(y)

        self._head_jit = jax.jit(traced_head)
        self._compiled: dict[tuple, Callable] = {}

    # ---- engine surface (what InferenceServer consumes) ------------------
    def compile(self, batch_size: int | None = None, *,
                donate_input: bool = False, data_parallel: int = 1,
                mode: str | None = None):
        # Resolved-mode key (like PhoneBitEngine's): the server's health
        # ladder passes the concrete mode string, direct calls pass None
        # — both must hit the same cached (or artifact-loaded) entry.
        key = (batch_size, donate_input, data_parallel,
               mode or self.matmul_mode)
        if key not in self._compiled:
            fwd = self.engine.compile(batch_size, donate_input=donate_input,
                                      data_parallel=data_parallel, mode=mode)
            self._compiled[key] = \
                lambda x, fwd=fwd: self._head_jit(fwd(x))
        return self._compiled[key]

    def _plan_shape(self, batch: int | None = None):
        return self.engine._plan_shape(batch)

    @property
    def matmul_mode(self) -> str:
        """Configured backend rung — lets the server's degradation ladder
        (DESIGN.md §11.3) judge and demote workload engines too."""
        return self.engine.matmul_mode

    # ---- AOT artifacts (DESIGN.md §12) -----------------------------------
    # The artifact loader's engine surface: graph/tuner come from the
    # wrapped engine; loaded executables (forward + head composed) land
    # in THIS cache so the server's compile() hits them.
    @property
    def _graph(self):
        return self.engine._graph

    @property
    def _tuner(self):
        return self.engine._tuner

    def _install_executable(self, batch_size: int, exe, *,
                            donate_input: bool = False,
                            data_parallel: int = 1,
                            mode: str | None = None) -> None:
        key = (int(batch_size), donate_input, data_parallel,
               mode or self.matmul_mode)
        self._compiled[key] = exe

    def export_artifact(self, path, buckets=(1, 2, 4, 8), *,
                        donate_input: bool = True,
                        workload: str | None = None) -> dict:
        """Export AOT bucket executables *including the postprocess
        head* (serialized per bucket at the forward output shape), so a
        loaded workload serves decoded predictions with zero traces."""
        from repro.serving import artifact as _artifact

        return _artifact.export_artifact(
            self.engine, path, buckets, donate_input=donate_input,
            head_fn=self._head_jit, workload=workload)

    def load_artifact(self, path, *, donate_input: bool = True,
                      data_parallel: int = 1, buckets=None) -> dict:
        """Restore forward+head executables into this engine's bucket
        cache (``trace_count`` stays 0 — neither the executor closure
        nor the head jit is ever traced)."""
        from repro.serving import artifact as _artifact

        return _artifact.load_artifact(
            self, path, donate_input=donate_input,
            data_parallel=data_parallel, buckets=buckets, head=True)

    @property
    def trace_count(self) -> int:
        """Forward + head traces: the serve-time no-recompile contract
        covers the whole image->prediction executable."""
        return self.engine.trace_count + self._head_trace_count

    # ---- direct calls ----------------------------------------------------
    def __call__(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        return self.compile(x_uint8.shape[0])(x_uint8)

    def raw(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        """Pre-head network output (logits / feature map)."""
        return self.engine(x_uint8)

    def cross_check(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        """Decoded predictions via the engine's graph path, asserting the
        graph == legacy-flat bit-exactness on the raw output first."""
        return self._head_jit(self.engine.cross_check(x_uint8))


@dataclasses.dataclass
class Workload:
    """One deployable paper workload: preprocess -> engine -> postprocess."""

    name: str
    task: str                                  # "classify" | "detect"
    spec: list
    input_hw: tuple[int, int]
    params: list
    matmul_mode: str = "xla"
    top_k: int = 5
    detect: DetectConfig | None = None
    class_names: tuple[str, ...] | None = None
    seed: int = 0

    def __post_init__(self):
        assert self.task in ("classify", "detect"), self.task
        if self.task == "detect" and self.detect is None:
            self.detect = DetectConfig()

    # ---- preprocessing ---------------------------------------------------
    def preprocess(self, img: jnp.ndarray) -> jnp.ndarray:
        """(H, W, C) uint8 at any size -> network-size uint8 (jit-able)."""
        if self.task == "detect":
            return pre.letterbox(img, self.input_hw)
        return pre.center_crop_resize(img, self.input_hw)

    @functools.cached_property
    def preprocess_hook(self) -> Callable[[np.ndarray], np.ndarray]:
        """Numpy-in/out per-payload hook for ``InferenceServer``."""
        return pre.as_server_hook(self.preprocess)

    # ---- postprocessing --------------------------------------------------
    def postprocess(self, raw: jnp.ndarray) -> jnp.ndarray:
        """Network output -> fixed-size prediction rows (jit-able)."""
        if self.task == "detect":
            return post.detect_head(raw, self.detect, self.input_hw)
        return post.topk_head(raw, self.top_k)

    # ---- engine / serving ------------------------------------------------
    @functools.cached_property
    def engine(self) -> WorkloadEngine:
        base = PhoneBitEngine.from_trained(self.params, self.spec,
                                           self.input_hw,
                                           matmul_mode=self.matmul_mode)
        return WorkloadEngine(base, self.postprocess)

    def server(self, **kw) -> InferenceServer:
        kw.setdefault("preprocess", self.preprocess_hook)
        return InferenceServer(self.engine, **kw)

    def predict(self, images) -> np.ndarray:
        """End-to-end convenience: list of raw uint8 HWC images (any
        sizes) -> stacked prediction rows."""
        x = jnp.stack([self.preprocess(jnp.asarray(i)) for i in images])
        return np.asarray(self.engine(x))

    def format(self, row) -> list[dict]:
        """One request's prediction rows -> readable dicts."""
        if self.task == "detect":
            return post.detections_to_dicts(row, self.detect)
        return [dict(class_id=int(c), prob=float(p),
                     label=(self.class_names[int(c)]
                            if self.class_names else str(int(c))))
                for c, p in np.asarray(row)]

    @property
    def model_bytes(self) -> int:
        return self.engine.engine.model_bytes


# --------------------------------------------------------------------------
# Tiny (topology-preserving) conformance variants
# --------------------------------------------------------------------------

def _tiny_alexnet():
    """AlexNet shrunk for the conformance sweep: strided first bit-plane
    conv, packed conv/pool stack, two packed dense, float head."""
    spec = [
        BConv(3, 32, kernel=5, stride=2, pad=2, first=True),
        Pool(2, 2),
        BConv(32, 48, kernel=3, stride=1, pad=1),
        Pool(2, 2),
        BDense(2 * 2 * 48, 64),
        BDense(64, 64),
        FloatDense(64, 10),
    ]
    return spec, (16, 16)


def _tiny_vgg16():
    """VGG16 shrunk: doubled conv blocks between pools, dense tail."""
    spec = [
        BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
        BConv(16, 16, kernel=3, stride=1, pad=1),
        Pool(2, 2),
        BConv(16, 32, kernel=3, stride=1, pad=1),
        BConv(32, 32, kernel=3, stride=1, pad=1),
        Pool(2, 2),
        BDense(4 * 4 * 32, 64),
        BDense(64, 64),
        FloatDense(64, 10),
    ]
    return spec, (16, 16)


def _tiny_yolov2(detect: DetectConfig):
    """YOLOv2-Tiny shrunk: conv/pool ladder ending in the darknet
    stride-1 'same' pool and the full-precision 1x1 detection head."""
    spec = [
        BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
        Pool(2, 2),
        BConv(16, 32, kernel=3, stride=1, pad=1),
        Pool(2, 2),
        BConv(32, 64, kernel=3, stride=1, pad=1),
        Pool(2, 1, pad=(0, 1)),
        BConv(64, 64, kernel=3, stride=1, pad=1),
        FloatConv(64, detect.channels, kernel=1, stride=1, pad=0),
    ]
    return spec, (32, 32)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Workload]] = {}


def register(name: str, builder: Callable[..., Workload]) -> None:
    _REGISTRY[name] = builder


def names() -> list[str]:
    return sorted(_REGISTRY)


def get(name: str, **kw) -> Workload:
    """Build a registered workload.  Common kwargs: ``variant`` ("paper"
    default, or "tiny" for the conformance-scale net), ``matmul_mode``,
    ``input_hw`` (int or (h, w); fully-conv nets only), ``seed``."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown workload {name!r}; have {names()}")
    return _REGISTRY[name](**kw)


def _hw(input_hw) -> tuple[int, int] | None:
    if input_hw is None:
        return None
    if isinstance(input_hw, int):
        return (input_hw, input_hw)
    return tuple(input_hw)


def _classify_builder(net: str, tiny_fn):
    def build(*, variant: str = "paper", matmul_mode: str = "xla",
              seed: int = 0, top_k: int = 5, input_hw=None) -> Workload:
        if variant == "paper":
            spec, (h, w, _) = paper_nets.get(net)
        elif variant == "tiny":
            spec, (h, w) = tiny_fn()
        else:
            raise ValueError(f"unknown variant {variant!r}")
        if _hw(input_hw) not in (None, (h, w)):
            raise ValueError(
                f"{net} has dense layers fixed to {(h, w)} inputs")
        return Workload(
            name=f"{net}_imagenet" if variant == "paper" else
                 f"{net}_imagenet[tiny]",
            task="classify", spec=spec, input_hw=(h, w),
            params=checkpoint_params(spec, seed),
            matmul_mode=matmul_mode, top_k=top_k, seed=seed)
    return build


def _detect_builder(name: str, net: str, tiny_fn):
    def build(*, variant: str = "paper", matmul_mode: str = "xla",
              seed: int = 0, input_hw=None,
              detect: DetectConfig | None = None) -> Workload:
        detect = detect if detect is not None else DetectConfig()
        if variant == "paper":
            spec, (h, w, _) = paper_nets.get(net)
        elif variant == "tiny":
            spec, (h, w) = tiny_fn(detect)
        else:
            raise ValueError(f"unknown variant {variant!r}")
        # Fully convolutional: any resolution the pool ladder divides.
        h, w = _hw(input_hw) or (h, w)
        return Workload(
            name=name if variant == "paper" else f"{name}[tiny]",
            task="detect", spec=spec, input_hw=(h, w),
            params=checkpoint_params(spec, seed),
            matmul_mode=matmul_mode, detect=detect,
            class_names=detect.class_names, seed=seed)
    return build


register("alexnet_imagenet", _classify_builder("alexnet", _tiny_alexnet))
register("vgg16_imagenet", _classify_builder("vgg16", _tiny_vgg16))
register("yolov2_tiny_voc",
         _detect_builder("yolov2_tiny_voc", "yolov2-tiny", _tiny_yolov2))
