"""Image preprocessing for the paper workloads (DESIGN.md §8.1).

The engine consumes raw ``uint8`` HWC pixels — the /255 normalization and
the first layer's BN are folded into the bit-plane layer's integer
thresholds at conversion time (DESIGN.md §3.3/§3.4) — so every transform
here maps an arbitrary-size uint8 image to a network-size uint8 image:

* :func:`letterbox`          — aspect-preserving resize onto a gray canvas
                               (detection; the YOLO convention), with
                               :func:`letterbox_boxes` /
                               :func:`unletterbox_boxes` mapping box
                               coordinates between the two frames;
* :func:`center_crop_resize` — shorter-side resize + center crop
                               (classification; the AlexNet/VGG eval
                               convention).

All transforms are pure ``jnp`` functions of statically-shaped inputs, so
they jit (one trace per distinct input size) and compose into the serving
path via :func:`as_server_hook`, which adapts a transform to
``InferenceServer``'s per-payload ``preprocess=`` hook (numpy in/out,
jit-cached).  The scheduler's zero-filled padding rows pass through the
same hook, so pads reach the engine at the network shape like every real
payload.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# Gray letterbox fill: the YOLO convention (114 in most implementations).
LETTERBOX_FILL = 114


# --------------------------------------------------------------------------
# Letterbox (detection)
# --------------------------------------------------------------------------

def letterbox_params(in_hw: tuple[int, int], out_hw: tuple[int, int]
                     ) -> tuple[float, tuple[int, int], tuple[int, int]]:
    """The static geometry of a letterbox: (scale, (top, left), (nh, nw)).

    One definition shared by the image transform and the box mappers, so
    coordinates always round-trip with the pixels they refer to.
    """
    h, w = in_hw
    oh, ow = out_hw
    scale = min(oh / h, ow / w)
    nh, nw = min(int(round(h * scale)), oh), min(int(round(w * scale)), ow)
    top, left = (oh - nh) // 2, (ow - nw) // 2
    return scale, (top, left), (nh, nw)


def letterbox(img: jnp.ndarray, out_hw: tuple[int, int],
              fill: int = LETTERBOX_FILL) -> jnp.ndarray:
    """Aspect-preserving resize of an (H, W, C) uint8 image onto a
    ``fill``-gray (out_h, out_w, C) canvas, content centered."""
    h, w, c = img.shape
    oh, ow = out_hw
    _, (top, left), (nh, nw) = letterbox_params((h, w), out_hw)
    resized = jax.image.resize(img.astype(jnp.float32), (nh, nw, c),
                               method="bilinear")
    canvas = jnp.full((oh, ow, c), float(fill), jnp.float32)
    canvas = lax.dynamic_update_slice(canvas, resized, (top, left, 0))
    return jnp.clip(jnp.round(canvas), 0, 255).astype(jnp.uint8)


def letterbox_boxes(boxes: np.ndarray, in_hw: tuple[int, int],
                    out_hw: tuple[int, int]) -> np.ndarray:
    """Map (..., 4) x1y1x2y2 boxes from original-image pixels to
    letterboxed network pixels."""
    scale, (top, left), _ = letterbox_params(in_hw, out_hw)
    boxes = np.asarray(boxes, np.float32)
    return boxes * scale + np.array([left, top, left, top], np.float32)


def unletterbox_boxes(boxes: np.ndarray, in_hw: tuple[int, int],
                      out_hw: tuple[int, int]) -> np.ndarray:
    """Inverse of :func:`letterbox_boxes`: network-frame boxes back to
    original-image pixels, clipped to the image bounds."""
    scale, (top, left), _ = letterbox_params(in_hw, out_hw)
    boxes = np.asarray(boxes, np.float32)
    out = (boxes - np.array([left, top, left, top], np.float32)) / scale
    h, w = in_hw
    return np.clip(out, 0, np.array([w, h, w, h], np.float32))


# --------------------------------------------------------------------------
# Center crop (classification)
# --------------------------------------------------------------------------

def center_crop_resize(img: jnp.ndarray,
                       out_hw: tuple[int, int]) -> jnp.ndarray:
    """Shorter-side resize then center crop to (out_h, out_w), uint8 in/out.

    The shorter side is resized to ``ceil(max(out_hw) * 8 / 7)`` — the
    256-for-224 eval convention, generalized so it holds at any (test-size)
    resolution — then the center (out_h, out_w) window is cropped.
    """
    h, w, c = img.shape
    oh, ow = out_hw
    short = -(-max(oh, ow) * 8 // 7)          # ceil; 256 when out is 224
    scale = short / min(h, w)
    nh = max(int(round(h * scale)), oh)
    nw = max(int(round(w * scale)), ow)
    resized = jax.image.resize(img.astype(jnp.float32), (nh, nw, c),
                               method="bilinear")
    out = lax.dynamic_slice(resized, ((nh - oh) // 2, (nw - ow) // 2, 0),
                            (oh, ow, c))
    return jnp.clip(jnp.round(out), 0, 255).astype(jnp.uint8)


# --------------------------------------------------------------------------
# Serving hook adapter
# --------------------------------------------------------------------------

def as_server_hook(transform: Callable[[jnp.ndarray], jnp.ndarray]
                   ) -> Callable[[np.ndarray], np.ndarray]:
    """Adapt a jnp image transform to ``InferenceServer(preprocess=...)``.

    The hook takes one numpy payload and returns the network-size uint8
    numpy image; the underlying transform is jit-compiled once per
    distinct input shape (a fixed-size request stream compiles exactly
    once — engine trace counts are unaffected either way).
    """
    jitted = jax.jit(transform)

    @functools.wraps(transform)
    def hook(payload: np.ndarray) -> np.ndarray:
        return np.asarray(jitted(jnp.asarray(payload)))

    return hook
