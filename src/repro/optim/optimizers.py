"""AdamW / SGD-momentum with global-norm clipping and LR schedules.

Pure-pytree implementation (no optax dependency).  The state trees mirror
the parameter tree exactly, so parameter PartitionSpecs apply verbatim and
optimizer state is sharded from birth (ZeRO semantics under pjit).

STE awareness: binarized layers train on *latent* float weights clipped to
[-1, 1] after each update (Courbariaux et al.); pass ``clip_latent_paths``
with a predicate on the tree path to enable per-leaf clipping.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray          # () int32
    mu: Any                    # first moment  (params-like)
    nu: Any | None             # second moment (params-like) — None for SGD


# --------------------------------------------------------------------------
# Schedules
# --------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    """Linear warmup -> cosine decay to ``floor * base_lr``."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * base_lr + (1 - floor) * base_lr * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


# --------------------------------------------------------------------------
# Grad utilities
# --------------------------------------------------------------------------

def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def adamw_update(params: Any, grads: Any, state: OptState, *,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0,
                 clip_latent_paths: Callable[[str], bool] | None = None):
    """One AdamW step.  ``lr`` is a float or a schedule fn(step)->lr.

    Returns (new_params, new_state, metrics dict).
    """
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    b1t = 1 - b1 ** step.astype(jnp.float32)
    b2t = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m / b1t
        vhat = v / b2t
        newp = (p.astype(jnp.float32)
                - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                          + weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        if clip_latent_paths is not None and clip_latent_paths(
                jax.tree_util.keystr(path)):
            np_ = jnp.clip(np_, -1.0, 1.0)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unf = functools.partial(jax.tree_util.tree_unflatten, treedef)
    metrics = {"grad_norm": gnorm, "lr": lr_t}
    return unf(new_p), OptState(step, unf(new_m), unf(new_v)), metrics


# --------------------------------------------------------------------------
# SGD + momentum (vision baselines)
# --------------------------------------------------------------------------

def sgdm_init(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(zeros, params), nu=None)


def sgdm_update(params: Any, grads: Any, state: OptState, *,
                lr, momentum: float = 0.9, weight_decay: float = 1e-4,
                max_grad_norm: float = 0.0):
    if max_grad_norm:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)

    def upd(p, g, m):
        gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m = momentum * m + gf
        return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m

    pm = jax.tree.map(upd, params, grads, state.mu)
    new_p = jax.tree.map(lambda t: t[0], pm,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], pm,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, OptState(step, new_m, None), {"grad_norm": gnorm,
                                                "lr": lr_t}
