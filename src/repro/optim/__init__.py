"""Optimizers: AdamW / SGD-momentum, global-norm clipping, LR schedules,
straight-through-estimator-aware updates for binarized layers.

Optimizer state mirrors the parameter pytree, so the parameter PartitionSpecs
apply verbatim to m/v/momentum — states are born sharded (ZeRO: no replica
ever materializes full optimizer state).
"""

from repro.optim.optimizers import (OptState, adamw_init, adamw_update,
                                    clip_by_global_norm, cosine_schedule,
                                    global_norm, sgdm_init, sgdm_update)

__all__ = ["OptState", "adamw_init", "adamw_update", "clip_by_global_norm",
           "cosine_schedule", "global_norm", "sgdm_init", "sgdm_update"]
