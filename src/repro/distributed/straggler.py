"""Straggler detection + mitigation hooks (host-side, framework layer).

At thousand-node scale, slow hosts (thermal throttling, failing HBM, noisy
neighbors) silently gate every synchronous collective.  The monitor keeps an
EWMA + variance of step wall-times and flags steps whose duration exceeds
``mean + k * std`` (k=3 default).  Mitigation is pluggable:

* ``on_warn`` — log/telemetry (default),
* ``on_persistent`` — called after N consecutive outliers: the launcher's
  hook can demote the host, trigger an elastic re-mesh (checkpoint ->
  restart with the survivor set; see checkpoint.elastic), or re-balance
  microbatches.

Two live consumers: the training launcher (:mod:`repro.launch.train`)
and replica-group serving (:mod:`repro.distributed.replicas`), where a
persistently slow replica is deprioritized by the router exactly like a
health-demoted one.  The monitor is deliberately dependency-free and
unit-testable by injecting synthetic step times.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable


@dataclasses.dataclass
class StragglerMonitor:
    threshold_sigma: float = 3.0
    # Relative guard: a step must ALSO be min_ratio slower than the mean.
    # Near-constant step times make sigma microscopic; without the guard
    # normal jitter (mean + 4 sigma = mean + 0.1%) would flag.
    min_ratio: float = 0.3
    min_samples: int = 10
    persistent_after: int = 5
    ewma_alpha: float = 0.05
    on_warn: Callable[[int, float, float], None] | None = None
    on_persistent: Callable[[int], None] | None = None

    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    _consecutive: int = 0
    _t0: float | None = None
    flagged_steps: list = dataclasses.field(default_factory=list)

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self, step: int) -> bool:
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        """Record one step duration.  Returns True if flagged as outlier."""
        flagged = False
        if self._n >= self.min_samples:
            std = math.sqrt(max(self._var, 1e-12))
            if (dt > self._mean + self.threshold_sigma * std
                    and dt > self._mean * (1 + self.min_ratio)):
                flagged = True
                self.flagged_steps.append((step, dt))
                self._consecutive += 1
                if self.on_warn:
                    self.on_warn(step, dt, self._mean)
                if (self._consecutive >= self.persistent_after
                        and self.on_persistent):
                    self.on_persistent(step)
                    self._consecutive = 0
            else:
                self._consecutive = 0
        # EWMA update only with non-outlier samples so one bad host does
        # not poison the baseline.
        if not flagged:
            a = self.ewma_alpha if self._n else 1.0
            delta = dt - self._mean
            self._mean += a * delta
            self._var = (1 - a) * (self._var + a * delta * delta)
        self._n += 1
        return flagged

    @property
    def mean_step_time(self) -> float:
        return self._mean
