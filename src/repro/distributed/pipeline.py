"""Pipeline-parallel serving placement on the runtime IR (DESIGN.md §13).

The seed module carried a GPipe ``shard_map`` schedule for *training*
over stacked homogeneous layers; serving the PhoneBit graph needs the
opposite decomposition — heterogeneous stages cut from one compiled
graph at its HBM touch points.  That machinery lives in
:mod:`repro.runtime.placement` (cut candidates, cost-balanced DP stage
planner, :class:`StagedExecutor` with per-device committed params and
cross-stage ``device_put`` transfers); this module is the *placement
object* the serving layer accepts:

    server = InferenceServer(engine, placement=Pipelined.over(4))

``InferenceServer`` duck-types placements on ``.kind`` (so
``repro.serving`` never imports this package): ``kind == "pipeline"``
makes every bucket compile through
``engine.compile(..., pipeline=devices)`` into a
:class:`~repro.runtime.placement.StagedExecutor`.  A one-device
``Pipelined`` is the degenerate-but-useful case: a single stage whose
params are committed to that device — how
:class:`~repro.distributed.replicas.ReplicaGroup` pins each replica.

Parity contract: stage boundaries are exact tensor handoffs, so a
pipelined server is bit-exact with the single-device ``cross_check``
oracle — pinned by ``tests/test_distributed.py`` and the
``TestDifferentialFuzz`` forced-mesh sweeps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class Pipelined:
    """Pipeline-parallel placement: stage the graph over ``devices``.

    The plan may produce fewer stages than devices when the graph has
    fewer legal cut points; surplus devices are simply unused (the
    executor reports the realized split via ``stage_report()``).
    """

    devices: tuple[Any, ...]
    kind = "pipeline"

    def __post_init__(self):
        if not self.devices:
            raise ValueError("Pipelined needs at least one device")
        object.__setattr__(self, "devices", tuple(self.devices))

    @classmethod
    def over(cls, n_stages: int, devices: Sequence[Any] | None = None
             ) -> "Pipelined":
        """First ``n_stages`` of ``devices`` (default: all visible)."""
        devices = tuple(devices if devices is not None else jax.devices())
        if n_stages < 1 or n_stages > len(devices):
            raise ValueError(f"n_stages={n_stages} outside 1.."
                             f"{len(devices)} visible devices")
        return cls(devices[:n_stages])

    @property
    def n_stages(self) -> int:
        return len(self.devices)
