"""GPipe-style pipeline parallelism over a mesh axis (optionally ``pod``).

The dry-run meshes use the ``pod`` axis as pure DP (simplest coherent
multi-pod story), but cross-pod links are slow enough that pipelining the
*depth* dimension across pods is the standard alternative — activations
cross the pod boundary once per microbatch instead of gradients every step.
This module provides that schedule as a composable building block:

* stage s owns layers [s·L/P, (s+1)·L/P) — parameters arrive stacked with a
  leading ``n_stages`` dim sharded over the pipeline axis;
* microbatches stream through stages with ``lax.ppermute`` shifting
  activations to the next stage each tick (GPipe fill/drain bubble:
  (P-1)/(M+P-1) of ticks idle);
* runs under ``shard_map`` so the communication schedule is explicit and
  inspectable in the lowered HLO (collective-permute ops, one per tick).

The schedule is validated against a sequential oracle in
tests/test_distributed.py on a host-device mesh.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str, n_microbatches: int):
    """Run ``stage_fn`` as a GPipe pipeline along ``axis``.

    stage_fn(params_local, x_mb) -> y_mb — applies ONE stage's layers to one
    microbatch.  stage_params: pytree whose leaves have leading dim
    n_stages (sharded over ``axis``).  x: (batch, ...) global input; batch
    must divide n_microbatches.  Returns y with the same batch layout.

    All microbatch activations have identical shape, so the loop state is a
    single (mb, ...) buffer per stage; tick t feeds microbatch t to stage 0
    and collects stage P-1's output from tick t+P-1.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches
    x_mbs = x.reshape(n_microbatches, mb, *x.shape[1:])

    def local(params, xl):
        # params: leaves (1, ...) — this stage's slice; xl: (M, mb, ...)
        params = jax.tree.map(lambda p: p[0], params)
        stage = lax.axis_index(axis)
        n_ticks = n_microbatches + n_stages - 1
        state = jnp.zeros_like(xl[0])                  # in-flight activation
        outs = jnp.zeros_like(xl)

        def tick(t, carry):
            state, outs = carry
            # stage 0 ingests microbatch t (if any); others use the
            # activation ppermuted from the previous stage last tick.
            mb_idx = jnp.clip(t, 0, n_microbatches - 1)
            inp = jnp.where(stage == 0, xl[mb_idx], state)
            out = stage_fn(params, inp)
            # last stage stores microbatch (t - (P-1)) when valid
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            store = jnp.logical_and(stage == n_stages - 1,
                                    t >= n_stages - 1)
            outs = jnp.where(store, outs.at[out_idx].set(out), outs)
            # shift to next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            state = lax.ppermute(out, axis, perm)
            return state, outs

        _, outs = lax.fori_loop(0, n_ticks, tick, (state, outs))
        # outputs live on the last stage, every other stage's buffer is
        # still zero -> psum broadcasts them to all shards (out_specs
        # replicate over the pipeline axis).
        return lax.psum(outs, axis)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    out = compat.shard_map(
        local, mesh=mesh,
        in_specs=(spec_p, P()), out_specs=P(),
        check_vma=False,
    )(stage_params, x_mbs)
    return out.reshape(b, *x.shape[1:])


def stack_stages(layer_params, n_stages: int):
    """(L, ...)-stacked layer params -> (n_stages, L/P, ...) stage params."""
    def resh(p):
        l = p.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return p.reshape(n_stages, l // n_stages, *p.shape[1:])
    return jax.tree.map(resh, layer_params)


def make_stage_fn(layer_fn: Callable):
    """Wrap a single-layer fn into a stage fn scanning its layer slice."""
    def stage(params, x):
        def body(h, lp):
            return layer_fn(h, lp), None
        y, _ = lax.scan(body, x, params)
        return y
    return stage
