"""Multi-device scale-out on the runtime IR (DESIGN.md §13).

Three placement shapes behind one serving front end:

sharding      mesh-axis rules (DP/TP/SP/EP for the LM stack) plus the
              ``DataParallel`` serving placement — one executable,
              batch dim split over a mesh axis
pipeline      ``Pipelined`` serving placement — the graph cut into
              per-device stages at HBM touch points
              (:mod:`repro.runtime.placement` owns the cut planner and
              the staged executor)
replicas      ``ReplicaGroup`` — N device-pinned ``InferenceServer``
              replicas (each optionally a pipeline) behind one front
              end, with per-replica health ladders and straggler-aware
              routing; ``LMReplicaGroup`` — LM decode lanes with
              checkpoint-backed sequence migration (DESIGN.md §14.4)
straggler     step-time outlier detection (wired into replica routing)
"""

from repro.distributed import pipeline, replicas, sharding, straggler
from repro.distributed.pipeline import Pipelined
from repro.distributed.replicas import (LMLane, LMReplicaGroup, Replica,
                                        ReplicaGroup)
from repro.distributed.sharding import DataParallel, Rules, rules_for_mesh
from repro.distributed.straggler import StragglerMonitor

__all__ = [
    "pipeline", "replicas", "sharding", "straggler",
    "Pipelined", "DataParallel", "Replica", "ReplicaGroup",
    "LMLane", "LMReplicaGroup",
    "Rules", "rules_for_mesh", "StragglerMonitor",
]
