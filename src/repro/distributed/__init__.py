"""Distributed runtime: sharding rules, pipeline parallelism, compression,
straggler monitoring, elastic re-meshing.

sharding      mesh-aware PartitionSpec rules per model family (DP/TP/SP/EP)
pipeline      optional gpipe-style pipeline parallelism over the pod axis
compression   int8 gradient compression with error feedback (slow links)
straggler     step-time outlier detection + mitigation hooks
"""

from repro.distributed import compression, pipeline, sharding, straggler

__all__ = ["compression", "pipeline", "sharding", "straggler"]
