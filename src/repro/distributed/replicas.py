"""Replica-group serving: one front end over per-device replicas
(DESIGN.md §13.3).

Data-parallel sharding (one executable, batch split by XLA) scales a
*single* batch; replica groups scale *request streams*: N independent
copies of the model, each pinned to its own device (or its own pipeline
of devices), behind one object speaking the standard server protocol —
``submit`` / ``poll`` / ``step`` / ``drain`` / ``metrics``.

The composition recipe is the multi-tenant one
(:mod:`repro.serving.multiplex`), rotated 90°: there, many models share
one device; here, one model spans many devices.  Each replica is a full
:class:`~repro.serving.server.InferenceServer` lane over its own engine
view — own scheduler, own retry policy, own
:class:`~repro.serving.faults.BackendHealth` ladder, own flight
recorder — so the PR 7 resilience machinery applies *per replica* with
no new code:

* a ``device_fault`` / ``device_oom`` injected on one replica demotes
  and quarantines **that replica's** ladder only; the group's router
  steers new work toward healthy replicas while the sick one re-probes
  and promotes per the normal ladder schedule;
* every lane is constructed with ``tenant=<replica name>``, so fault
  plans target one replica by matching ``{"tenant": "r1"}`` at the
  ``server.dispatch`` / ``server.device`` sites, and flight-recorder
  records carry replica attribution for postmortems.

Device pinning reuses pipeline placement: each replica's lane gets a
one-stage (or multi-stage) :class:`~repro.distributed.pipeline.Pipelined`
over its devices, so its bucket executables have params committed to —
and compute placed on — its own device.  Engines are *views*
(``dataclasses.replace``) of one shared artifact: packed weights are
shared host-side; per-replica executable caches are independent.

Routing is health-then-depth: healthy (non-demoted, non-slow) replicas
are preferred, ties broken by queue depth then round-robin.  A
:class:`~repro.distributed.straggler.StragglerMonitor` per replica
watches step wall-times; a persistently slow replica (thermal throttle,
noisy neighbor) is deprioritized exactly like a demoted one, and
rejoins the preferred set when its step times recover.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

from repro.distributed.pipeline import Pipelined
from repro.distributed.straggler import StragglerMonitor
from repro.obs import trace as _trace
from repro.serving.scheduler import Request
from repro.serving.server import InferenceServer


class Replica:
    """One replica lane: its server, devices, and straggler state."""

    __slots__ = ("name", "server", "devices", "monitor", "slow", "rr")

    def __init__(self, name: str, server: InferenceServer,
                 devices: tuple, monitor: StragglerMonitor):
        self.name = name
        self.server = server
        self.devices = devices
        self.monitor = monitor
        # Set by the monitor's persistent-outlier hook; cleared when a
        # subsequent step is NOT flagged (the replica caught back up).
        self.slow = False
        self.rr = 0  # round-robin tiebreak stamp

    @property
    def healthy(self) -> bool:
        # Demoted = the lane's live mode sits below the engine's
        # configured mode (promotion back up restores health).
        h = self.server.health
        demoted = h is not None and h.mode != self.server.engine.matmul_mode
        return not demoted and not self.slow


class ReplicaGroup:
    """N device-pinned InferenceServer replicas behind one front end.

    ``devices_per_replica`` > 1 composes both parallelism axes: each
    replica is itself a pipeline over that many devices (replicas of
    pipelines — the scale-out shape data_parallel×pipeline cannot
    express in one executable).

    Keyword arguments become defaults for every replica's
    ``InferenceServer``; each lane gets ``tenant=<name>`` and a
    ``Pipelined`` placement over its device slice.
    """

    def __init__(self, engine, devices: Sequence[Any], *,
                 devices_per_replica: int = 1,
                 names: Sequence[str] | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] | None = None,
                 slow_after: int = 3,
                 **server_kw):
        devices = tuple(devices)
        k = int(devices_per_replica)
        if k < 1 or len(devices) < k:
            raise ValueError(f"devices_per_replica={k} needs at least "
                             f"{k} of {len(devices)} devices")
        if len(devices) % k:
            raise ValueError(f"{len(devices)} devices do not split into "
                             f"replicas of {k}")
        n = len(devices) // k
        names = tuple(names if names is not None
                      else (f"r{i}" for i in range(n)))
        if len(names) != n:
            raise ValueError(f"{len(names)} names for {n} replicas")
        self.clock = clock
        self._sleep = sleep if sleep is not None \
            else (lambda s: time.sleep(min(s, 0.05)))
        kw = dict(server_kw)
        kw.setdefault("clock", clock)
        self.replicas: dict[str, Replica] = {}
        self._rr = 0
        for i, name in enumerate(names):
            devs = devices[i * k:(i + 1) * k]
            # A view of the shared artifact with its own executable
            # cache (dataclasses.replace drops cached_property state).
            eng = dataclasses.replace(engine)
            server = InferenceServer(eng, tenant=name,
                                     placement=Pipelined(devs), **kw)
            monitor = StragglerMonitor(persistent_after=slow_after)
            rep = Replica(name, server, devs, monitor)
            # Persistent outlier → deprioritize in routing; any clean
            # step clears the flag (see _observe_step).
            monitor.on_persistent = (
                lambda step, _r=rep: setattr(_r, "slow", True))
            self.replicas[name] = rep

    # ---- warm-up ----------------------------------------------------------
    def compile_buckets(self) -> dict[str, dict[int, float]]:
        """Precompile every replica's bucket executables (per-device
        compile: each replica's params are committed to its devices).
        After this, serving triggers zero retraces group-wide."""
        return {name: rep.server.compile_buckets()
                for name, rep in self.replicas.items()}

    @property
    def trace_count(self) -> int:
        return sum(r.server.engine.trace_count
                   for r in self.replicas.values())

    # ---- routing ----------------------------------------------------------
    def _route(self) -> Replica:
        """Health-then-depth-then-round-robin replica choice."""
        reps = list(self.replicas.values())
        healthy = [r for r in reps if r.healthy]
        pool = healthy if healthy else reps
        self._rr += 1
        chosen = min(pool, key=lambda r: (r.server.queue_depth, r.rr))
        chosen.rr = self._rr
        return chosen

    # ---- request lifecycle ------------------------------------------------
    def submit(self, payload: Any, replica: str | None = None,
               **kw) -> Request:
        """Route one request to a replica (or pin it with ``replica=``)."""
        rep = self.replicas[replica] if replica is not None \
            else self._route()
        r = rep.server.submit(payload, **kw)
        _trace.instant("replica.route", "serve", req=r.id,
                       replica=rep.name)
        return r

    def poll(self, request: Request) -> bool:
        return request.done

    # ---- serving loop -----------------------------------------------------
    def _observe_step(self, rep: Replica, dt: float, step_no: int) -> None:
        flagged = rep.monitor.observe(step_no, dt)
        if not flagged and rep.slow:
            rep.slow = False    # caught back up: rejoin the healthy pool

    def step(self, now: float | None = None,
             force: bool = False) -> list[Request]:
        """One tick across every replica (each replica's dispatch and
        readback run in its own lane; devices execute concurrently).
        Returns all requests completed this tick."""
        done: list[Request] = []
        for rep in self.replicas.values():
            t = self.clock() if now is None else now
            t0 = time.perf_counter()
            done += rep.server.step(t, force=force)
            self._observe_step(rep, time.perf_counter() - t0,
                               rep.monitor._n)
        return done

    def _busy(self) -> bool:
        return any(len(r.server.scheduler) or r.server._pending is not None
                   for r in self.replicas.values())

    def drain(self, now: float | None = None,
              max_steps: int | None = None) -> list[Request]:
        """Serve until every replica is idle; bounded like
        ``InferenceServer.drain`` (wedged stragglers terminally error)."""
        if max_steps is None:
            budget = max([(r.server.retry.max_attempts if r.server.retry
                           else 1) for r in self.replicas.values()] or [1])
            queued = sum(len(r.server.scheduler)
                         for r in self.replicas.values())
            max_steps = 4 * (queued + 2 * max(len(self.replicas), 1)
                             + 2) * budget + 16
        done: list[Request] = []
        steps = 0
        while self._busy():
            if steps >= max_steps:
                t = self.clock() if now is None else now
                for rep in self.replicas.values():
                    done += rep.server._abort_wedged(t)
                break
            steps += 1
            t = self.clock() if now is None else now
            done += self.step(t, force=True)
            if all(r.server._pending is None
                   for r in self.replicas.values()):
                queued = [r for r in self.replicas.values()
                          if len(r.server.scheduler)]
                waits = [r.server.scheduler.backoff_wait(t)
                         for r in queued]
                if queued and all(w is not None and w > 0 for w in waits):
                    self._sleep(min(waits))
        return done

    # ---- observability ----------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return sum(r.server.queue_depth for r in self.replicas.values())

    def metrics(self) -> dict:
        """Per-replica server snapshots plus the routing ledger (health,
        slow flag, devices, mean step time)."""
        return {
            "replicas": {name: rep.server.metrics()
                         for name, rep in self.replicas.items()},
            "routing": {name: {
                "healthy": rep.healthy,
                "slow": rep.slow,
                "mode": (rep.server.health.mode
                         if rep.server.health is not None
                         else rep.server.engine.matmul_mode),
                "devices": [str(d) for d in rep.devices],
                "mean_step_s": round(rep.monitor.mean_step_time, 6),
            } for name, rep in self.replicas.items()},
            "queue_depth": self.queue_depth,
        }


# ---------------------------------------------------------------------------
# LM decode lanes with cross-lane sequence migration (DESIGN.md §14.4)
# ---------------------------------------------------------------------------

class LMLane:
    """One LM decode lane: its server plus quarantine state.  Unlike
    the BNN replica, whose ladder quarantines *backends*, a lane
    quarantines the whole decode loop: a lane that exhausted its
    in-lane restore budget hands its flight away and sits out a
    doubling probe interval before routing sends it new work."""

    __slots__ = ("name", "server", "quarantined_until", "probe_interval",
                 "quarantines", "rr")

    def __init__(self, name: str, server, probe_after_s: float):
        self.name = name
        self.server = server
        self.quarantined_until: float | None = None
        self.probe_interval = probe_after_s
        self.quarantines = 0
        self.rr = 0

    def quarantined(self, now: float) -> bool:
        return (self.quarantined_until is not None
                and now < self.quarantined_until)


class LMReplicaGroup:
    """N continuous-batching LM lanes behind one front end, with
    checkpoint-backed sequence migration (DESIGN.md §14.4).

    Each lane is a full :class:`~repro.serving.lm_server.LMServer`
    (``tenant=<name>``, so fault plans target one lane by matching
    ``{"tenant": "lm1"}`` at ``lm.step``).  The group installs itself
    as every lane's ``evacuate`` hook: when a lane's decode faults
    outlast its restore budget, its in-flight sequences — prompt plus
    every already-emitted token, which the checkpoint/restore machinery
    kept intact host-side — are *adopted* by a healthy lane via replay
    prefill.  Migration is prefix-preserving, not bit-exact (RoPE
    positions and cache history differ across lanes — §14.4); the
    emitted prefix is kept verbatim and only future tokens come from
    the new lane.  The evacuated lane is quarantined with a doubling
    probe interval and rejoins routing when it expires.

    Keyword arguments become defaults for every lane's ``LMServer``.
    """

    def __init__(self, cfg, rules, params, *, n_slots: int, max_seq: int,
                 n_lanes: int = 2, names: Sequence[str] | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 probe_after_s: float = 30.0, probe_backoff: float = 2.0,
                 **lane_kw):
        from repro.serving.lm_server import LMServer

        names = tuple(names if names is not None
                      else (f"lm{i}" for i in range(n_lanes)))
        self.clock = clock
        self.probe_backoff = probe_backoff
        self.migrations = 0     # sequences adopted across lanes
        self._rr = 0
        kw = dict(lane_kw)
        kw.setdefault("clock", clock)
        kw.setdefault("checkpoint_every", 4)
        self.lanes: dict[str, LMLane] = {}
        for name in names:
            server = LMServer(cfg=cfg, rules=rules, params=params,
                              n_slots=n_slots, max_seq=max_seq,
                              tenant=name, **kw)
            lane = LMLane(name, server, probe_after_s)
            server.evacuate = (
                lambda items, _lane=lane: self._adopt(_lane, items))
            self.lanes[name] = lane

    # ---- migration --------------------------------------------------------
    def _adopt(self, origin: LMLane, items: list) -> bool:
        """Evacuation hook for one lane: find a healthy lane with room
        for the whole flight, replay-prefill every sequence there, and
        quarantine the origin.  All-or-nothing (partial adoption would
        split one consistent flight across inconsistent outcomes)."""
        now = self.clock()
        candidates = sorted(
            (ln for ln in self.lanes.values()
             if ln is not origin and not ln.quarantined(now)),
            key=lambda ln: (ln.server.queue_depth, ln.rr))
        target = next(
            (ln for ln in candidates
             if len(ln.server.manager._free) >= len(items)), None)
        if target is None:
            return False
        for r, seq in items:
            target.server.adopt_sequence(r, seq.prompt, seq.tokens,
                                         seq.max_new)
        origin.quarantined_until = now + origin.probe_interval
        origin.probe_interval *= self.probe_backoff
        origin.quarantines += 1
        self.migrations += len(items)
        _trace.instant("replica.migrate", "serve", n=len(items),
                       src=origin.name, dst=target.name)
        target.server.flight.record(kind="migration", outcome="adopted",
                                    seqs=len(items), src=origin.name,
                                    done_s=now)
        return True

    # ---- routing ----------------------------------------------------------
    def _route(self, now: float) -> LMLane:
        lanes = list(self.lanes.values())
        pool = [ln for ln in lanes if not ln.quarantined(now)] or lanes
        self._rr += 1
        chosen = min(pool, key=lambda ln: (ln.server.queue_depth, ln.rr))
        chosen.rr = self._rr
        return chosen

    # ---- request lifecycle ------------------------------------------------
    def submit(self, prompt: list[int], max_new: int = 16,
               lane: str | None = None, **kw) -> Request:
        now = self.clock()
        ln = self.lanes[lane] if lane is not None else self._route(now)
        r = ln.server.submit(prompt, max_new=max_new, **kw)
        _trace.instant("replica.route", "serve", req=r.id, lane=ln.name)
        return r

    def poll(self, request: Request) -> bool:
        return request.done

    # ---- serving loop -----------------------------------------------------
    def serve_tick(self, now: float | None = None) -> list[Request]:
        done: list[Request] = []
        for ln in self.lanes.values():
            done += ln.server.serve_tick(now)
        return done

    def _busy(self) -> bool:
        return any(ln.server.queue_depth for ln in self.lanes.values())

    def drain(self, now: float | None = None,
              max_steps: int | None = None) -> list[Request]:
        """Serve until every lane is idle; bounded like
        ``LMServer.drain`` (wedged lanes terminally error)."""
        if max_steps is None:
            budget = max((ln.server.retry.max_attempts
                          if ln.server.retry else 1)
                         for ln in self.lanes.values())
            outstanding = sum(ln.server.queue_depth
                              for ln in self.lanes.values()) + 1
            max_seq = max(ln.server.max_seq for ln in self.lanes.values())
            max_steps = outstanding * (max_seq + budget) * 2 + 16
        done: list[Request] = []
        steps = 0
        while self._busy():
            if steps >= max_steps:
                for ln in self.lanes.values():
                    done += ln.server.drain(now=now, max_steps=0)
                break
            steps += 1
            done += self.serve_tick(now)
        return done

    # ---- observability ----------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return sum(ln.server.queue_depth for ln in self.lanes.values())

    def metrics(self) -> dict:
        now = self.clock()
        return {
            "lanes": {name: ln.server.metrics()
                      for name, ln in self.lanes.items()},
            "routing": {name: {
                "quarantined": ln.quarantined(now),
                "quarantines": ln.quarantines,
                "restores": ln.server.restores,
                "evacuations": ln.server.evacuations,
            } for name, ln in self.lanes.items()},
            "migrations": self.migrations,
            "queue_depth": self.queue_depth,
        }
