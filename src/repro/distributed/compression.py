"""Gradient compression with error feedback (slow cross-pod links).

On a multi-pod mesh the ``pod`` axis rides data-center interconnect, far
slower than intra-pod ICI.  Before the cross-pod gradient reduction we
quantize to int8 with a per-tensor scale and carry the quantization residual
into the next step (error feedback, Seide et al. / Karimireddy et al.), which
keeps SGD/Adam convergence unaffected while cutting pod-link bytes 4×
(f32 -> i8).

Usage inside a train step::

    grads, err = compress_decompress(grads, err)      # quantize + EF
    # ... optimizer update uses the dequantized grads as usual; the psum
    # over the pod axis happens on the int8 representation when executed
    # under shard_map (see apply_pod_compressed_mean).

Pure-pjit training can also use :func:`compress_decompress` as a *simulated*
compressor (quantize->dequantize locally): GSPMD still reduces in f32, but
the numerical effect — and the EF state machinery, checkpointing, tests —
are identical, and the shard_map path below demonstrates the real wire
format.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


def quantize_int8(x: jnp.ndarray):
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Any, err: Any | None):
    """Quantize each gradient leaf with error feedback.

    err is the residual tree from the previous step (or None).  Returns
    (dequantized grads, new err tree).
    """
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        dq = dequantize_int8(q, scale)
        return dq.astype(g.dtype), corrected - dq

    pairs = jax.tree.map(one, grads, err)
    new_g = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e


def pod_compressed_mean(grads: Any, err: Any | None, mesh, *,
                        pod_axis: str = "pod"):
    """Mean-reduce gradients over the pod axis on the int8 wire format.

    Runs under shard_map with everything else replicated along ``pod``:
    each pod quantizes (with EF), psums the *int8-valued* payload (carried
    in f32 lanes — XLA's psum has no int8 accumulator, the wire win is the
    4x-smaller payload), rescales, and dequantizes.
    """
    if pod_axis not in mesh.axis_names:
        return compress_decompress(grads, err)

    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def local(g, e):
        def one(gl, el):
            corrected = gl.astype(jnp.float32) + el
            q, scale = quantize_int8(corrected)
            # payload = int8 values; scale is per-pod -> take the max so
            # dequantization is conservative and shared.
            scale_g = lax.pmax(scale, pod_axis)
            qsum = lax.psum(q.astype(jnp.float32), pod_axis)
            n = lax.psum(jnp.ones((), jnp.float32), pod_axis)
            dq = qsum * scale_g / n
            return dq.astype(gl.dtype), corrected - dequantize_int8(q, scale)
        pairs = jax.tree.map(one, g, e)
        ng = jax.tree.map(lambda t: t[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
        ne = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
        return ng, ne

    from jax.sharding import PartitionSpec as P
    spec = jax.tree.map(lambda _: P(), grads)
    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec), out_specs=(spec, spec),
        check_vma=False,
    )(grads, err)
