"""Mesh-aware sharding rules (DP / FSDP / TP / SP / EP).

One :class:`Rules` object describes how a mesh's axes are used:

* ``batch`` axes — data parallelism.  ``("pod", "data")`` on the multi-pod
  mesh (pods are DP replicas for the dry-run), ``("data",)`` single-pod.
* ``model`` axis — tensor/sequence/expert parallelism, context-dependent:
  - LM activations: the *sequence* dim of the residual stream (SP), so every
    matmul parallelizes over tokens regardless of head-count divisibility;
  - attention: Q-head sharding when ``n_heads % model == 0`` (Megatron TP,
    enables the triangular causal schedule), else sequence-sharded Q;
  - MoE: the expert dim (EP) with explicit all_to_all (see models.moe);
  - decode KV caches: the sequence dim (flash-decoding SP);
  - vision/diffusion: channel / head dims.
* FSDP — parameters are additionally sharded over the ``data`` axis
  (ZeRO-3 style); with scan-over-layers the per-layer all-gather happens
  once per scan step, overlapped by XLA with the previous layer's compute.

All helpers are divisibility-safe: a dim that does not divide the axis size
falls back to replication (GSPMD/pjit reject non-divisible input shardings),
and the fallback is recorded so the dry-run can report it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    """Axis-usage rules for one mesh."""
    mesh: Mesh
    batch: tuple[str, ...] = ("data",)
    model: str = "model"
    fsdp: str = "data"

    # ---- axis sizes -------------------------------------------------------
    def axis_size(self, name: str | tuple[str, ...] | None) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            size = 1
            for n in name:
                size *= self.mesh.shape[n]
            return size
        return self.mesh.shape[name]

    @property
    def dp(self) -> int:
        return self.axis_size(self.batch)

    @property
    def tp(self) -> int:
        return self.axis_size(self.model)

    @property
    def n_devices(self) -> int:
        return self.dp * self.tp

    # ---- divisibility-safe spec atoms --------------------------------------
    def shard_if(self, dim: int, axes: str | tuple[str, ...] | None):
        """Return ``axes`` if ``dim`` divides their product, else None."""
        if axes is None:
            return None
        if dim % self.axis_size(axes) == 0:
            return axes
        return None

    def batch_spec(self, batch_size: int):
        """Best batch-dim sharding: all batch axes, progressively fewer."""
        axes = self.batch
        while axes:
            if batch_size % self.axis_size(axes) == 0:
                return axes if len(axes) > 1 else axes[0]
            axes = axes[1:]
        return None

    def tokens_spec(self, n_tokens: int):
        """Token dim over batch axes + model axis (flattened (B*S, D))."""
        full = (*self.batch, self.model)
        if n_tokens % self.axis_size(full) == 0:
            return full
        return self.batch_spec(n_tokens)

    # ---- shardings ----------------------------------------------------------
    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def tree_shardings(self, spec_tree: Any) -> Any:
        # None is a structural empty node (e.g. SGD's nu=None), not a spec.
        return jax.tree.map(
            self.named, spec_tree, is_leaf=lambda x: isinstance(x, P))

    def constrain(self, x, *spec):
        return jax.lax.with_sharding_constraint(x, self.sharding(*spec))


@dataclasses.dataclass(frozen=True)
class DataParallel:
    """Data-parallel serving placement (DESIGN.md §13): shard the batch
    dim of every bucket over ``mesh``'s ``axis``.

    The generalized form of ``InferenceServer(mesh=, data_axis=)`` —
    the server duck-types it on ``.kind == "data"`` and derives mesh +
    axis from it, so data- and pipeline-parallel serving share one
    ``placement=`` surface.  One executable: XLA splits each bucket via
    ``NamedSharding(mesh, P(axis))``; buckets are rounded up to shard
    evenly and autotuning runs at the per-device shard shape.
    """

    mesh: Mesh
    axis: str = "data"
    kind = "data"

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(f"axis {self.axis!r} not in mesh axes "
                             f"{self.mesh.axis_names}")

    @classmethod
    def over(cls, n_shards: int, axis: str = "data") -> "DataParallel":
        """A host mesh of the first ``n_shards`` visible devices."""
        from repro.launch.mesh import make_host_mesh

        return cls(make_host_mesh(data=n_shards, model=1), axis)

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])


def single_pod_rules(mesh: Mesh) -> Rules:
    return Rules(mesh=mesh, batch=("data",))


def multi_pod_rules(mesh: Mesh) -> Rules:
    return Rules(mesh=mesh, batch=("pod", "data"))


def rules_for_mesh(mesh: Mesh) -> Rules:
    """Infer rules from the mesh's axis names."""
    if "pod" in mesh.axis_names:
        return multi_pod_rules(mesh)
    return single_pod_rules(mesh)


def spec_tree_like(params: Any, fn) -> Any:
    """Build a PartitionSpec pytree by mapping ``fn(path, leaf)``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [fn(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)
