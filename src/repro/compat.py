"""Version-compat shims for the installed jax.

The codebase targets the modern jax API surface; older installs spell some
of it differently.  Everything here is a thin rename — no behavioral
wrappers — so call sites read like modern jax.

* ``shard_map``: ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old), whose replication-check
  kwarg is ``check_rep`` rather than ``check_vma``.
* ``AxisType`` handling lives in :mod:`repro.launch.mesh` (meshes are
  implicitly auto-typed on old jax).
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:  # jax < 0.6: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
