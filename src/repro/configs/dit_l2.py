"""dit-l2 [arXiv:2212.09748; paper] — DiT-L/2.

img_res=256 (latent 32²×4), patch=2, 24L d_model=1024 16H.
"""

from repro.configs.shapes import DIFFUSION_SHAPES
from repro.models.dit import DiTConfig

FAMILY = "diffusion"
SHAPES = DIFFUSION_SHAPES

# Production defaults carry the hillclimbed settings (EXPERIMENTS §Perf
# H1: Megatron-SP residual + dots remat, +54% roofline); the baseline
# artifacts in artifacts/dryrun/ were measured with both off.
FULL = DiTConfig(
    name="dit-l2", img_res=256, patch=2, n_layers=24, d_model=1024,
    n_heads=16, seq_shard=True, remat_policy="dots",
)

SMOKE = DiTConfig(
    name="dit-smoke", img_res=64, patch=2, n_layers=2, d_model=64,
    n_heads=4, n_classes=10,
)
