"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01; unverified].

40L d_model=8192 64H (GQA kv=8, head 128) d_ff=22528, vocab 256000,
no biases, tied embeddings (Cohere ties input/output embeddings).
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

FAMILY = "lm"
SHAPES = LM_SHAPES

FULL = LMConfig(
    name="command-r-35b",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22528, vocab=256000,
    tie_embeddings=True, rope_theta=10_000.0, mlp_act="swiglu",
)

SMOKE = LMConfig(
    name="command-r-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=160, vocab=256,
    tie_embeddings=True, rope_theta=10_000.0, mlp_act="swiglu",
)
