"""dit-xl2 [arXiv:2212.09748; paper] — DiT-XL/2.

img_res=256 (latent 32²×4), patch=2, 28L d_model=1152 16H.
"""

from repro.configs.shapes import DIFFUSION_SHAPES
from repro.models.dit import DiTConfig

FAMILY = "diffusion"
SHAPES = DIFFUSION_SHAPES

# Production defaults carry the hillclimbed settings (EXPERIMENTS §Perf
# H1); baseline artifacts were measured with both off.
FULL = DiTConfig(
    name="dit-xl2", img_res=256, patch=2, n_layers=28, d_model=1152,
    n_heads=16, seq_shard=True, remat_policy="dots",
)

SMOKE = DiTConfig(
    name="dit-xl-smoke", img_res=64, patch=2, n_layers=2, d_model=48,
    n_heads=4, n_classes=10,
)
