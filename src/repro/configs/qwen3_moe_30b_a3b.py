"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4, head_dim 128 — Qwen3 uses a decoupled head
dim) expert d_ff=768, vocab 151936, MoE 128 experts top-8, QK-norm.
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

FAMILY = "lm"
SHAPES = LM_SHAPES

FULL = LMConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=0, vocab=151936,
    n_experts=128, top_k=8, d_ff_expert=768,
    qk_norm=True, tie_embeddings=False, rope_theta=1_000_000.0,
    mlp_act="swiglu",
)

SMOKE = LMConfig(
    name="qwen3-moe-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=0, vocab=256,
    n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=4.0,
    qk_norm=True, rope_theta=1_000_000.0, mlp_act="swiglu",
)
