"""vit-h14 [arXiv:2010.11929; paper] — ViT-H/14.

img_res=224 patch=14 32L d_model=1280 16H d_ff=5120.
"""

from repro.configs.shapes import VISION_SHAPES
from repro.models.vit import ViTConfig

FAMILY = "vision"
SHAPES = VISION_SHAPES

FULL = ViTConfig(
    name="vit-h14", img_res=224, patch=14, n_layers=32, d_model=1280,
    n_heads=16, d_ff=5120, pos_grid=16,
)

SMOKE = ViTConfig(
    name="vit-h-smoke", img_res=28, patch=7, n_layers=2, d_model=32,
    n_heads=4, d_ff=64, n_classes=10, pos_grid=4,
)
