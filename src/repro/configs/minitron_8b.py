"""minitron-8b [arXiv:2407.14679; hf] — pruned Nemotron-4.

32L d_model=4096 32H (GQA kv=8, head 128) d_ff=16384, vocab 256000.
Nemotron lineage: squared-ReLU MLP (no gate), untied embeddings.
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

FAMILY = "lm"
SHAPES = LM_SHAPES

FULL = LMConfig(
    name="minitron-8b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=256000,
    tie_embeddings=False, rope_theta=10_000.0, mlp_act="relu2",
)

SMOKE = LMConfig(
    name="minitron-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=128, vocab=256,
    rope_theta=10_000.0, mlp_act="relu2",
)
