"""efficientnet-b7 [arXiv:1905.11946; paper].

width_mult=2.0 depth_mult=3.1 (native img_res 600; the assigned shape set
runs 224/384 — native-600 is exercised by the benchmark harness).
PhoneBit technique: 1×1 expand/project convs binarize (binary variant).
"""

from repro.configs.shapes import VISION_SHAPES
from repro.models.efficientnet import EffNetConfig

FAMILY = "vision"
SHAPES = VISION_SHAPES

FULL = EffNetConfig(
    name="efficientnet-b7", img_res=600, width=2.0, depth=3.1,
)

SMOKE = EffNetConfig(
    name="efficientnet-smoke", img_res=32, width=0.5, depth=0.4,
    n_classes=10,
)
