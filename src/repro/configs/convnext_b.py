"""convnext-b [arXiv:2201.03545; paper].

img_res=224 depths=(3,3,27,3) dims=(128,256,512,1024).
PhoneBit technique: 1×1 MLP convs binarize (binary variant); 7×7 depthwise
stays float (DESIGN §6).
"""

from repro.configs.shapes import VISION_SHAPES
from repro.models.convnext import ConvNeXtConfig

FAMILY = "vision"
SHAPES = VISION_SHAPES

FULL = ConvNeXtConfig(
    name="convnext-b", img_res=224, depths=(3, 3, 27, 3),
    dims=(128, 256, 512, 1024),
)

SMOKE = ConvNeXtConfig(
    name="convnext-smoke", img_res=32, depths=(1, 1, 2, 1),
    dims=(16, 32, 64, 128), n_classes=10,
)
