"""vit-l16 [arXiv:2010.11929; paper] — ViT-L/16.

img_res=224 patch=16 24L d_model=1024 16H d_ff=4096.
PhoneBit technique: QKV/MLP dense projections binarize (binary variant).
"""

from repro.configs.shapes import VISION_SHAPES
from repro.models.vit import ViTConfig

FAMILY = "vision"
SHAPES = VISION_SHAPES

FULL = ViTConfig(
    name="vit-l16", img_res=224, patch=16, n_layers=24, d_model=1024,
    n_heads=16, d_ff=4096, pos_grid=14,
)

SMOKE = ViTConfig(
    name="vit-smoke", img_res=32, patch=8, n_layers=2, d_model=32,
    n_heads=4, d_ff=64, n_classes=10, pos_grid=4,
)
