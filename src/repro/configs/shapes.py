"""Assigned input-shape sets, one table per architecture family.

Every (arch × shape) pair is a dry-run *cell*; ``kind`` selects which step
gets lowered:

  train    train_step  (fwd + bwd + optimizer)
  prefill  prefill_step (prompt forward + KV-cache build)
  decode   decode_step (one token against a seq_len KV cache)
  sample   sample_step (one denoising forward; × steps for a full image)
  serve    inference forward
  skip     cell is skipped (reason recorded) — long_500k on the pure
           full-attention LM archs per the assignment rule.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # vision/diffusion fields
    img_res: int = 0
    batch: int = 0
    steps: int = 0
    note: str = ""


LM_SHAPES = (
    Shape("train_4k", "train", seq_len=4_096, global_batch=256),
    Shape("prefill_32k", "prefill", seq_len=32_768, global_batch=32),
    Shape("decode_32k", "decode", seq_len=32_768, global_batch=128),
    Shape("long_500k", "skip", seq_len=524_288, global_batch=1,
          note="pure full-attention arch: 512k full attention is "
               "out of budget by construction (DESIGN.md "
               "§Arch-applicability); sub-quadratic override not a "
               "published config"),
)

DIFFUSION_SHAPES = (
    Shape("train_256", "train", img_res=256, batch=256, steps=1_000),
    Shape("gen_1024", "sample", img_res=1_024, batch=4, steps=50),
    Shape("gen_fast", "sample", img_res=512, batch=16, steps=4),
    Shape("train_1024", "train", img_res=1_024, batch=32, steps=1_000),
)

VISION_SHAPES = (
    Shape("cls_224", "train", img_res=224, batch=256),
    Shape("cls_384", "train", img_res=384, batch=64),
    Shape("serve_b1", "serve", img_res=224, batch=1),
    Shape("serve_b128", "serve", img_res=224, batch=128),
)

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "diffusion": DIFFUSION_SHAPES,
    "vision": VISION_SHAPES,
}
