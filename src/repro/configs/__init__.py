"""Architecture registry: ``--arch <id>`` resolution for every assigned
architecture (plus the paper's own BNN workloads in ``paper_bnn``).

Each arch module exports FULL (exact published config), SMOKE (reduced
same-family config for CPU tests), FAMILY and SHAPES.  ``get(arch_id)``
returns the record; ``all_cells()`` enumerates the 40 (arch × shape)
dry-run cells.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

from repro.configs.shapes import FAMILY_SHAPES, Shape

ARCH_IDS = (
    "granite-moe-3b-a800m",
    "qwen3-moe-30b-a3b",
    "minitron-8b",
    "command-r-35b",
    "dit-l2",
    "dit-xl2",
    "efficientnet-b7",
    "convnext-b",
    "vit-l16",
    "vit-h14",
)


@dataclasses.dataclass(frozen=True)
class ArchRecord:
    arch_id: str
    family: str
    full: Any
    smoke: Any
    shapes: tuple[Shape, ...]

    def shape(self, name: str) -> Shape:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}; "
                       f"have {[s.name for s in self.shapes]}")


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_")


def get(arch_id: str) -> ArchRecord:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(_module_name(arch_id))
    return ArchRecord(arch_id=arch_id, family=mod.FAMILY, full=mod.FULL,
                      smoke=mod.SMOKE, shapes=tuple(mod.SHAPES))


def all_cells() -> list[tuple[str, Shape]]:
    """All 40 (arch, shape) dry-run cells, skips included."""
    cells = []
    for arch_id in ARCH_IDS:
        rec = get(arch_id)
        for shape in rec.shapes:
            cells.append((arch_id, shape))
    return cells
