"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-3b-a800m-base; hf].

32L d_model=1536 24H (GQA kv=8, head 64) expert d_ff=512, vocab 49155,
MoE 40 experts top-8, tied embeddings.  (The assignment line reads
"MoE 40e top-8 — 32 experts top-8"; 40 matches the first clause and the HF
config, so 40 is used.)

Systems notes: 24 heads do not divide the 16-way model axis, so this arch
exercises the SP (sequence-sharded Q) attention path; 40 experts pad to 48
for 16-way EP (8 masked slots — see models.moe).
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

FAMILY = "lm"
SHAPES = LM_SHAPES

FULL = LMConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=0, vocab=49155,
    n_experts=40, top_k=8, d_ff_expert=512,
    tie_embeddings=True, rope_theta=10_000.0, mlp_act="swiglu",
)

# Reduced same-family smoke config: MoE, non-divisible heads, tied embed.
SMOKE = LMConfig(
    name="granite-moe-smoke",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, d_head=8,
    d_ff=0, vocab=256,
    n_experts=5, top_k=2, d_ff_expert=32, capacity_factor=4.0,
    tie_embeddings=True, rope_theta=10_000.0, mlp_act="swiglu",
)
