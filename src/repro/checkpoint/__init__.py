"""Checkpointing: atomic save/restore, async writer, elastic re-mesh.

store     atomic npz-tree checkpoints (tmp + os.replace), retention,
          async background writer so the train loop never blocks on disk
elastic   restore onto a *different* mesh: arrays are saved as full host
          arrays and re-placed with the new mesh's shardings, so a job can
          restart with a different device count (survivor set after a node
          failure) without format conversion

At 1000+-node scale the npz host-array format would be replaced by a
distributed array store (tensorstore/OCDBT) with per-host shards; the
interface (save/restore/elastic_restore) is format-agnostic on purpose and
DESIGN.md records the swap point.
"""

from repro.checkpoint.store import (CheckpointManager, latest_step,
                                    restore, save)

__all__ = ["CheckpointManager", "latest_step", "restore", "save"]
