"""Atomic, restart-safe checkpoint store (npz pytree format).

Write protocol (crash-safe):
  1. serialize the pytree to ``<dir>/tmp.<step>.npz`` (unique temp name),
  2. ``os.replace`` to ``<dir>/step_<step>.npz`` — atomic on POSIX,
  3. update retention (keep last N), never deleting the file just written.

A checkpoint is therefore either fully present or absent; a job killed
mid-write leaves only a tmp file that the next run ignores and overwrites.

``CheckpointManager`` adds an async writer thread: ``save_async`` snapshots
the pytree to host memory (device_get) on the caller's thread — cheap — and
does the (slow) compression+disk work in the background, so the training
loop never blocks on storage.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import re
import threading
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"step_(\d+)\.npz$")
_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def save(directory: str | os.PathLike, step: int, tree: Any) -> str:
    """Atomically write one checkpoint.  Returns the final path."""
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"tmp.{step}.{os.getpid()}.npz"
    final = d / f"step_{step}.npz"
    np.savez_compressed(tmp, **_flatten(tree))
    os.replace(tmp, final)
    return str(final)


def latest_step(directory: str | os.PathLike) -> int | None:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for f in d.iterdir()
             if (m := _STEP_RE.search(f.name))]
    return max(steps) if steps else None


def restore(directory: str | os.PathLike, step: int, like: Any,
            shardings: Any | None = None) -> Any:
    """Restore into the structure of ``like``.

    ``shardings``: optional pytree of NamedSharding — arrays are placed
    with these shardings (elastic restore: the mesh may differ from the
    one that saved; full host arrays reshard transparently).
    """
    path = pathlib.Path(directory) / f"step_{step}.npz"
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), sh in zip(flat, shard_leaves):
        key = jax.tree_util.keystr(p)
        arr = data[key]
        expected = tuple(leaf.shape)
        if tuple(arr.shape) != expected:
            raise ValueError(f"checkpoint leaf {key} has shape "
                             f"{arr.shape}, expected {expected}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ---- sync ----------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        path = save(self.directory, step, tree)
        self._retain()
        return path

    # ---- async ---------------------------------------------------------
    def save_async(self, step: int, tree: Any) -> None:
        """Snapshot to host now, write in the background."""
        self.wait()  # one in-flight write at a time
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))

        def work():
            try:
                save(self.directory, step, host_tree)
                self._retain()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---- restore ---------------------------------------------------------
    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def restore_latest(self, like: Any, shardings: Any | None = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, restore(self.directory, step, like, shardings)

    def _retain(self) -> None:
        d = pathlib.Path(self.directory)
        files = sorted(
            ((int(m.group(1)), f) for f in d.iterdir()
             if (m := _STEP_RE.search(f.name))))
        for _, f in files[:-self.keep] if self.keep else []:
            f.unlink(missing_ok=True)
