"""The paper's own workloads: AlexNet, VGG16, YOLOv2-Tiny (Tab II-IV, Fig 5).

Each network exists in two execution forms sharing one latent-float
parameter set:

* **BNN engine form** — ``core.bnn_model.packed_forward`` after
  ``core.converter.convert``: first layer bit-plane, hidden layers integer
  xor/popcount/threshold on channel-packed words, last layer float (the
  PhoneBit deployment path).
* **float-CNN baseline** — :func:`cnn_float_forward`: the same topology at
  full precision with ReLU (what CNNdroid / TFLite-float execute in
  Tab III); and ``bnn_model.float_forward`` — the binarized net's float
  oracle used for training and engine validation.

Network definitions follow the originals (AlexNet/VGG16 at ImageNet shapes
— the paper's Tab II model sizes only reconcile with 1000-class ImageNet
heads; YOLOv2-Tiny at 416² VOC with 125 = 5·(20+5) output channels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.bnn_model import (BConv, BDense, FloatConv, FloatDense,
                                  Pool, init_params)

# --------------------------------------------------------------------------
# Specs (paper benchmark networks)
# --------------------------------------------------------------------------

def alexnet_spec() -> list:
    """AlexNet, 227x227x3 input, 1000 classes.  conv1 = bit-plane layer."""
    return [
        BConv(3, 96, kernel=11, stride=4, pad=0, first=True),
        Pool(3, 2),
        BConv(96, 256, kernel=5, stride=1, pad=2),
        Pool(3, 2),
        BConv(256, 384, kernel=3, stride=1, pad=1),
        BConv(384, 384, kernel=3, stride=1, pad=1),
        BConv(384, 256, kernel=3, stride=1, pad=1),
        Pool(3, 2),
        BDense(6 * 6 * 256, 4096),
        BDense(4096, 4096),
        FloatDense(4096, 1000),
    ]


def vgg16_spec() -> list:
    """VGG16, 224x224x3 input, 1000 classes."""
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    spec: list = []
    c_in, first = 3, True
    for item in cfg:
        if item == "M":
            spec.append(Pool(2, 2))
        else:
            spec.append(BConv(c_in, item, kernel=3, stride=1, pad=1,
                              first=first))
            c_in, first = item, False
    spec += [BDense(7 * 7 * 512, 4096), BDense(4096, 4096),
             FloatDense(4096, 1000)]
    return spec


def yolov2_tiny_spec() -> list:
    """YOLOv2-Tiny, 416x416x3 input, 125 output channels (VOC: 5·(20+5)).

    conv9 is the paper's full-precision 1x1 head (Fig 5); pool6 is the
    darknet stride-1 'same' pool (pad (0,1)) keeping the 13x13 grid.
    """
    return [
        BConv(3, 16, kernel=3, stride=1, pad=1, first=True),
        Pool(2, 2),
        BConv(16, 32, kernel=3, stride=1, pad=1), Pool(2, 2),
        BConv(32, 64, kernel=3, stride=1, pad=1), Pool(2, 2),
        BConv(64, 128, kernel=3, stride=1, pad=1), Pool(2, 2),
        BConv(128, 256, kernel=3, stride=1, pad=1), Pool(2, 2),
        BConv(256, 512, kernel=3, stride=1, pad=1),
        Pool(2, 1, pad=(0, 1)),
        BConv(512, 1024, kernel=3, stride=1, pad=1),
        BConv(1024, 1024, kernel=3, stride=1, pad=1),
        FloatConv(1024, 125, kernel=1, stride=1, pad=0),
    ]


NETWORKS = {
    "alexnet": (alexnet_spec, (227, 227, 3)),
    "vgg16": (vgg16_spec, (224, 224, 3)),
    "yolov2-tiny": (yolov2_tiny_spec, (416, 416, 3)),
}


def get(name: str):
    """Returns (spec, input_hwc)."""
    fn, shape = NETWORKS[name]
    return fn(), shape


def init(name: str, key=None):
    spec, shape = get(name)
    key = key if key is not None else jax.random.key(0)
    return spec, shape, init_params(key, spec)


# --------------------------------------------------------------------------
# Full-precision CNN baseline (Tab III float frameworks)
# --------------------------------------------------------------------------

def cnn_float_forward(params, spec, x_uint8: jnp.ndarray) -> jnp.ndarray:
    """The float CNN the paper benchmarks against: same topology, ReLU+BN,
    full-precision weights (the latent floats), standard 0-padding."""
    x = x_uint8.astype(jnp.float32) / 255.0
    for layer, p in zip(spec, params):
        if isinstance(layer, BConv):
            x = lax.conv_general_dilated(
                x, p["w"], (layer.stride, layer.stride),
                [(layer.pad, layer.pad)] * 2,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            sigma = jnp.sqrt(p["var"] + 1e-4)
            x = p["gamma"] * (x - p["mu"]) / sigma + p["beta"]
            x = jax.nn.relu(x)
        elif isinstance(layer, Pool):
            if layer.pad != (0, 0):
                x = jnp.pad(x, ((0, 0), layer.pad, layer.pad, (0, 0)),
                            constant_values=-jnp.inf)
            x = lax.reduce_window(
                x, -jnp.inf, lax.max,
                (1, layer.window, layer.window, 1),
                (1, layer.stride, layer.stride, 1), "VALID")
        elif isinstance(layer, BDense):
            x = x.reshape(x.shape[0], -1) @ p["w"]
            sigma = jnp.sqrt(p["var"] + 1e-4)
            x = p["gamma"] * (x - p["mu"]) / sigma + p["beta"]
            x = jax.nn.relu(x)
        elif isinstance(layer, FloatDense):
            x = x.reshape(x.shape[0], -1) @ p["w"] + p["b"]
        elif isinstance(layer, FloatConv):
            x = lax.conv_general_dilated(
                x, p["w"], (layer.stride, layer.stride),
                [(layer.pad, layer.pad)] * 2,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    return x
