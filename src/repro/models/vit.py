"""Vision Transformer (ViT-L/16, ViT-H/14) — encoder-only classifier.

Assigned shapes run at 224 (cls_224, serve_b1, serve_b128) and 384
(cls_384 finetune; the learned position table is bilinearly resized, the
standard finetune recipe from the ViT paper §3.2).

Sharding: batch over the data axes; attention heads and the MLP hidden dim
over ``model`` (both ViT variants have 16 heads and model-divisible d_ff, so
classic Megatron TP applies).  Layers are scanned (stacked params).

PhoneBit applicability (DESIGN §6): the QKV/MLP projections are binarizable
dense layers; ``binary_dense=True`` switches them to STE-sign binary
matmuls (latent float weights), the training-compatible float emulation of
the packed engine.  Attention softmax and norms stay float, exactly as the
paper keeps non-conv ops full precision.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import binarize
from repro.distributed.sharding import Rules
from repro.models import layers
from repro.optim import adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_classes: int = 1000
    pos_grid: int = 0          # side of the *trained* position grid
    binary_dense: bool = False  # PhoneBit technique on QKV/MLP projections
    # Unrolled layer loop (dry-run cost probes; see layers.scan_layers)
    unroll: bool = False

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def n_tokens(self, img_res: int | None = None) -> int:
        r = img_res or self.img_res
        return (r // self.patch) ** 2 + 1

    def param_count(self) -> int:
        d, l = self.d_model, self.n_layers
        per_layer = 4 * d * d + 2 * d * self.d_ff + 4 * d + d + self.d_ff
        patch = self.patch * self.patch * 3 * d + d
        grid = (self.pos_grid or self.img_res // self.patch) ** 2 + 1
        return (l * per_layer + patch + grid * d + d
                + 2 * d + d * self.n_classes + self.n_classes)


def init_params(key: jax.Array, cfg: ViTConfig) -> dict:
    d, l, ff = cfg.d_model, cfg.n_layers, cfg.d_ff
    grid = cfg.pos_grid or cfg.img_res // cfg.patch
    ks = layers.split_keys(key, 12)
    lay = {
        "ln1_s": jnp.ones((l, d), jnp.float32),
        "ln1_b": jnp.zeros((l, d), jnp.float32),
        "wqkv": _stack(ks[0], l, (d, 3 * d)),
        "bqkv": jnp.zeros((l, 3 * d), jnp.float32),
        "wo": _stack(ks[1], l, (d, d)),
        "bo": jnp.zeros((l, d), jnp.float32),
        "ln2_s": jnp.ones((l, d), jnp.float32),
        "ln2_b": jnp.zeros((l, d), jnp.float32),
        "w1": _stack(ks[2], l, (d, ff)),
        "b1": jnp.zeros((l, ff), jnp.float32),
        "w2": _stack(ks[3], l, (ff, d)),
        "b2": jnp.zeros((l, d), jnp.float32),
    }
    return {
        "patch_w": layers.conv_init(
            ks[4], (cfg.patch, cfg.patch, 3, d)),
        "patch_b": jnp.zeros((d,), jnp.float32),
        "cls": layers.normal_init(ks[5], (1, 1, d)),
        "pos": layers.normal_init(ks[6], (grid * grid + 1, d)),
        "layers": lay,
        "ln_f_s": jnp.ones((d,), jnp.float32),
        "ln_f_b": jnp.zeros((d,), jnp.float32),
        "head_w": layers.normal_init(ks[7], (d, cfg.n_classes)),
        "head_b": jnp.zeros((cfg.n_classes,), jnp.float32),
    }


def _stack(key, l, shape):
    return jax.random.normal(key, (l, *shape), jnp.float32) / math.sqrt(
        shape[0])


def param_specs(cfg: ViTConfig, rules: Rules) -> dict:
    fs, mp = rules.fsdp, rules.model
    ff = rules.shard_if(cfg.d_ff, mp)
    d3 = rules.shard_if(3 * cfg.d_model, mp)
    lay = {
        "ln1_s": P(None, None), "ln1_b": P(None, None),
        "wqkv": P(None, fs, d3), "bqkv": P(None, d3),
        "wo": P(None, rules.shard_if(cfg.d_model, mp), fs),
        "bo": P(None, None),
        "ln2_s": P(None, None), "ln2_b": P(None, None),
        "w1": P(None, fs, ff), "b1": P(None, ff),
        "w2": P(None, ff, fs), "b2": P(None, None),
    }
    return {
        "patch_w": P(None, None, None, rules.shard_if(cfg.d_model, mp)),
        "patch_b": P(None),
        "cls": P(None, None, None),
        "pos": P(None, None),
        "layers": lay,
        "ln_f_s": P(None), "ln_f_b": P(None),
        "head_w": P(fs, None), "head_b": P(None),
    }


def abstract_params(cfg: ViTConfig):
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.key(0))


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _maybe_binary(w, x, enabled: bool):
    """Dense matmul, optionally in the binary (+-1 STE) domain."""
    cd = layers.COMPUTE_DTYPE
    if not enabled:
        return x @ w.astype(cd)
    xb = binarize.ste_sign(x.astype(jnp.float32)).astype(cd)
    wb = binarize.ste_sign(w).astype(cd)
    return xb @ wb


def resize_pos_embed(pos: jnp.ndarray, grid_from: int, grid_to: int):
    """Bilinear resize of the (G²+1, D) position table (finetune at 384)."""
    if grid_from == grid_to:
        return pos
    cls, grid = pos[:1], pos[1:]
    d = grid.shape[-1]
    img = grid.reshape(1, grid_from, grid_from, d)
    img = jax.image.resize(img, (1, grid_to, grid_to, d), "bilinear")
    return jnp.concatenate([cls, img.reshape(grid_to * grid_to, d)], axis=0)


def forward(params: dict, images: jnp.ndarray, cfg: ViTConfig,
            rules: Rules) -> jnp.ndarray:
    """images: (B, R, R, 3) float -> logits (B, n_classes)."""
    b, r, _, _ = images.shape
    cd = layers.COMPUTE_DTYPE
    bspec = rules.batch_spec(b)
    mp = rules.model

    x = lax.conv_general_dilated(
        images.astype(cd), params["patch_w"].astype(cd),
        (cfg.patch, cfg.patch), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    g = r // cfg.patch
    x = x.reshape(b, g * g, cfg.d_model) + params["patch_b"].astype(cd)
    cls = jnp.broadcast_to(params["cls"].astype(cd), (b, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1)
    grid_from = cfg.pos_grid or cfg.img_res // cfg.patch
    pos = resize_pos_embed(params["pos"], grid_from, g)
    x = x + pos.astype(cd)[None]
    x = rules.constrain(x, bspec, None, None)

    h, hd = cfg.n_heads, cfg.d_head
    s = x.shape[1]

    def layer_body(x, lp):
        hn = layers.layer_norm(x, lp["ln1_s"], lp["ln1_b"])
        qkv = (_maybe_binary(lp["wqkv"], hn, cfg.binary_dense)
               + lp["bqkv"].astype(cd))
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = rules.constrain(q.reshape(b, s, h, hd), bspec, None, mp, None)
        k = rules.constrain(k.reshape(b, s, h, hd), bspec, None, mp, None)
        v = rules.constrain(v.reshape(b, s, h, hd), bspec, None, mp, None)
        o = layers.chunked_attention(q, k, v, causal=False,
                                     q_chunk=s, kv_chunk=s)
        o = (_maybe_binary(lp["wo"], o.reshape(b, s, h * hd),
                           cfg.binary_dense) + lp["bo"].astype(cd))
        x = x + o
        hn = layers.layer_norm(x, lp["ln2_s"], lp["ln2_b"])
        hmid = layers.gelu(
            _maybe_binary(lp["w1"], hn, cfg.binary_dense)
            + lp["b1"].astype(cd))
        out = (_maybe_binary(lp["w2"], hmid, cfg.binary_dense)
               + lp["b2"].astype(cd))
        x = rules.constrain(x + out, bspec, None, None)
        return x, None

    x, _ = layers.scan_layers(layer_body, x, params["layers"],
                              n_layers=cfg.n_layers, unroll=cfg.unroll)
    x = layers.layer_norm(x, params["ln_f_s"], params["ln_f_b"])
    pooled = x[:, 0, :]
    return (pooled @ params["head_w"].astype(cd)
            + params["head_b"].astype(cd))


def loss_fn(params, batch, cfg: ViTConfig, rules: Rules):
    logits = forward(params, batch["images"], cfg, rules)
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold), {}


def make_train_step(cfg: ViTConfig, rules: Rules, *, lr=1e-3):
    def train_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, rules)
        clip = (lambda path: "wqkv" in path or "w1" in path or "w2" in path
                or "wo" in path) if cfg.binary_dense else None
        params, opt_state, om = adamw_update(
            params, grads, opt_state, lr=lr, clip_latent_paths=clip)
        return params, opt_state, {"loss": loss, **om}
    return train_step
