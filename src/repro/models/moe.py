"""Mixture-of-Experts layer with explicit expert parallelism (EP).

GSPMD cannot derive an efficient MoE schedule automatically: dense one-hot
dispatch either over-computes every expert for every token (E/k× waste) or
materializes a (tokens × experts × capacity) dispatch tensor.  This module
instead writes the canonical EP collective schedule *explicitly* under
``shard_map``:

  1. route locally (softmax → top-k, capacity-limited scatter into per-expert
     buckets of shape (E, C, D)),
  2. ``all_to_all`` over the ``model`` axis — each shard keeps only its
     E/ep experts but receives that bucket from every peer,
  3. batched expert FFN (one einsum over the local experts),
  4. inverse ``all_to_all``, weighted un-scatter back to token order.

Capacity semantics follow Switch/GShard: per-source-shard capacity
``C = ceil(T_local * k / E * capacity_factor)``; overflow tokens are dropped
(their residual passes through unchanged).  Tests use a high factor to make
the layer exactly match the dense reference.

Expert count padding: if E does not divide the EP degree (granite: 40
experts on 16 shards) the weights are padded to the next multiple (48) and
the router logits of the padding experts are masked to -inf, so they are
never selected and cost only idle FLOPs on 8/48 expert slots.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import Rules
from repro.models import layers

from repro import compat


def padded_experts(n_experts: int, ep: int) -> int:
    return -(-n_experts // ep) * ep


def capacity(tokens_local: int, top_k: int, n_experts_padded: int,
             factor: float) -> int:
    c = math.ceil(tokens_local * top_k / n_experts_padded * factor)
    return max(c, 1)


# --------------------------------------------------------------------------
# Local (per-shard) routing + dispatch
# --------------------------------------------------------------------------

def _route(x, router, *, n_real: int, top_k: int):
    """x: (T, D); router: (D, E_pad).  Returns (weights (T,k), ids (T,k),
    probs (T, E_pad)) with padding experts masked out."""
    logits = x.astype(jnp.float32) @ router.astype(jnp.float32)
    e_pad = router.shape[1]
    if e_pad != n_real:
        mask = jnp.arange(e_pad) < n_real
        logits = jnp.where(mask[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, ids, probs


def _dispatch_indices(ids, *, n_experts: int, cap: int):
    """Flat (T*k,) destination slots ``expert*C + position`` with drops.

    Position within each expert's bucket comes from a cumsum over the
    one-hot assignment matrix (order-preserving, deterministic).
    Returns (dest (T*k,) int32 — out-of-range == dropped, keep (T*k,) bool).
    """
    flat = ids.reshape(-1)                                    # (T*k,)
    onehot = (flat[:, None] == jnp.arange(n_experts)[None, :])
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1    # (T*k, E)
    pos_t = jnp.sum(jnp.where(onehot, pos, 0), axis=1)        # (T*k,)
    keep = pos_t < cap
    dest = flat * cap + pos_t
    dest = jnp.where(keep, dest, n_experts * cap)             # drop sentinel
    return dest, keep


def _expert_ffn(xe, wg, wu, wd, act: str):
    """xe: (El, T, D); weights (El, D, F)/(El, D, F)/(El, F, D)."""
    xe = xe.astype(layers.COMPUTE_DTYPE)
    h_up = jnp.einsum("etd,edf->etf", xe, wu.astype(xe.dtype))
    if act == "swiglu":
        h_gate = jnp.einsum("etd,edf->etf", xe, wg.astype(xe.dtype))
        h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(xe.dtype) * h_up
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(h_up))
    else:
        raise ValueError(act)
    return jnp.einsum("etf,efd->etd", h, wd.astype(xe.dtype))


def _moe_local(x, router, wg, wu, wd, *, n_real: int, top_k: int,
               cap: int, ep_axis: str, all_axes: tuple[str, ...],
               act: str):
    """Per-shard MoE body (runs under shard_map).

    x: (T_local, D); router: (D, E_pad) replicated; wg/wu/wd: local expert
    slices (E_pad/ep, D, F) etc.  Returns (out (T_local, D), aux scalar).
    """
    t_l, d = x.shape
    e_pad = router.shape[1]
    w, ids, probs = _route(x, router, n_real=n_real, top_k=top_k)
    dest, keep = _dispatch_indices(ids, n_experts=e_pad, cap=cap)

    x_rep = jnp.repeat(x, top_k, axis=0)                      # (T*k, D)
    buf = jnp.zeros((e_pad * cap, d), x.dtype)
    buf = buf.at[dest].set(x_rep, mode="drop")                # scatter
    buf = buf.reshape(e_pad, cap, d)

    # EP exchange: keep E_pad/ep experts, receive from all ep peers.
    recv = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                          tiled=True)                         # (El, ep*C, D)
    y = _expert_ffn(recv, wg, wu, wd, act)
    back = lax.all_to_all(y, ep_axis, split_axis=1, concat_axis=0,
                          tiled=True)                         # (E_pad, C, D)

    back_flat = back.reshape(e_pad * cap, d)
    safe = jnp.minimum(dest, e_pad * cap - 1)
    picked = jnp.where(keep[:, None], back_flat[safe], 0.0)   # (T*k, D)
    out = jnp.sum(
        picked.reshape(t_l, top_k, d)
        * w.astype(picked.dtype)[..., None], axis=1)

    # Switch-style load-balance loss: E * sum_e f_e * p_e, averaged over
    # every shard (all tokens).
    onehot_tok = jax.nn.one_hot(ids, e_pad, dtype=jnp.float32)  # (T,k,E)
    f = jnp.mean(jnp.sum(onehot_tok, axis=1), axis=0)           # (E,)
    p = jnp.mean(probs, axis=0)
    aux = n_real * jnp.sum(f * p)
    aux = lax.pmean(aux, all_axes)
    return out.astype(x.dtype), aux


# --------------------------------------------------------------------------
# Public entry point
# --------------------------------------------------------------------------

def moe_apply(x_tokens: jnp.ndarray, router, wg, wu, wd, *,
              n_experts: int, top_k: int, capacity_factor: float,
              rules: Rules, token_axes, act: str = "swiglu"):
    """Expert-parallel MoE over flat tokens.

    x_tokens: (T, D) with sharding P(token_axes, None).  Expert weights are
    (E_pad, D, F)-shaped with E_pad sharded over ``rules.model``.  When
    ``token_axes`` includes the model axis the EP all_to_all moves disjoint
    token sets; when it does not (decode: too few tokens) the model shards
    route redundantly — correct, and the expert FLOPs at decode are
    negligible.  Returns (out (T, D), aux_loss scalar).
    """
    ep = rules.tp
    e_pad = wg.shape[0]
    assert e_pad % ep == 0, (e_pad, ep)
    t = x_tokens.shape[0]
    token_axes = tuple(token_axes) if token_axes else ()
    t_local = t // max(1, rules.axis_size(token_axes))
    cap = capacity(t_local, top_k, e_pad, capacity_factor)

    body = functools.partial(
        _moe_local, n_real=n_experts, top_k=top_k, cap=cap,
        ep_axis=rules.model,
        all_axes=tuple(rules.mesh.axis_names), act=act)
    if not token_axes:
        tok_axis = None
    elif len(token_axes) == 1:
        tok_axis = token_axes[0]
    else:
        tok_axis = token_axes
    tok_spec = P(tok_axis, None)
    # check_vma=False: when tokens are replicated over the model axis
    # (decode), the static variance checker cannot prove the all_to_all
    # round-trip keeps them replicated; the collectives are still correct.
    out, aux = compat.shard_map(
        body, mesh=rules.mesh,
        in_specs=(tok_spec, P(None, None), P(rules.model, None, None),
                  P(rules.model, None, None), P(rules.model, None, None)),
        out_specs=(tok_spec, P()),
        check_vma=False,
    )(x_tokens, router, wg, wu, wd)
    return out, aux


def moe_reference(x_tokens, router, wg, wu, wd, *, n_experts: int,
                  top_k: int, act: str = "swiglu"):
    """Dense oracle: every expert on every token, then top-k combine.

    No capacity, no drops — the target moe_apply matches when its capacity
    factor is high enough to avoid drops.
    """
    w, ids, _ = _route(x_tokens, router, n_real=n_experts, top_k=top_k)
    all_out = _expert_ffn(
        jnp.broadcast_to(x_tokens, (wg.shape[0],) + x_tokens.shape),
        wg, wu, wd, act)                                       # (E, T, D)
    t = x_tokens.shape[0]
    picked = jnp.take_along_axis(
        jnp.transpose(all_out, (1, 0, 2)), ids[..., None], axis=1)  # (T,k,D)
    return jnp.sum(picked * w.astype(picked.dtype)[..., None], axis=1)
