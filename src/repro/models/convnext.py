"""ConvNeXt-B — Liu et al., arXiv:2201.03545.

depths (3, 3, 27, 3), dims (128, 256, 512, 1024).  Block: 7×7 depthwise
conv → LN → 1×1 expand (4×, GELU) → 1×1 project → layer-scale → residual.
Stages are separated by LN + 2×2/s2 downsample convs.

The identical blocks inside each stage are scanned (stacked params), so the
traced depth is 4 stages regardless of the 27-deep third stage.

Sharding: batch over data axes; channels over ``model`` (all stage dims are
16-divisible).  The 1×1 convs are channel matmuls — Megatron-style sharding
(expand out-dim sharded, project in-dim sharded) gives one reduce per block.

PhoneBit applicability (DESIGN §6): with ``binary_pointwise=True`` the 1×1
expand/project convs — the FLOP majority — run as STE-sign binary matmuls;
the 7×7 depthwise convs stay float (K=49 reduction packs poorly, the same
reason the paper's engine keeps non-conv ops float).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import binarize
from repro.distributed.sharding import Rules
from repro.models import layers
from repro.optim import adamw_update


@dataclasses.dataclass(frozen=True)
class ConvNeXtConfig:
    name: str
    img_res: int = 224
    depths: tuple[int, ...] = (3, 3, 27, 3)
    dims: tuple[int, ...] = (128, 256, 512, 1024)
    n_classes: int = 1000
    layer_scale_init: float = 1e-6
    binary_pointwise: bool = False
    # Unroll block scans into a python loop.  The dry-run uses this for
    # exact cost accounting: XLA's HloCostAnalysis counts a while-loop
    # body ONCE regardless of trip count, so scanned stages would
    # under-report FLOPs/bytes by depth×.
    unroll: bool = False

    def param_count(self) -> int:
        total = 4 * 4 * 3 * self.dims[0] + self.dims[0] * 2
        prev = self.dims[0]
        for depth, dim in zip(self.depths, self.dims):
            if dim != prev:
                total += prev * dim * 4 + dim + prev * 2
            total += depth * (7 * 7 * dim + dim * 2 + dim * 4 * dim
                              + 4 * dim + 4 * dim * dim + dim + dim)
            prev = dim
        return total + self.dims[-1] * 2 + self.dims[-1] * self.n_classes


def init_params(key: jax.Array, cfg: ConvNeXtConfig) -> dict:
    ks = iter(layers.split_keys(key, 64))
    params: dict = {
        "stem_w": layers.conv_init(next(ks), (4, 4, 3, cfg.dims[0])),
        "stem_b": jnp.zeros((cfg.dims[0],), jnp.float32),
        "stem_ln_s": jnp.ones((cfg.dims[0],), jnp.float32),
        "stem_ln_b": jnp.zeros((cfg.dims[0],), jnp.float32),
        "stages": [],
    }
    prev = cfg.dims[0]
    for depth, dim in zip(cfg.depths, cfg.dims):
        stage: dict = {}
        if dim != prev:
            stage["down_ln_s"] = jnp.ones((prev,), jnp.float32)
            stage["down_ln_b"] = jnp.zeros((prev,), jnp.float32)
            stage["down_w"] = layers.conv_init(next(ks), (2, 2, prev, dim))
            stage["down_b"] = jnp.zeros((dim,), jnp.float32)
        stage["blocks"] = {
            "dw_w": _stack(next(ks), depth, (7, 7, 1, dim), conv=True),
            "dw_b": jnp.zeros((depth, dim), jnp.float32),
            "ln_s": jnp.ones((depth, dim), jnp.float32),
            "ln_b": jnp.zeros((depth, dim), jnp.float32),
            "w1": _stack(next(ks), depth, (dim, 4 * dim)),
            "b1": jnp.zeros((depth, 4 * dim), jnp.float32),
            "w2": _stack(next(ks), depth, (4 * dim, dim)),
            "b2": jnp.zeros((depth, dim), jnp.float32),
            "gamma": jnp.full((depth, dim), cfg.layer_scale_init,
                              jnp.float32),
        }
        params["stages"].append(stage)
        prev = dim
    params.update({
        "head_ln_s": jnp.ones((cfg.dims[-1],), jnp.float32),
        "head_ln_b": jnp.zeros((cfg.dims[-1],), jnp.float32),
        "head_w": layers.normal_init(next(ks),
                                     (cfg.dims[-1], cfg.n_classes)),
        "head_b": jnp.zeros((cfg.n_classes,), jnp.float32),
    })
    return params


def _stack(key, depth, shape, conv=False):
    init = layers.conv_init if conv else functools.partial(
        layers.fanin_init, fan_axis=0)
    keys = layers.split_keys(key, depth)
    return jnp.stack([init(k, shape) for k in keys])


def param_specs(cfg: ConvNeXtConfig, rules: Rules) -> dict:
    fs, mp = rules.fsdp, rules.model
    specs: dict = {
        "stem_w": P(None, None, None, rules.shard_if(cfg.dims[0], mp)),
        "stem_b": P(None), "stem_ln_s": P(None), "stem_ln_b": P(None),
        "stages": [],
    }
    prev = cfg.dims[0]
    for depth, dim in zip(cfg.depths, cfg.dims):
        st: dict = {}
        if dim != prev:
            st["down_ln_s"] = P(None)
            st["down_ln_b"] = P(None)
            st["down_w"] = P(None, None, None, rules.shard_if(dim, mp))
            st["down_b"] = P(None)
        c_sh = rules.shard_if(dim, mp)
        st["blocks"] = {
            "dw_w": P(None, None, None, None, c_sh),
            "dw_b": P(None, None),
            "ln_s": P(None, None), "ln_b": P(None, None),
            "w1": P(None, fs, rules.shard_if(4 * dim, mp)),
            "b1": P(None, None),
            "w2": P(None, rules.shard_if(4 * dim, mp), fs),
            "b2": P(None, None),
            "gamma": P(None, None),
        }
        specs["stages"].append(st)
        prev = dim
    specs.update({
        "head_ln_s": P(None), "head_ln_b": P(None),
        "head_w": P(fs, None), "head_b": P(None),
    })
    return specs


def abstract_params(cfg: ConvNeXtConfig):
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.key(0))


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _pointwise(x, w, enabled_binary: bool):
    cd = layers.COMPUTE_DTYPE
    if not enabled_binary:
        return x @ w.astype(cd)
    xb = binarize.ste_sign(x.astype(jnp.float32)).astype(cd)
    wb = binarize.ste_sign(w).astype(cd)
    return xb @ wb


def forward(params: dict, images: jnp.ndarray, cfg: ConvNeXtConfig,
            rules: Rules) -> jnp.ndarray:
    cd = layers.COMPUTE_DTYPE
    b = images.shape[0]
    bspec = rules.batch_spec(b)
    mp = rules.model

    x = lax.conv_general_dilated(
        images.astype(cd), params["stem_w"].astype(cd), (4, 4), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = x + params["stem_b"].astype(cd)
    x = layers.layer_norm(x, params["stem_ln_s"], params["stem_ln_b"])

    prev = cfg.dims[0]
    for stage, (depth, dim) in zip(params["stages"],
                                   zip(cfg.depths, cfg.dims)):
        if dim != prev:
            x = layers.layer_norm(x, stage["down_ln_s"], stage["down_ln_b"])
            x = lax.conv_general_dilated(
                x, stage["down_w"].astype(cd), (2, 2), "VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = x + stage["down_b"].astype(cd)
        x = rules.constrain(x, bspec, None, None, rules.shard_if(dim, mp))

        def block(x, bp, dim=dim):
            h = lax.conv_general_dilated(
                x, bp["dw_w"].astype(cd), (1, 1), [(3, 3), (3, 3)],
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=dim)
            h = h + bp["dw_b"].astype(cd)
            h = layers.layer_norm(h, bp["ln_s"], bp["ln_b"])
            h = layers.gelu(_pointwise(h, bp["w1"], cfg.binary_pointwise)
                            + bp["b1"].astype(cd))
            h = (_pointwise(h, bp["w2"], cfg.binary_pointwise)
                 + bp["b2"].astype(cd))
            return x + bp["gamma"].astype(cd) * h, None

        if cfg.unroll:
            for i in range(depth):
                bp = jax.tree.map(lambda p, i=i: p[i], stage["blocks"])
                x, _ = block(x, bp)
        else:
            body = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)
            x, _ = lax.scan(body, x, stage["blocks"])
        prev = dim

    x = jnp.mean(x.astype(jnp.float32), axis=(1, 2))
    x = layers.layer_norm(x, params["head_ln_s"], params["head_ln_b"])
    return x @ params["head_w"] + params["head_b"]


def loss_fn(params, batch, cfg: ConvNeXtConfig, rules: Rules):
    logits = forward(params, batch["images"], cfg, rules).astype(
        jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None],
                               axis=-1)[:, 0]
    return jnp.mean(lse - gold), {}


def make_train_step(cfg: ConvNeXtConfig, rules: Rules, *, lr=4e-3):
    def train_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, rules)
        clip = ((lambda p: ("w1" in p or "w2" in p) and "blocks" in p)
                if cfg.binary_pointwise else None)
        params, opt_state, om = adamw_update(
            params, grads, opt_state, lr=lr, clip_latent_paths=clip)
        return params, opt_state, {"loss": loss, **om}
    return train_step
