"""Shared model substrate: norms, RoPE, attention, initializers, dtype policy.

Attention is the memory-critical op at the assigned shapes (32k prefill would
materialize a 17 GB score matrix per device if written naively), so both the
training/prefill path and the decode path are written memory-bounded:

* :func:`chunked_attention` — online-softmax (flash-style) attention in pure
  JAX: ``lax.scan`` over KV chunks with running (max, denom, acc) statistics.
  Causal masking uses a *triangular schedule*: a static python loop over Q
  chunks where each Q chunk only scans KV chunks up to its own diagonal, so
  causal attention does ~S²/2 work instead of S² (the masked half is never
  computed, not just masked out).

* :func:`flash_decode` — decode-time attention over a sequence-sharded KV
  cache (flash-decoding style SP).  Runs under ``shard_map``: each model
  shard computes partial (logsumexp, weighted-V) over its KV chunk and the
  partials are combined with two small cross-shard reductions instead of
  all-gathering the cache.

Dtype policy: parameters are stored f32 (optimizer-friendly), compute is
bf16 via :func:`cast_compute`, reductions/softmax accumulate in f32.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

# Dry-run accounting mode: XLA:CPU legalizes every bf16 dot to f32
# (convert operands + f32 dot) and hoists those converts ahead of the
# GSPMD collectives, so a bf16 model compiled for host devices reports
# inflated, convert-noise-riddled bytes/wire numbers that a TPU lowering
# (native MXU bf16) would not have.  REPRO_DRYRUN_F32=1 runs the whole
# model in f32 — zero converts, clean collective placement — and the
# analysis applies a documented ×0.5 bf16 adjustment to bytes/wire.
COMPUTE_DTYPE = (jnp.float32 if os.environ.get("REPRO_DRYRUN_F32")
                 else jnp.bfloat16)


def cast_compute(x: jnp.ndarray, dtype=COMPUTE_DTYPE) -> jnp.ndarray:
    if jnp.issubdtype(x.dtype, jnp.floating):
        return x.astype(dtype)
    return x


# --------------------------------------------------------------------------
# Initializers (all take a key and return f32)
# --------------------------------------------------------------------------

def normal_init(key, shape, stddev: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * stddev)


def fanin_init(key, shape, fan_axis: int = 0):
    fan_in = shape[fan_axis] if isinstance(fan_axis, int) else math.prod(
        shape[a] for a in fan_axis)
    return jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)


def conv_init(key, shape):
    """HWIO conv kernel, He-normal over the receptive field."""
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape, jnp.float32) * math.sqrt(2.0 / fan_in)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray | None,
               eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def modulate(x: jnp.ndarray, shift: jnp.ndarray, scale: jnp.ndarray):
    """adaLN modulation (DiT): x * (1 + scale) + shift, broadcast over seq."""
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Chunked (flash-style) attention — training & prefill
# --------------------------------------------------------------------------

def _attn_one_q_chunk(q, k, v, *, mask_fn, kv_chunk: int, n_kv: int,
                      step_remat: bool = True):
    """Online-softmax over KV chunks for one Q chunk.

    q: (B, Sq, KV, G, hd); k/v: (B, Skv_used, KV, hd) — already sliced to the
    KV prefix this Q chunk may attend to.  mask_fn(q_idx, kv_idx) -> bool
    (True = attend) applied only to the final (diagonal) chunk when causal.

    Mixed precision (MXU-style): operands stay bf16, scores/stats/acc
    accumulate f32 via preferred_element_type, probabilities downcast to
    bf16 for the PV matmul, output downcast before the caller's concat —
    no full-(Sq, H·hd) f32 tensor ever materializes (perf-log it5).
    Returns (B, Sq, KV, G, hd) in q.dtype.
    """
    b, sq, kvh, g, hd = q.shape
    scale = 1.0 / math.sqrt(hd)

    k = k.reshape(b, n_kv, kv_chunk, kvh, hd)
    v = v.reshape(b, n_kv, kv_chunk, kvh, hd)

    def step(carry, kv_i):
        m, l, acc = carry
        kc, vc, ci = kv_i                                  # (B,kc,KV,hd) x2
        # scores: (B, KV, G, Sq, kc) f32 accumulate from bf16 operands;
        # 1/sqrt(hd) folded into the f32 scores (no f32 roundtrip on q)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kc,
                       preferred_element_type=jnp.float32) * scale
        if mask_fn is not None:
            q_pos = jnp.arange(sq)                          # offset added by caller
            kv_pos = ci * kv_chunk + jnp.arange(kv_chunk)
            mask = mask_fn(q_pos, kv_pos)                   # (Sq, kc) bool
            s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    ks = jnp.moveaxis(k, 1, 0)                              # (n_kv, B, kc, KV, hd)
    vs = jnp.moveaxis(v, 1, 0)
    # Remat each KV step: without it, scan's AD stashes the (Sq, kc) f32
    # probability matrix of EVERY step for the backward pass (flash
    # attention's whole point is recomputing those).  step_remat=False
    # trades that memory back for one less score-chain recompute — the
    # right call when the outer layer policy already recomputes ("dots")
    # or HBM has headroom.
    if step_remat:
        step = jax.checkpoint(step)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0),
                              (ks, vs, jnp.arange(n_kv)))
    out = (acc / l[..., None]).astype(q.dtype)
    return jnp.transpose(out, (0, 3, 1, 2, 4))              # (B,Sq,KV,G,hd)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, q_chunk: int = 1024, kv_chunk: int = 1024,
                      step_remat: bool = True) -> jnp.ndarray:
    """Memory-bounded GQA attention.

    q: (B, S, H, hd); k, v: (B, S, KV, hd) with H = KV * G.  Never
    materializes the (S, S) score matrix: peak extra memory is
    O(q_chunk * kv_chunk) per (head, batch).

    Causal uses the triangular schedule: Q chunk i scans only KV chunks
    [0, i], so FLOPs ~ S²/2 + diagonal masking on the last chunk only.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    q = q.reshape(b, s, kvh, g, hd)

    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    assert s % q_chunk == 0 and s % kv_chunk == 0, (s, q_chunk, kv_chunk)
    n_q = s // q_chunk

    outs = []
    for qi in range(n_q):
        q_lo = qi * q_chunk
        qc = lax.slice_in_dim(q, q_lo, q_lo + q_chunk, axis=1)
        if causal:
            # This Q chunk attends to KV positions [0, q_lo + q_chunk).
            kv_hi = q_lo + q_chunk
            n_kv = -(-kv_hi // kv_chunk)
            kv_used = n_kv * kv_chunk
            kc = lax.slice_in_dim(k, 0, kv_used, axis=1)
            vc = lax.slice_in_dim(v, 0, kv_used, axis=1)

            def mask_fn(q_pos, kv_pos, q_lo=q_lo):
                return (q_lo + q_pos)[:, None] >= kv_pos[None, :]
        else:
            n_kv = s // kv_chunk
            kc, vc = k, v
            mask_fn = None
        o = _attn_one_q_chunk(qc, kc, vc, mask_fn=mask_fn,
                              kv_chunk=kv_chunk, n_kv=n_kv,
                              step_remat=step_remat)
        outs.append(o)
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(b, s, h, hd)


def reference_attention(q, k, v, *, causal: bool) -> jnp.ndarray:
    """Naive O(S²)-memory oracle for chunked_attention (tests only)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, s, kvh, g, hd) / math.sqrt(hd)
    s_ = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        s_ = jnp.where(mask[None, None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(b, s, h, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# Flash decode — sequence-parallel attention over a sharded KV cache
# --------------------------------------------------------------------------

def flash_decode_local(q, k_cache, v_cache, valid_len, chunk_start):
    """Partial attention of one query over a *local* KV-cache chunk.

    q: (B, H, hd); k/v_cache: (B, C, KV, hd) local chunk; valid_len: scalar
    total valid cache length; chunk_start: scalar global offset of the chunk.
    Returns partials (out (B, H, hd) f32 unnormalized, lse-stats m (B, H),
    l (B, H)) to be combined across shards.
    """
    b, c, kvh, hd = k_cache.shape
    h = q.shape[1]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, kvh, g, hd) / math.sqrt(hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    pos = chunk_start + jnp.arange(c)
    s = jnp.where((pos < valid_len)[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return (o.reshape(b, h, hd), m.reshape(b, h), l.reshape(b, h))


def combine_decode_partials(o, m, l, axis_name: str):
    """Combine per-shard flash-decode partials along ``axis_name``.

    o: (B, H, hd) unnormalized; m, l: (B, H).  Two small collectives
    (max + sum) instead of an all-gather of the KV cache.
    """
    m_glob = lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = lax.psum(l * corr, axis_name)
    o_glob = lax.psum(o * corr[..., None], axis_name)
    return (o_glob / jnp.maximum(l_glob, 1e-30)[..., None])


# --------------------------------------------------------------------------
# Layer stacking: scan (production) or unrolled python loop (dry-run probes)
# --------------------------------------------------------------------------

REMAT_POLICIES = {
    # recompute everything in bwd: minimum memory, +1 forward of FLOPs
    "nothing": jax.checkpoint_policies.nothing_saveable,
    # save matmul outputs, recompute elementwise only: ~zero extra FLOPs,
    # memory = per-layer matmul activations — the right default whenever
    # HBM has headroom (small models / small per-device batches)
    "dots": jax.checkpoint_policies.dots_saveable,
}


def scan_layers(body, carry, xs_tree, *, n_layers: int, unroll: bool,
                remat: bool = True, remat_policy: str = "nothing"):
    """lax.scan over stacked layer params, or an unrolled python loop.

    The unrolled path exists for dry-run cost accounting: XLA's
    HloCostAnalysis counts a while-loop body ONCE regardless of trip
    count, so the probe compiles (n_layers=1/2, unroll=True) recover exact
    per-layer FLOPs/bytes/collectives.  Production always scans (flat HLO,
    flat compile time).  ``remat`` applies the selected checkpoint policy
    to the body in both paths, so backward recompute is identical.
    """
    if remat:
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat_policy])
    if not unroll:
        return lax.scan(body, carry, xs_tree)
    ys = []
    for i in range(n_layers):
        xs_i = jax.tree.map(lambda p, i=i: p[i], xs_tree)
        carry, y = body(carry, xs_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# --------------------------------------------------------------------------
# Misc
# --------------------------------------------------------------------------

def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def count_params(params: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def param_bytes(params: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def tree_cast(params: Any, dtype) -> Any:
    return jax.tree.map(lambda x: cast_compute(x, dtype), params)
