"""Model zoo: every assigned architecture + the paper's own BNN workloads.

transformer    dense/MoE GQA LMs (granite-moe, qwen3-moe, minitron, command-r)
dit            Diffusion Transformer (DiT-L/2, DiT-XL/2), adaLN-zero
vit            Vision Transformer (ViT-L/16, ViT-H/14)
convnext       ConvNeXt-B
efficientnet   EfficientNet-B7
paper_nets     AlexNet / VGG16 / YOLOv2-Tiny, float + binarized (PhoneBit)
layers         shared substrate: norms, RoPE, chunked flash attention,
               flash decode, initializers, dtype policy
"""

from repro.models import (convnext, dit, efficientnet, layers, paper_nets,
                          transformer, vit)

__all__ = ["convnext", "dit", "efficientnet", "layers", "paper_nets",
           "transformer", "vit"]
