"""Diffusion Transformer (DiT-L/2, DiT-XL/2) — Peebles & Xie, arXiv:2212.09748.

Operates in a VAE latent space (8× downsample, 4 channels): img_res 256 →
32×32×4 latents → patch 2 → 256 tokens.  Conditioning (timestep + class) is
injected with adaLN-zero: per-block shift/scale/gate regressed from the
conditioning vector, gates initialized to zero.

Steps provided:

* ``train_step`` — DDPM ε-prediction MSE at uniformly sampled t (the
  assigned ``train_256``/``train_1024`` cells),
* ``sample_step`` — one DDIM denoising update; a 50-step sampler is 50
  invocations (the assigned ``gen_1024``/``gen_fast`` cells lower this
  function — the sampling loop is step-count × this cost).

Sharding: batch over data axes when divisible, else tokens over data
(gen_1024 has batch 4); heads/MLP over ``model`` (16 heads, divisible).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import Rules
from repro.models import layers
from repro.optim import adamw_update


@dataclasses.dataclass(frozen=True)
class DiTConfig:
    name: str
    img_res: int               # pixel resolution (latent = /8)
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    n_classes: int = 1000
    latent_channels: int = 4
    vae_downsample: int = 8
    mlp_ratio: int = 4
    # diffusion schedule
    n_train_timesteps: int = 1000
    # Unrolled layer loop (dry-run cost probes; see layers.scan_layers)
    unroll: bool = False
    # Activation-checkpoint policy (see layers.REMAT_POLICIES)
    remat_policy: str = "nothing"
    # Megatron-SP: shard the token dim of the residual stream over the
    # model axis (halves the per-block boundary wire: RS+AG vs 2×AR)
    seq_shard: bool = False

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return self.mlp_ratio * self.d_model

    def latent_res(self, img_res: int | None = None) -> int:
        return (img_res or self.img_res) // self.vae_downsample

    def n_tokens(self, img_res: int | None = None) -> int:
        return (self.latent_res(img_res) // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.latent_channels

    def param_count(self) -> int:
        d, l = self.d_model, self.n_layers
        per_layer = 4 * d * d + 2 * d * self.d_ff + d * 6 * d + 6 * d
        cond = 256 * d + d * d + self.n_classes * d
        final = d * 2 * d + d * 2 * self.patch_dim
        return (l * per_layer + cond + self.patch_dim * d + final)


def init_params(key: jax.Array, cfg: DiTConfig) -> dict:
    d, l, ff = cfg.d_model, cfg.n_layers, cfg.d_ff
    ks = layers.split_keys(key, 14)
    lay = {
        "wqkv": _stack(ks[0], l, (d, 3 * d)),
        "wo": _stack(ks[1], l, (d, d)),
        "w1": _stack(ks[2], l, (d, ff)),
        "w2": _stack(ks[3], l, (ff, d)),
        # adaLN-zero: 6 modulation vectors per block; zero-init so each
        # block starts as identity (the "-zero" in adaLN-zero).
        "ada_w": jnp.zeros((l, d, 6 * d), jnp.float32),
        "ada_b": jnp.zeros((l, 6 * d), jnp.float32),
    }
    grid = cfg.latent_res() // cfg.patch
    return {
        "patch_w": layers.fanin_init(ks[4], (cfg.patch_dim, d)),
        "patch_b": jnp.zeros((d,), jnp.float32),
        "pos": layers.normal_init(ks[5], (grid * grid, d)),
        "t_mlp1": layers.fanin_init(ks[6], (256, d)),
        "t_mlp2": layers.fanin_init(ks[7], (d, d)),
        "label_emb": layers.normal_init(ks[8], (cfg.n_classes + 1, d)),
        "layers": lay,
        "final_ada_w": jnp.zeros((d, 2 * d), jnp.float32),
        "final_ada_b": jnp.zeros((2 * d,), jnp.float32),
        # 2x channels: predict (eps, sigma) like the paper
        "final_w": jnp.zeros((d, 2 * cfg.patch_dim), jnp.float32),
        "final_b": jnp.zeros((2 * cfg.patch_dim,), jnp.float32),
    }


def _stack(key, l, shape):
    return jax.random.normal(key, (l, *shape), jnp.float32) / math.sqrt(
        shape[0])


def param_specs(cfg: DiTConfig, rules: Rules) -> dict:
    fs, mp = rules.fsdp, rules.model
    d, ff = cfg.d_model, cfg.d_ff
    lay = {
        "wqkv": P(None, fs, rules.shard_if(3 * d, mp)),
        "wo": P(None, rules.shard_if(d, mp), fs),
        "w1": P(None, fs, rules.shard_if(ff, mp)),
        "w2": P(None, rules.shard_if(ff, mp), fs),
        "ada_w": P(None, fs, rules.shard_if(6 * d, mp)),
        "ada_b": P(None, None),
    }
    return {
        "patch_w": P(None, fs), "patch_b": P(None),
        "pos": P(None, None),
        "t_mlp1": P(None, fs), "t_mlp2": P(fs, None),
        "label_emb": P(None, fs),
        "layers": lay,
        "final_ada_w": P(fs, None), "final_ada_b": P(None),
        "final_w": P(fs, None), "final_b": P(None),
    }


def abstract_params(cfg: DiTConfig):
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.key(0))


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def timestep_embedding(t: jnp.ndarray, dim: int = 256) -> jnp.ndarray:
    """Sinusoidal features of diffusion timestep t (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10_000.0)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def patchify(lat: jnp.ndarray, patch: int) -> jnp.ndarray:
    """(B, H, W, C) -> (B, H/p * W/p, p*p*C)."""
    b, hh, ww, c = lat.shape
    g_h, g_w = hh // patch, ww // patch
    x = lat.reshape(b, g_h, patch, g_w, patch, c)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(b, g_h * g_w, patch * patch * c)


def unpatchify(x: jnp.ndarray, patch: int, grid: int, c: int) -> jnp.ndarray:
    b, n, _ = x.shape
    x = x.reshape(b, grid, grid, patch, patch, c)
    x = jnp.transpose(x, (0, 1, 3, 2, 4, 5))
    return x.reshape(b, grid * patch, grid * patch, c)


def forward(params: dict, latents: jnp.ndarray, t: jnp.ndarray,
            labels: jnp.ndarray, cfg: DiTConfig, rules: Rules):
    """latents: (B, Hl, Wl, C); t: (B,) int; labels: (B,) int.
    Returns (eps_pred, sigma_raw) each (B, Hl, Wl, C)."""
    b, hl, _, c = latents.shape
    cd = layers.COMPUTE_DTYPE
    grid = hl // cfg.patch
    n_tok = grid * grid
    bspec = rules.batch_spec(b)
    # batch 4 on a 16-way data axis: shard tokens over data instead
    tspec = None if bspec is not None else rules.shard_if(
        n_tok, rules.batch[-1])
    mp = rules.model

    x = patchify(latents, cfg.patch).astype(cd) @ params["patch_w"].astype(cd)
    x = x + params["patch_b"].astype(cd)
    pos = params["pos"]
    if pos.shape[0] != n_tok:
        side = int(math.sqrt(pos.shape[0]))
        img = pos.reshape(1, side, side, -1)
        img = jax.image.resize(img, (1, grid, grid, pos.shape[-1]),
                               "bilinear")
        pos = img.reshape(n_tok, -1)
    if cfg.seq_shard and tspec is None:
        # Megatron-SP residual: tokens over model between blocks; GSPMD
        # lowers each block boundary to reduce-scatter + all-gather
        # instead of two all-reduces (half the wire bytes).
        tspec = rules.shard_if(n_tok, rules.model)
    # attention tensors are head-sharded over model — their token dim
    # must not also claim the model axis
    attn_tspec = None if tspec == rules.model else tspec
    x = x + pos.astype(cd)[None]
    x = rules.constrain(x, bspec, tspec, None)

    temb = timestep_embedding(t) @ params["t_mlp1"]
    cvec = (jax.nn.silu(temb) @ params["t_mlp2"]
            + params["label_emb"][labels])              # (B, D) f32
    cvec = jax.nn.silu(cvec).astype(cd)

    h, hd = cfg.n_heads, cfg.d_head
    s = n_tok

    def layer_body(x, lp):
        mods = cvec @ lp["ada_w"].astype(cd) + lp["ada_b"].astype(cd)
        (sh1, sc1, g1, sh2, sc2, g2) = jnp.split(mods, 6, axis=-1)
        hn = layers.layer_norm(x, jnp.ones((cfg.d_model,), jnp.float32),
                               None)
        hn = layers.modulate(hn, sh1, sc1)
        qkv = hn @ lp["wqkv"].astype(cd)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = rules.constrain(q.reshape(b, s, h, hd), bspec, attn_tspec,
                            mp, None)
        k = rules.constrain(k.reshape(b, s, h, hd), bspec, None, mp, None)
        v = rules.constrain(v.reshape(b, s, h, hd), bspec, None, mp, None)
        o = layers.chunked_attention(
            q, k, v, causal=False, q_chunk=s,
            kv_chunk=min(1024, s))
        o = o.reshape(b, s, h * hd) @ lp["wo"].astype(cd)
        x = x + g1[:, None, :] * o
        hn = layers.layer_norm(x, jnp.ones((cfg.d_model,), jnp.float32),
                               None)
        hn = layers.modulate(hn, sh2, sc2)
        out = layers.gelu(hn @ lp["w1"].astype(cd)) @ lp["w2"].astype(cd)
        x = x + g2[:, None, :] * out
        x = rules.constrain(x, bspec, tspec, None)
        return x, None

    x, _ = layers.scan_layers(layer_body, x, params["layers"],
                              n_layers=cfg.n_layers, unroll=cfg.unroll,
                              remat_policy=cfg.remat_policy)

    fmods = cvec @ params["final_ada_w"].astype(cd) + params[
        "final_ada_b"].astype(cd)
    fsh, fsc = jnp.split(fmods, 2, axis=-1)
    x = layers.modulate(
        layers.layer_norm(x, jnp.ones((cfg.d_model,), jnp.float32), None),
        fsh, fsc)
    out = x @ params["final_w"].astype(cd) + params["final_b"].astype(cd)
    eps, sigma = jnp.split(out, 2, axis=-1)
    return (unpatchify(eps, cfg.patch, grid, c),
            unpatchify(sigma, cfg.patch, grid, c))


# --------------------------------------------------------------------------
# Diffusion schedule (linear betas, DDPM) + steps
# --------------------------------------------------------------------------

def alphas_cumprod(cfg: DiTConfig) -> jnp.ndarray:
    betas = jnp.linspace(1e-4, 0.02, cfg.n_train_timesteps,
                         dtype=jnp.float32)
    return jnp.cumprod(1.0 - betas)


def train_loss(params, batch, cfg: DiTConfig, rules: Rules):
    """batch: latents (B,H,W,C), labels (B,), t (B,), noise (B,H,W,C)."""
    acp = alphas_cumprod(cfg)[batch["t"]][:, None, None, None]
    noisy = (jnp.sqrt(acp) * batch["latents"]
             + jnp.sqrt(1 - acp) * batch["noise"])
    eps, _ = forward(params, noisy, batch["t"], batch["labels"], cfg, rules)
    return jnp.mean(jnp.square(eps.astype(jnp.float32)
                               - batch["noise"].astype(jnp.float32))), {}


def make_train_step(cfg: DiTConfig, rules: Rules, *, lr=1e-4):
    def train_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(train_loss, has_aux=True)(
            params, batch, cfg, rules)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             lr=lr, weight_decay=0.0)
        return params, opt_state, {"loss": loss, **om}
    return train_step


def make_sample_step(cfg: DiTConfig, rules: Rules):
    """One DDIM update x_t -> x_{t_prev} (deterministic, eta=0)."""
    acp = alphas_cumprod(cfg)

    def sample_step(params, x_t, t, t_prev, labels):
        eps, _ = forward(params, x_t, t, labels, cfg, rules)
        eps = eps.astype(jnp.float32)
        a_t = acp[t][:, None, None, None]
        a_p = jnp.where(t_prev >= 0, acp[jnp.maximum(t_prev, 0)],
                        jnp.ones_like(t_prev, jnp.float32))[:, None, None,
                                                            None]
        x0 = (x_t.astype(jnp.float32) - jnp.sqrt(1 - a_t) * eps
              ) / jnp.sqrt(a_t)
        return (jnp.sqrt(a_p) * x0
                + jnp.sqrt(1 - a_p) * eps).astype(x_t.dtype)

    return sample_step
