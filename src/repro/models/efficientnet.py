"""EfficientNet-B7 — Tan & Le, arXiv:1905.11946 (width 2.0 / depth 3.1).

MBConv blocks (expand 1×1 → depthwise k×k → squeeze-excite → project 1×1)
with batch norm and SiLU.  The B7 scaling yields 55 blocks in 7 stages;
within each stage the stride-1 repeat blocks are identical and are scanned
(stacked params), so the traced depth stays at 7 stage-heads + 7 scans.

Batch norm carries running statistics in a separate ``state`` tree:
``apply(params, state, x, train=True)`` computes batch stats (all-reduced
over the data axes by GSPMD) and returns the updated state; ``train=False``
consumes the running stats (the serve_* shapes).

PhoneBit applicability (DESIGN §6): with ``binary_pointwise=True`` the 1×1
expand/project convs binarize (STE); depthwise convs (tiny K) and SE stay
float — the documented deviation.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import binarize
from repro.distributed.sharding import Rules
from repro.models import layers
from repro.optim import sgdm_update

# (expand_ratio, kernel, stride, base_out_channels, base_repeats)
_BASE_BLOCKS = ((1, 3, 1, 16, 1), (6, 3, 2, 24, 2), (6, 5, 2, 40, 2),
                (6, 3, 2, 80, 3), (6, 5, 1, 112, 3), (6, 5, 2, 192, 4),
                (6, 3, 1, 320, 1))
_BN_MOM = 0.99
_BN_EPS = 1e-3


def round_filters(c: float, width: float) -> int:
    c *= width
    new = max(8, int(c + 4) // 8 * 8)
    if new < 0.9 * c:
        new += 8
    return int(new)


def round_repeats(r: int, depth: float) -> int:
    return int(math.ceil(depth * r))


@dataclasses.dataclass(frozen=True)
class EffNetConfig:
    name: str
    img_res: int = 600
    width: float = 2.0
    depth: float = 3.1
    n_classes: int = 1000
    se_ratio: float = 0.25
    binary_pointwise: bool = False
    # Unroll repeat-block scans (exact dry-run cost accounting: XLA counts
    # while bodies once, so scans under-report FLOPs by repeat×).
    unroll: bool = False

    @property
    def stem_ch(self) -> int:
        return round_filters(32, self.width)

    @property
    def head_ch(self) -> int:
        return round_filters(1280, self.width)

    def stages(self):
        """Resolved per-stage (expand, kernel, stride, in_c, out_c, repeats)."""
        out = []
        prev = self.stem_ch
        for e, k, s, c, r in _BASE_BLOCKS:
            oc = round_filters(c, self.width)
            out.append((e, k, s, prev, oc, round_repeats(r, self.depth)))
            prev = oc
        return out

    def param_count(self) -> int:
        params = jax.eval_shape(
            functools.partial(init_params, cfg=self), jax.random.key(0))
        return sum(int(x.size) for x in jax.tree.leaves(params[0]))


def _mb_block_params(key, e, k, c_in, c_out, se_ratio,
                     n: int | None = None):
    """One MBConv block's params; n != None stacks n copies (scan xs)."""
    mid = c_in * e
    se = max(1, int(c_in * se_ratio))
    ks = iter(layers.split_keys(key, 8))
    def st(shape, init=layers.conv_init):
        if n is None:
            return init(next(ks), shape)
        kk = layers.split_keys(next(ks), n)
        return jnp.stack([init(k2, shape) for k2 in kk])
    def zeros(shape):
        return jnp.zeros(shape if n is None else (n, *shape), jnp.float32)
    def ones(shape):
        return jnp.ones(shape if n is None else (n, *shape), jnp.float32)
    p = {}
    if e != 1:
        p["exp_w"] = st((1, 1, c_in, mid))
        p["exp_bn_s"], p["exp_bn_b"] = ones((mid,)), zeros((mid,))
    p["dw_w"] = st((k, k, 1, mid))
    p["dw_bn_s"], p["dw_bn_b"] = ones((mid,)), zeros((mid,))
    p["se_w1"] = st((1, 1, mid, se))
    p["se_b1"] = zeros((se,))
    p["se_w2"] = st((1, 1, se, mid))
    p["se_b2"] = zeros((mid,))
    p["proj_w"] = st((1, 1, mid, c_out))
    p["proj_bn_s"], p["proj_bn_b"] = ones((c_out,)), zeros((c_out,))
    return p


def _mb_block_state(e, c_in, c_out, n: int | None = None):
    mid = c_in * e
    def zo(c):
        shape = (c,) if n is None else (n, c)
        return {"mean": jnp.zeros(shape, jnp.float32),
                "var": jnp.ones(shape, jnp.float32)}
    s = {}
    if e != 1:
        s["exp_bn"] = zo(mid)
    s["dw_bn"] = zo(mid)
    s["proj_bn"] = zo(c_out)
    return s


def init_params(key: jax.Array, cfg: EffNetConfig):
    """Returns (params, state) — state carries BN running stats."""
    ks = iter(layers.split_keys(key, 64))
    params: dict = {
        "stem_w": layers.conv_init(next(ks), (3, 3, 3, cfg.stem_ch)),
        "stem_bn_s": jnp.ones((cfg.stem_ch,), jnp.float32),
        "stem_bn_b": jnp.zeros((cfg.stem_ch,), jnp.float32),
        "stages": [],
    }
    state: dict = {
        "stem_bn": {"mean": jnp.zeros((cfg.stem_ch,), jnp.float32),
                    "var": jnp.ones((cfg.stem_ch,), jnp.float32)},
        "stages": [],
    }
    for e, k, s, c_in, c_out, r in cfg.stages():
        sp = {"head": _mb_block_params(next(ks), e, k, c_in, c_out,
                                       cfg.se_ratio)}
        ss = {"head": _mb_block_state(e, c_in, c_out)}
        if r > 1:
            sp["rest"] = _mb_block_params(next(ks), e, k, c_out, c_out,
                                          cfg.se_ratio, n=r - 1)
            ss["rest"] = _mb_block_state(e, c_out, c_out, n=r - 1)
        params["stages"].append(sp)
        state["stages"].append(ss)
    params.update({
        "head_w": layers.conv_init(
            next(ks), (1, 1, cfg.stages()[-1][4], cfg.head_ch)),
        "head_bn_s": jnp.ones((cfg.head_ch,), jnp.float32),
        "head_bn_b": jnp.zeros((cfg.head_ch,), jnp.float32),
        "fc_w": layers.normal_init(next(ks), (cfg.head_ch, cfg.n_classes)),
        "fc_b": jnp.zeros((cfg.n_classes,), jnp.float32),
    })
    state["head_bn"] = {"mean": jnp.zeros((cfg.head_ch,), jnp.float32),
                        "var": jnp.ones((cfg.head_ch,), jnp.float32)}
    return params, state


def param_specs(cfg: EffNetConfig, rules: Rules):
    """Channel (model-axis) sharding on every conv's output-channel dim."""
    params, state = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), jax.random.key(0))

    def spec_of(leaf):
        c = leaf.shape[-1]
        sh = rules.shard_if(c, rules.model)
        return P(*([None] * (leaf.ndim - 1)), sh)

    pspecs = jax.tree.map(spec_of, params)
    sspecs = jax.tree.map(spec_of, state)
    return pspecs, sspecs


def abstract_params(cfg: EffNetConfig):
    return jax.eval_shape(functools.partial(init_params, cfg=cfg),
                          jax.random.key(0))


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _bn(x, scale, bias, stats, train: bool):
    """Batch norm.  Returns (y, new_stats)."""
    xf = x.astype(jnp.float32)
    if train:
        mean = jnp.mean(xf, axis=(0, 1, 2))
        var = jnp.var(xf, axis=(0, 1, 2))
        new = {"mean": _BN_MOM * stats["mean"] + (1 - _BN_MOM) * mean,
               "var": _BN_MOM * stats["var"] + (1 - _BN_MOM) * var}
    else:
        mean, var = stats["mean"], stats["var"]
        new = stats
    y = (xf - mean) * lax.rsqrt(var + _BN_EPS) * scale + bias
    return y.astype(x.dtype), new


def _conv(x, w, stride=1, groups=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _pointwise_conv(x, w, binary: bool):
    if not binary:
        return _conv(x, w)
    xb = binarize.ste_sign(x.astype(jnp.float32)).astype(x.dtype)
    wb = binarize.ste_sign(w).astype(x.dtype)
    return _conv(xb, wb)


def _mb_block(x, p, s, *, expand, kernel, stride, train, binary,
              se_only_head=False):
    """One MBConv block.  Returns (y, new_state)."""
    ns = dict(s)
    h = x
    if expand != 1:
        h = _pointwise_conv(h, p["exp_w"], binary)
        h, ns["exp_bn"] = _bn(h, p["exp_bn_s"], p["exp_bn_b"],
                              s["exp_bn"], train)
        h = jax.nn.silu(h)
    mid = h.shape[-1]
    h = _conv(h, p["dw_w"], stride=stride, groups=mid)
    h, ns["dw_bn"] = _bn(h, p["dw_bn_s"], p["dw_bn_b"], s["dw_bn"], train)
    h = jax.nn.silu(h)
    # squeeze-excite (float, DESIGN §6)
    se = jnp.mean(h.astype(jnp.float32), axis=(1, 2), keepdims=True)
    se = jax.nn.silu(_conv(se, p["se_w1"]) + p["se_b1"])
    se = jax.nn.sigmoid(_conv(se, p["se_w2"]) + p["se_b2"])
    h = h * se.astype(h.dtype)
    h = _pointwise_conv(h, p["proj_w"], binary)
    h, ns["proj_bn"] = _bn(h, p["proj_bn_s"], p["proj_bn_b"],
                           s["proj_bn"], train)
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h, ns


def apply(params, state, images, cfg: EffNetConfig, rules: Rules, *,
          train: bool):
    """Returns (logits, new_state)."""
    cd = layers.COMPUTE_DTYPE
    b = images.shape[0]
    bspec = rules.batch_spec(b)
    new_state = {"stages": []}

    x = _conv(images.astype(cd), params["stem_w"], stride=2)
    x, new_state["stem_bn"] = _bn(x, params["stem_bn_s"],
                                  params["stem_bn_b"], state["stem_bn"],
                                  train)
    x = jax.nn.silu(x)

    for (e, k, s, c_in, c_out, r), sp, ss in zip(
            cfg.stages(), params["stages"], state["stages"]):
        x = rules.constrain(x, bspec, None, None,
                            rules.shard_if(x.shape[-1], rules.model))
        x, head_ns = _mb_block(x, sp["head"], ss["head"], expand=e,
                               kernel=k, stride=s, train=train,
                               binary=cfg.binary_pointwise)
        stage_ns = {"head": head_ns}
        if r > 1:
            def body(x, ps):
                bp, bs = ps
                y, ns = _mb_block(x, bp, bs, expand=e, kernel=k, stride=1,
                                  train=train, binary=cfg.binary_pointwise)
                return y, ns
            if cfg.unroll:
                all_ns = []
                for i in range(r - 1):
                    ps_i = jax.tree.map(lambda p, i=i: p[i],
                                        (sp["rest"], ss["rest"]))
                    x, ns_i = body(x, ps_i)
                    all_ns.append(ns_i)
                rest_ns = jax.tree.map(lambda *xs: jnp.stack(xs), *all_ns)
            else:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
                x, rest_ns = lax.scan(body, x, (sp["rest"], ss["rest"]))
            stage_ns["rest"] = rest_ns
        new_state["stages"].append(stage_ns)

    x = _conv(x, params["head_w"])
    x, new_state["head_bn"] = _bn(x, params["head_bn_s"],
                                  params["head_bn_b"], state["head_bn"],
                                  train)
    x = jax.nn.silu(x)
    x = jnp.mean(x.astype(jnp.float32), axis=(1, 2))
    logits = x @ params["fc_w"] + params["fc_b"]
    return logits, new_state


def loss_fn(params, state, batch, cfg: EffNetConfig, rules: Rules):
    logits, new_state = apply(params, state, batch["images"], cfg, rules,
                              train=True)
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold), new_state


def make_train_step(cfg: EffNetConfig, rules: Rules, *, lr=0.016):
    def train_step(params, state, opt_state, batch):
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, state, batch, cfg, rules)
        params, opt_state, om = sgdm_update(params, grads, opt_state,
                                            lr=lr)
        return params, new_state, opt_state, {"loss": loss, **om}
    return train_step
