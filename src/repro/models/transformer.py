"""Decoder-only transformer LMs: dense + MoE, GQA, RoPE, KV-cache decode.

Covers the four assigned LM architectures (granite-moe-3b-a800m,
qwen3-moe-30b-a3b, minitron-8b, command-r-35b) plus arbitrary reduced smoke
configs.  Design points:

* **scan-over-layers + remat** — parameters are stacked on a leading L dim;
  one traced layer keeps HLO size and compile time flat in depth, remat
  bounds activation memory to one layer's residual stash.
* **Attention sharding modes** (picked per arch by divisibility, see
  distributed.sharding):
  - ``tp_heads`` (n_heads % tp == 0): Megatron-style — Q/K/V heads sharded
    over ``model``; the *triangular* chunked-attention schedule runs
    (~S²/2 causal FLOPs).
  - ``sp_seq`` (fallback, e.g. granite's 24 heads on 16 shards): Q sequence
    dim sharded over ``model``, K/V gathered; full masked KV scan (≤2×
    causal FLOPs, noted in the roofline's useful-FLOPs ratio).
* **SP residual stream** — activations between blocks are
  P(batch, model, None): the per-layer stash that remat saves is sharded
  over *both* mesh axes, which is what lets 32k-token training fit.
* **MoE** — expert-parallel shard_map with explicit all_to_all
  (models.moe); expert count padded to the EP degree when non-divisible.
* **Decode** — flash-decoding SP: the KV cache shards its sequence dim over
  ``model``; softmax/PV over the sharded dim lowers to two small
  all-reduces (max, sum) instead of a cache all-gather.

``long_500k`` is skipped for these archs: their published configs are pure
full attention (see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import Rules
from repro.models import layers, moe as moe_lib
from repro.optim import adamw_init, adamw_update

# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # variants
    qk_norm: bool = False
    mlp_act: str = "swiglu"          # "swiglu" | "relu2"
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    # attention chunking
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # PhoneBit technique flag (out of the paper's scope for LMs; DESIGN §6)
    binary_mlp: bool = False
    # Unrolled layer loop (dry-run cost probes; see layers.scan_layers)
    unroll: bool = False
    # Activation-checkpoint policy: "nothing" (min memory) or "dots"
    # (save matmul outputs — no bwd recompute; use when HBM has headroom)
    remat_policy: str = "nothing"
    # Remat the attention KV-scan step (see layers.chunked_attention)
    attn_step_remat: bool = True

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def padded_experts(self, ep: int) -> int:
        return moe_lib.padded_experts(self.n_experts, ep)

    # ---- analytics -------------------------------------------------------
    def param_count(self, ep: int = 1) -> int:
        d, l = self.d_model, self.n_layers
        attn = d * self.qkv_dim + 2 * d * self.kv_dim + self.qkv_dim * d
        if self.moe:
            e = self.n_experts
            mlp = d * e + 3 * e * d * self.d_ff_expert
        else:
            n_mats = 3 if self.mlp_act == "swiglu" else 2
            mlp = n_mats * d * self.d_ff
        norms = 2 * d + (2 * self.d_head if self.qk_norm else 0)
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + mlp + norms) + embed + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        attn = d * self.qkv_dim + 2 * d * self.kv_dim + self.qkv_dim * d
        mlp = d * self.n_experts + 3 * self.top_k * d * self.d_ff_expert
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + mlp + 2 * d) + embed + d

    def train_flops_per_token(self) -> float:
        """MODEL_FLOPS/token = 6·N_active (fwd 2N + bwd 4N), attn excluded."""
        return 6.0 * self.active_param_count()


# --------------------------------------------------------------------------
# Parameter init + specs
# --------------------------------------------------------------------------

def padded_vocab(vocab: int, multiple: int) -> int:
    """Megatron-style vocab padding: a vocab that does not divide the TP
    degree (granite: 49155 on 16) would leave the logits REPLICATED —
    measured 1.5 GB × dozens of live buffers per device and 16× redundant
    head FLOPs (perf-log H2).  Pad ids are masked to -inf in the loss and
    never produced by decode."""
    return -(-vocab // multiple) * multiple


def init_params(key: jax.Array, cfg: LMConfig, ep: int = 1,
                vocab_pad_to: int = 1) -> dict:
    """Stacked-layer parameter pytree.  ``ep`` pads the expert dim,
    ``vocab_pad_to`` pads the vocab (pass the TP degree)."""
    d, l = cfg.d_model, cfg.n_layers
    v_pad = padded_vocab(cfg.vocab, vocab_pad_to)
    ks = layers.split_keys(key, 16)
    lay: dict[str, jnp.ndarray] = {
        "ln1": jnp.ones((l, d), jnp.float32),
        "ln2": jnp.ones((l, d), jnp.float32),
        "wq": _stack(ks[0], l, (d, cfg.qkv_dim)),
        "wk": _stack(ks[1], l, (d, cfg.kv_dim)),
        "wv": _stack(ks[2], l, (d, cfg.kv_dim)),
        "wo": _stack(ks[3], l, (cfg.qkv_dim, d)),
    }
    if cfg.qk_norm:
        lay["q_norm"] = jnp.ones((l, cfg.d_head), jnp.float32)
        lay["k_norm"] = jnp.ones((l, cfg.d_head), jnp.float32)
    if cfg.moe:
        e_pad = cfg.padded_experts(ep)
        fe = cfg.d_ff_expert
        lay["router"] = _stack(ks[4], l, (d, e_pad))
        lay["we_gate"] = _stack(ks[5], l, (e_pad, d, fe))
        lay["we_up"] = _stack(ks[6], l, (e_pad, d, fe))
        lay["we_down"] = _stack(ks[7], l, (e_pad, fe, d))
    else:
        if cfg.mlp_act == "swiglu":
            lay["w_gate"] = _stack(ks[4], l, (d, cfg.d_ff))
        lay["w_up"] = _stack(ks[5], l, (d, cfg.d_ff))
        lay["w_down"] = _stack(ks[6], l, (cfg.d_ff, d))
    params = {
        "embed": layers.normal_init(ks[8], (v_pad, d)),
        "layers": lay,
        "final_norm": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.normal_init(ks[9], (d, v_pad))
    return params


def _stack(key, l, shape):
    fan_in = shape[0] if len(shape) == 2 else shape[1]
    return (jax.random.normal(key, (l, *shape), jnp.float32)
            / math.sqrt(fan_in))


def param_specs(cfg: LMConfig, rules: Rules) -> dict:
    """PartitionSpec pytree matching init_params (FSDP + TP 2D sharding)."""
    fs, mp = rules.fsdp, rules.model
    lay: dict[str, P] = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, fs, rules.shard_if(cfg.qkv_dim, mp)),
        "wk": P(None, fs, rules.shard_if(cfg.kv_dim, mp)),
        "wv": P(None, fs, rules.shard_if(cfg.kv_dim, mp)),
        "wo": P(None, rules.shard_if(cfg.qkv_dim, mp), fs),
    }
    if cfg.qk_norm:
        lay["q_norm"] = P(None, None)
        lay["k_norm"] = P(None, None)
    if cfg.moe:
        lay["router"] = P(None, None, None)
        lay["we_gate"] = P(None, mp, fs, None)
        lay["we_up"] = P(None, mp, fs, None)
        lay["we_down"] = P(None, mp, None, fs)
    else:
        ff = rules.shard_if(cfg.d_ff, mp)
        if cfg.mlp_act == "swiglu":
            lay["w_gate"] = P(None, fs, ff)
        lay["w_up"] = P(None, fs, ff)
        lay["w_down"] = P(None, ff, fs)
    specs = {
        "embed": P(rules.shard_if(padded_vocab(cfg.vocab, rules.tp),
                                  mp), fs),
        "layers": lay,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(
            fs, rules.shard_if(padded_vocab(cfg.vocab, rules.tp), mp))
    return specs


def abstract_params(cfg: LMConfig, ep: int = 1, vocab_pad_to: int = 1):
    """ShapeDtypeStruct pytree — dry-run stand-in, no allocation."""
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg, ep=ep,
                          vocab_pad_to=vocab_pad_to),
        jax.random.key(0))


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _rms(x, scale, eps):
    return layers.rms_norm(x, scale, eps)


def _attention(x, lp, cfg: LMConfig, rules: Rules, bspec, positions):
    """Causal self-attention over the full sequence (train / prefill)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    cd = layers.COMPUTE_DTYPE
    hnorm = _rms(x, lp["ln1"], cfg.norm_eps)
    # Pin the norm to the sequence-sharded side: otherwise GSPMD hoists
    # the Megatron all-gather BEFORE the norm and its f32 internals
    # materialize at full sequence length (2 GB/buffer on command-r).
    hnorm = rules.constrain(hnorm, bspec, rules.shard_if(s, rules.model),
                            None)
    tp_heads = (h % rules.tp == 0) and rules.tp > 1
    if tp_heads:
        # Megatron-SP boundary made EXPLICIT: one bf16 all-gather of the
        # normed hidden over the sequence axis, then every head-sharded
        # tensor is produced locally.  Leaving the boundary implicit made
        # GSPMD transition q/k/v themselves from S-sharded to
        # head-sharded — an "involuntary full rematerialization"
        # (replicate-then-partition) of (B,S,H,hd) tensors (perf-log
        # it2/it6).
        hnorm = rules.constrain(hnorm, bspec, None, None)
    q = (hnorm @ lp["wq"].astype(cd)).reshape(b, s, h, hd)
    k = (hnorm @ lp["wk"].astype(cd)).reshape(b, s, kvh, hd)
    v = (hnorm @ lp["wv"].astype(cd)).reshape(b, s, kvh, hd)
    if tp_heads:
        q = rules.constrain(q, bspec, None, rules.model, None)
        k = rules.constrain(k, bspec, None, None, None)
        v = rules.constrain(v, bspec, None, None, None)
    if cfg.qk_norm:
        q = layers.rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = layers.rms_norm(k, lp["k_norm"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)

    if tp_heads:
        # Native GQA (no KV repeat): Q's head sharding propagates through
        # the (KV, G) reshape as a [KV×G] tiling with no transition.
        o = layers.chunked_attention(
            q, k, v, causal=True,
            q_chunk=min(cfg.q_chunk, s), kv_chunk=min(cfg.kv_chunk, s),
            step_remat=cfg.attn_step_remat)
    else:
        # SP attention: Q sequence-sharded, K/V gathered, full masked scan.
        sspec = rules.shard_if(s, rules.model)
        q = rules.constrain(q, bspec, sspec, None, None)
        k = rules.constrain(k, bspec, None, None, None)
        v = rules.constrain(v, bspec, None, None, None)
        o = layers.chunked_attention(
            q, k, v, causal=True, q_chunk=s,
            kv_chunk=min(cfg.kv_chunk, s),
            step_remat=cfg.attn_step_remat)
    o = o.reshape(b, s, h * hd) @ lp["wo"].astype(cd)
    return x + o


def _mlp_dense(hnorm, lp, cfg: LMConfig):
    cd = layers.COMPUTE_DTYPE
    up = hnorm @ lp["w_up"].astype(cd)
    if cfg.mlp_act == "swiglu":
        gate = hnorm @ lp["w_gate"].astype(cd)
        hmid = jax.nn.silu(gate.astype(jnp.float32)).astype(cd) * up
    elif cfg.mlp_act == "relu2":
        hmid = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(cfg.mlp_act)
    return hmid @ lp["w_down"].astype(cd)


def _mlp_or_moe(x, lp, cfg: LMConfig, rules: Rules, bspec):
    b, s, d = x.shape
    hnorm = _rms(x, lp["ln2"], cfg.norm_eps)
    hnorm = rules.constrain(hnorm, bspec, rules.shard_if(s, rules.model),
                            None)  # see _attention: norm stays SP-side
    if not cfg.moe and cfg.d_ff % rules.tp == 0 and rules.tp > 1:
        # Explicit Megatron-SP boundary for the dense MLP (same reasoning
        # as _attention): one bf16 S-gather, then F-sharded matmuls.
        hnorm = rules.constrain(hnorm, bspec, None, None)
    if cfg.moe:
        tok = hnorm.reshape(b * s, d)
        taxes = rules.tokens_spec(b * s)
        taxes = (taxes,) if isinstance(taxes, str) else (taxes or ())
        out, aux = moe_lib.moe_apply(
            tok, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"],
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, rules=rules,
            token_axes=taxes, act=cfg.mlp_act)
        return x + out.reshape(b, s, d), aux
    out = _mlp_dense(hnorm, lp, cfg)
    return x + out, jnp.zeros((), jnp.float32)


def forward_hidden(params: dict, tokens: jnp.ndarray, cfg: LMConfig,
                   rules: Rules) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Embed + all layers + final norm.  Returns (x (B,S,D), aux)."""
    b, s = tokens.shape
    bspec = rules.batch_spec(b)
    sspec = rules.shard_if(s, rules.model)
    cd = layers.COMPUTE_DTYPE

    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    x = rules.constrain(x, bspec, sspec, None)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]

    def layer_body(carry, lp):
        x = carry
        x = _attention(x, lp, cfg, rules, bspec, positions)
        x, aux = _mlp_or_moe(x, lp, cfg, rules, bspec)
        x = rules.constrain(x, bspec, sspec, None)
        return x, aux

    x, auxs = layers.scan_layers(layer_body, x, params["layers"],
                                 n_layers=cfg.n_layers, unroll=cfg.unroll,
                                 remat_policy=cfg.remat_policy)
    x = _rms(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.mean(auxs)


def _head(params, cfg: LMConfig):
    return (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(layers.COMPUTE_DTYPE)


def _mask_pad_vocab(logits, cfg: LMConfig):
    """-inf on padded vocab columns (argmax/softmax never pick them)."""
    v_pad = logits.shape[-1]
    if v_pad == cfg.vocab:
        return logits
    mask = jnp.arange(v_pad) < cfg.vocab
    return jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))


def forward(params: dict, tokens: jnp.ndarray, cfg: LMConfig,
            rules: Rules) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits (B,S,Vp) f32-castable, aux);
    padded vocab columns (if any) are masked to -inf."""
    b, s = tokens.shape
    x, aux = forward_hidden(params, tokens, cfg, rules)
    head = _head(params, cfg)
    logits = x @ head
    logits = rules.constrain(
        logits, rules.batch_spec(b), None,
        rules.shard_if(head.shape[1], rules.model))
    return _mask_pad_vocab(logits, cfg), aux


# --------------------------------------------------------------------------
# Loss + train step
# --------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4):
    """Mean token CE over a (possibly vocab-sharded) logits tensor."""
    lg = logits.astype(jnp.float32)
    m = jnp.max(lg, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - gold)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def chunked_ce(x: jnp.ndarray, head: jnp.ndarray, labels: jnp.ndarray,
               rules: Rules, vocab: int, z_loss: float = 1e-4,
               n_chunks: int = 8):
    """Sequence-chunked head-matmul + cross-entropy.

    The full (B, S, V) f32 logits of a 256k-vocab model are multi-GB per
    device (command-r train_4k: ~6 GB of the HBM budget); computing the
    head and the CE per S-chunk in a static python loop keeps the peak at
    one chunk while leaving cost accounting exact (no scan).
    """
    b, s, _ = x.shape
    bspec = rules.batch_spec(b)
    # Shard on the head's actual (possibly vocab-padded) width — the raw
    # vocab may not divide the TP degree (granite: 49155), which would
    # silently replicate every logits chunk (perf-log H2-it3).
    width = head.shape[1]
    vspec = rules.shard_if(width, rules.model)
    n_chunks = max(1, min(n_chunks, s))
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks
    # Resolve the head's FSDP (data-axis) sharding ONCE: inside the loop it
    # would be re-all-gathered per chunk (command-r: 4.2 GB × n_chunks).
    head = rules.constrain(head, None, vspec)
    pad_mask = (jnp.arange(width) < vocab) if width != vocab else None
    total = jnp.zeros((), jnp.float32)
    ztotal = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        xc = lax.slice_in_dim(x, i * cs, (i + 1) * cs, axis=1)
        lc = lax.slice_in_dim(labels, i * cs, (i + 1) * cs, axis=1)
        logits = xc @ head
        logits = rules.constrain(logits, bspec, None, vspec)
        lg = logits.astype(jnp.float32)
        if pad_mask is not None:
            lg = jnp.where(pad_mask, lg, -1e30)
        m = jnp.max(lg, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1))
        gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(lse - gold)
        ztotal = ztotal + jnp.sum(jnp.square(lse))
    n_tok = b * s
    return total / n_tok + z_loss * ztotal / n_tok


def loss_fn(params, batch, cfg: LMConfig, rules: Rules,
            aux_weight: float = 0.01):
    x, aux = forward_hidden(params, batch["tokens"], cfg, rules)
    ce = chunked_ce(x, _head(params, cfg), batch["labels"], rules,
                    cfg.vocab)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: LMConfig, rules: Rules, *, lr=3e-4):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, rules)
        params, opt_state, om = adamw_update(
            params, grads, opt_state, lr=lr)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------
# Serving: prefill + decode with a sequence-sharded KV cache
# --------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int):
    """KV-head-major layout (L, B, KV, S, hd): decode's QK/PV einsums
    consume it with NO physical transpose (the (S, hd) panel is the GEMM
    operand) — the naive (B, S, KV, hd) layout costs two full-cache
    transposes per layer per token (measured in EXPERIMENTS §Roofline
    decode diagnosis)."""
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.d_head)
    return {"k": jnp.zeros(shape, layers.COMPUTE_DTYPE),
            "v": jnp.zeros(shape, layers.COMPUTE_DTYPE)}


def abstract_cache(cfg: LMConfig, batch: int, max_seq: int):
    return jax.eval_shape(functools.partial(
        init_cache, cfg, batch, max_seq))


def cache_specs(cfg: LMConfig, rules: Rules, batch: int, max_seq: int):
    """Flash-decoding SP: cache sequence dim sharded over ``model``."""
    spec = P(None, rules.batch_spec(batch), None,
             rules.shard_if(max_seq, rules.model), None)
    return {"k": spec, "v": spec}


def make_prefill_step(cfg: LMConfig, rules: Rules, max_seq: int):
    """Prefill: logits for the whole prompt + a filled KV cache."""

    def prefill_step(params, tokens):
        b, s = tokens.shape
        bspec = rules.batch_spec(b)
        sspec = rules.shard_if(s, rules.model)
        cd = layers.COMPUTE_DTYPE
        x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
        x = rules.constrain(x, bspec, sspec, None)
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]

        def layer_body(x, lp):
            hnorm = _rms(x, lp["ln1"], cfg.norm_eps)
            k = (hnorm @ lp["wk"].astype(cd)).reshape(
                b, s, cfg.n_kv_heads, cfg.d_head)
            v = (hnorm @ lp["wv"].astype(cd)).reshape(
                b, s, cfg.n_kv_heads, cfg.d_head)
            if cfg.qk_norm:
                k = layers.rms_norm(k, lp["k_norm"], cfg.norm_eps)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            x = _attention(x, lp, cfg, rules, bspec, positions)
            x, _ = _mlp_or_moe(x, lp, cfg, rules, bspec)
            x = rules.constrain(x, bspec, sspec, None)
            # cache layout (B, KV, S, hd) — see init_cache
            kc = _pad_seq(jnp.transpose(k, (0, 2, 1, 3)), max_seq)
            vc = _pad_seq(jnp.transpose(v, (0, 2, 1, 3)), max_seq)
            cspec = P(bspec, None, rules.shard_if(max_seq, rules.model),
                      None)
            kc = rules.constrain(kc, *cspec)
            vc = rules.constrain(vc, *cspec)
            return x, {"k": kc, "v": vc}

        x, cache = layers.scan_layers(
            layer_body, x, params["layers"], n_layers=cfg.n_layers,
            unroll=cfg.unroll, remat=False)
        x = _rms(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(cd)
        # Serving prefill only needs the last position's logits.
        logits = x[:, -1, :] @ head
        return logits, cache

    return prefill_step


def _pad_seq(x, max_seq):
    """Pad the seq dim (axis 2 of the (B, KV, S, hd) cache layout)."""
    s = x.shape[2]
    if s == max_seq:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, max_seq - s), (0, 0)))


def make_decode_step(cfg: LMConfig, rules: Rules, max_seq: int):
    """One decode step: (params, cache, tokens (B,1), pos ()) ->
    (logits (B,V), new cache).

    Attention over the sequence-sharded cache is written as a plain masked
    softmax over max_seq; GSPMD lowers the sharded-axis max/sum/PV into the
    flash-decoding combine (two small all-reduces), never gathering the
    cache.
    """
    def decode_step(params, cache, tokens, pos):
        b = tokens.shape[0]
        bspec = rules.batch_spec(b)
        h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        g = h // kvh
        cd = layers.COMPUTE_DTYPE
        # (B, KV, S, hd) cache layout — see init_cache
        cspec = (bspec, None, rules.shard_if(max_seq, rules.model), None)

        x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(cd)
        x = rules.constrain(x, bspec, None)
        positions = jnp.full((b, 1), pos, jnp.int32)

        def layer_body(x, xs):
            lp, kc, vc = xs                       # kc/vc (B, KVH, Smax, hd)
            hnorm = _rms(x, lp["ln1"], cfg.norm_eps)
            q = (hnorm @ lp["wq"].astype(cd)).reshape(b, 1, h, hd)
            k = (hnorm @ lp["wk"].astype(cd)).reshape(b, 1, kvh, hd)
            v = (hnorm @ lp["wv"].astype(cd)).reshape(b, 1, kvh, hd)
            if cfg.qk_norm:
                q = layers.rms_norm(q, lp["q_norm"], cfg.norm_eps)
                k = layers.rms_norm(k, lp["k_norm"], cfg.norm_eps)
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            k_ins = jnp.transpose(k, (0, 2, 1, 3))     # (B, KV, 1, hd)
            v_ins = jnp.transpose(v, (0, 2, 1, 3))
            kc = lax.dynamic_update_slice(kc, k_ins, (0, 0, pos, 0))
            vc = lax.dynamic_update_slice(vc, v_ins, (0, 0, pos, 0))
            kc = rules.constrain(kc, *cspec)
            vc = rules.constrain(vc, *cspec)

            qf = (q.reshape(b, kvh, g, hd).astype(jnp.float32)
                  / math.sqrt(hd))
            # layout-native: (S, hd) is the GEMM panel, no cache transpose
            s = jnp.einsum("bhgd,bhsd->bhgs", qf, kc.astype(jnp.float32))
            valid = jnp.arange(max_seq) <= pos
            s = jnp.where(valid[None, None, None], s, -1e30)
            m = jnp.max(s, axis=-1, keepdims=True)     # all-reduce (model)
            p = jnp.exp(s - m)
            l = jnp.sum(p, axis=-1, keepdims=True)     # all-reduce (model)
            o = jnp.einsum("bhgs,bhsd->bhgd", p / l,
                           vc.astype(jnp.float32))     # psum (model)
            o = o.reshape(b, h * hd).astype(cd) @ lp["wo"].astype(cd)
            x = x + o

            hnorm2 = _rms(x, lp["ln2"], cfg.norm_eps)
            if cfg.moe:
                taxes = rules.tokens_spec(b)
                taxes = ((taxes,) if isinstance(taxes, str)
                         else (taxes or ()))
                out, _ = moe_lib.moe_apply(
                    hnorm2, lp["router"], lp["we_gate"], lp["we_up"],
                    lp["we_down"], n_experts=cfg.n_experts,
                    top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                    rules=rules, token_axes=taxes, act=cfg.mlp_act)
            else:
                out = _mlp_dense(hnorm2, lp, cfg)
            return x + out, {"k": kc, "v": vc}

        x, new_cache = layers.scan_layers(
            layer_body, x, (params["layers"], cache["k"], cache["v"]),
            n_layers=cfg.n_layers, unroll=cfg.unroll, remat=False)
        x = _rms(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(cd)
        logits = x @ head
        logits = rules.constrain(
            logits, bspec, rules.shard_if(head.shape[1], rules.model))
        return _mask_pad_vocab(logits, cfg), new_cache

    return decode_step
