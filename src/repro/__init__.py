"""repro: PhoneBit (DATE'19) on TPU — a JAX/Pallas BNN serving + training
framework with multi-pod distribution.

Layers (bottom-up):
  core          the paper's contribution: packing, xor-popcount ops,
                layer integration, bit-planes, converter, BNN engine model
  kernels       Pallas TPU kernels (+ pure-jnp oracles)
  models        model zoo: LM transformers (dense/MoE), DiT, ViT,
                ConvNeXt, EfficientNet, and the paper's own networks
  configs       --arch registry: 10 assigned architectures × shapes
  distributed   placement: data-parallel sharding, pipeline stages,
                replica groups, straggler-aware routing
  optim         AdamW / SGD, schedules, STE-aware updates
  data          deterministic shardable pipelines
  checkpoint    atomic async checkpoints, elastic re-mesh restore
  serving       PhoneBit engine, batch scheduler, KV-cache manager
  launch        production mesh, dry-run driver, train/serve loops
"""

__version__ = "1.0.0"
