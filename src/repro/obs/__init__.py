"""Unified observability layer (DESIGN.md §10).

    trace       span tracing with Chrome/Perfetto trace-event export;
                disabled by default behind a no-op fast path
    metrics     counters/gauges/histograms/events registry + the one
                canonical percentile/summary implementation, and the
                ServingMetrics view both servers share
    flight      bounded ring buffer of recent request records (postmortems)
    provenance  the ``meta`` block stamped into every BENCH_*.json

The contract: with tracing disabled (the default) the hot path sees one
global read per instrumentation site and zero jit retraces; enabling it
adds host-side spans only (never anything traced), so served results
stay bit-exact and ``trace_count`` stays flat — both pinned by
``tests/test_obs.py``.
"""

from repro.obs import metrics, trace
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (MetricsRegistry, ServingMetrics,
                               get_registry, percentile, summarize,
                               use_registry)
from repro.obs.provenance import provenance_meta, stamp, write_bench
from repro.obs.trace import (Tracer, get_tracer, install, span, uninstall,
                             validate_trace)

__all__ = [
    "FlightRecorder", "MetricsRegistry", "ServingMetrics", "Tracer",
    "get_registry", "get_tracer", "install", "metrics", "percentile",
    "provenance_meta", "span", "stamp", "summarize", "trace", "uninstall",
    "use_registry", "validate_trace", "write_bench",
]
