"""Benchmark provenance: the ``meta`` block every ``BENCH_*.json``
carries (DESIGN.md §10.5).

A benchmark number without its context — which commit, which jax, which
device — is not comparable across runs; the bench trajectory only
becomes a trajectory once every artifact is stamped.  ``stamp(report)``
adds a ``meta`` dict with git sha, jax/jaxlib versions, device
kind/count, timestamp, and the executor backend list; every writer in
``benchmarks/`` goes through :func:`write_bench` (via
``benchmarks.common``), and CI's obs-smoke job asserts the block is
present.

Everything is best-effort: a missing git binary or a detached worktree
yields ``None`` fields, never a failed benchmark.
"""

from __future__ import annotations

import datetime
import json
import pathlib
import platform
import subprocess
import sys

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]

META_SCHEMA = "bench-meta-v1"


def git_revision(root: pathlib.Path | None = None
                 ) -> tuple[str | None, bool | None]:
    """(sha, dirty) of the repo containing this package; (None, None)
    when git is unavailable."""
    cwd = root or _REPO_ROOT
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip())
        return sha, dirty
    except (OSError, subprocess.SubprocessError):
        return None, None


def provenance_meta() -> dict:
    """The meta block: enough to compare two BENCH artifacts honestly."""
    import jax

    try:
        import jaxlib

        jaxlib_version = jaxlib.__version__
    except Exception:
        jaxlib_version = None
    try:
        devices = jax.devices()
        device_kind = devices[0].device_kind
        n_devices = len(devices)
    except RuntimeError:
        device_kind, n_devices = None, 0
    from repro.runtime.executor import ALL_MODES

    sha, dirty = git_revision()
    return {
        "schema": META_SCHEMA,
        "git_sha": sha,
        "git_dirty": dirty,
        "jax": jax.__version__,
        "jaxlib": jaxlib_version,
        "backend": jax.default_backend(),
        "device_kind": device_kind,
        "n_devices": n_devices,
        "backends": list(ALL_MODES),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
                             .isoformat(timespec="seconds"),
    }


def stamp(report: dict) -> dict:
    """A copy of ``report`` carrying the provenance ``meta`` block."""
    return dict(report, meta=provenance_meta())


def write_bench(path, report: dict, *, sort_keys: bool = False) -> dict:
    """Stamp and write one BENCH artifact; returns the stamped report —
    the single write path for every ``BENCH_*.json``."""
    stamped = stamp(report)
    with open(path, "w") as f:
        json.dump(stamped, f, indent=1, sort_keys=sort_keys)
        f.write("\n")
    return stamped
