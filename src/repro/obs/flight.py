"""Flight recorder: a bounded ring of recent request records
(DESIGN.md §10.3).

The postmortem surface for a long-running server: when a latency spike
or a burst of deadline sheds shows up in the metrics, ``dump()`` gives
the last N requests with arrival time, bucket, deadline outcome, and
per-stage timings — without the unbounded growth of a full trace.  The
ring is plain host-side bookkeeping (a ``deque(maxlen=...)`` of dicts),
always on, O(1) per request.
"""

from __future__ import annotations

from collections import deque


class FlightRecorder:
    """Keeps the most recent ``capacity`` request records."""

    def __init__(self, capacity: int = 256,
                 tags: dict | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        # Fields stamped onto every record — how a multi-tenant server
        # marks each lane's records with its tenant name.
        self.tags = dict(tags) if tags else {}
        self._records: deque[dict] = deque(maxlen=capacity)

    def record(self, **fields) -> dict:
        """Append one request record (free-form fields; the servers write
        id/arrival_s/bucket/outcome/latency_s/stage timings)."""
        if self.tags:
            fields = {**self.tags, **fields}
        self._records.append(fields)
        return fields

    def __len__(self) -> int:
        return len(self._records)

    def dump(self) -> list[dict]:
        """Oldest-to-newest copies of the retained records."""
        return [dict(r) for r in self._records]

    def last(self, n: int = 1) -> list[dict]:
        return [dict(r) for r in list(self._records)[-n:]]

    def clear(self) -> None:
        self._records.clear()
