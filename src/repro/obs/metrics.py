"""Metrics registry: counters, gauges, histograms, structured events
(DESIGN.md §10.2).

Also the canonical home of the percentile/summary math — the one
nearest-rank :func:`percentile` the serving metrics, the benchmark
timers, and the tests all share (previously each carried its own copy).

A :class:`MetricsRegistry` is plain host-side bookkeeping: integer adds
and list appends, never anything traced — it is always on (the serving
metrics have always been) and costs nanoseconds per update.  The default
process registry is what the runtime/serving/autotune instrumentation
writes to; tests swap a fresh one in with :func:`use_registry`.

Metric naming: dot-separated ``subsystem.metric`` with units in the
suffix (``_s`` seconds, ``_ms`` milliseconds, ``_bytes`` bytes); the
full catalogue lives in DESIGN.md §10.2.
"""

from __future__ import annotations

import contextlib
import math
import time
from collections import deque
from typing import Callable, Iterable, Sequence


# ---- canonical percentile / summary math ----------------------------------

def percentile(sorted_vals: Sequence[float], p: float) -> float | None:
    """Nearest-rank percentile of an ascending sequence (None when
    empty): the smallest value with at least ``p`` of the sample at or
    below it, i.e. index ``ceil(p*n) - 1``."""
    n = len(sorted_vals)
    if not n:
        return None
    return sorted_vals[max(0, min(n - 1, math.ceil(p * n) - 1))]


def summarize(samples: Iterable[float]) -> dict:
    """count/min/max/mean/p50/p95 of a sample (the one summary shape)."""
    vals = sorted(samples)
    if not vals:
        return {"count": 0, "min": None, "max": None, "mean": None,
                "p50": None, "p95": None}
    return {"count": len(vals), "min": vals[0], "max": vals[-1],
            "mean": sum(vals) / len(vals),
            "p50": percentile(vals, 0.50), "p95": percentile(vals, 0.95)}


# ---- primitives ------------------------------------------------------------

class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-written value (e.g. a plan's ``peak_bytes``)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = None

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Sample accumulator summarized via the canonical percentile math."""

    __slots__ = ("name", "samples")

    def __init__(self, name: str):
        self.name = name
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(v)

    def observe_many(self, vals: Iterable[float]) -> None:
        self.samples.extend(vals)

    @property
    def count(self) -> int:
        return len(self.samples)

    def summary(self) -> dict:
        return summarize(self.samples)


class MetricsRegistry:
    """Named counters/gauges/histograms plus a bounded structured-event
    ring (``event()`` — what the autotuner's hit/miss audit trail uses)."""

    def __init__(self, max_events: int = 4096):
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._events: deque[dict] = deque(maxlen=max_events)

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    # ---- structured events ------------------------------------------------
    def event(self, name: str, **fields) -> dict:
        ev = dict(event=name, **fields)
        self._events.append(ev)
        return ev

    def events(self, name: str | None = None) -> list[dict]:
        return [e for e in self._events
                if name is None or e["event"] == name]

    # ---- reporting --------------------------------------------------------
    def snapshot(self) -> dict:
        """name -> value (counters/gauges) or summary dict (histograms)."""
        out: dict = {}
        for name, m in sorted(self._metrics.items()):
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        self._metrics.clear()
        self._events.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The default process registry (what instrumentation writes to)."""
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, registry
    return prev


@contextlib.contextmanager
def use_registry(registry: MetricsRegistry | None = None):
    """Swap in a registry (default: a fresh one) for a scope — how tests
    isolate their counts from process-global state."""
    reg = registry if registry is not None else MetricsRegistry()
    prev = set_registry(reg)
    try:
        yield reg
    finally:
        set_registry(prev)


# ---- serving metrics (shared by both servers) ------------------------------

class ServingMetrics:
    """Latency/throughput bookkeeping shared by both servers (DESIGN.md
    §7.4) — now a thin view over registry primitives: the latency
    histogram, served/dropped counters, and the busy window, emitting the
    same ``metrics()`` dict shape as ever.  The busy window uses the
    owner's (injectable) clock — under a fake clock, throughput reports
    simulated time, the same domain as the latency percentiles.

    ``registry`` defaults to a **private** :class:`MetricsRegistry` per
    instance — two servers in one process must not sum each other's
    ``served`` — exposed as ``.registry`` so callers can read the series
    (``serve.latency_s``, ``serve.bucket_size``, ...) directly.  The
    process registry keeps the runtime-wide series (autotune, retraces,
    arena bytes) that *are* shared."""

    def __init__(self, clock: Callable[[], float] = time.monotonic,
                 registry: MetricsRegistry | None = None,
                 prefix: str = "serve"):
        self._clock = clock
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._lat = self.registry.histogram(f"{prefix}.latency_s")
        self._served = self.registry.counter(f"{prefix}.served")
        self._dropped = self.registry.counter(f"{prefix}.dropped")
        self._buckets = self.registry.histogram(f"{prefix}.bucket_size")
        # Resilience series (DESIGN.md §11): retries, terminal errors,
        # admission rejections, and backend demotions.
        self._retries = self.registry.counter(f"{prefix}.retries")
        self._errors = self.registry.counter(f"{prefix}.errors")
        self._rejected = self.registry.counter(f"{prefix}.rejected")
        self._degraded = self.registry.counter(f"{prefix}.degraded")
        self._t_first: float | None = None
        self._t_last: float | None = None

    @property
    def latencies(self) -> list[float]:
        return self._lat.samples

    @property
    def served(self) -> int:
        return self._served.value

    def mark_dispatch(self, bucket: int | None = None) -> None:
        """First device work entered flight: the busy window opens.
        ``bucket`` (when known) feeds the per-bucket dispatch histogram."""
        if bucket is not None:
            self._buckets.observe(bucket)
        if self._t_first is None:
            self._t_first = self._clock()

    def record(self, latencies: list[float]) -> None:
        """A batch of requests completed with these submit→done times."""
        self._lat.observe_many(latencies)
        self._served.inc(len(latencies))
        self._t_last = self._clock()

    def record_dropped(self, n: int = 1) -> None:
        self._dropped.inc(n)

    def record_retry(self, n: int = 1) -> None:
        self._retries.inc(n)

    def record_error(self, n: int = 1) -> None:
        self._errors.inc(n)

    def record_rejected(self, n: int = 1) -> None:
        self._rejected.inc(n)

    def record_degraded(self, n: int = 1) -> None:
        self._degraded.inc(n)

    def snapshot(self, *, dropped: int, queue_depth: int,
                 **extra) -> dict:
        lat = sorted(self.latencies)
        busy = (self._t_last - self._t_first
                if self._t_first is not None and self._t_last is not None
                else None)
        return {
            "served": self.served,
            "dropped": dropped,
            "retries": self._retries.value,
            "errors": self._errors.value,
            "rejected": self._rejected.value,
            "degraded": self._degraded.value,
            "queue_depth": queue_depth,
            "p50_ms": None if not lat else percentile(lat, 0.50) * 1e3,
            "p95_ms": None if not lat else percentile(lat, 0.95) * 1e3,
            "throughput": (self.served / busy if busy else None),
            **extra,
        }
