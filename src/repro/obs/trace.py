"""Span tracing with Chrome trace-event export (DESIGN.md §10.1).

One process-wide :class:`Tracer` behind a module-level slot.  Tracing is
**disabled by default**: while the slot is ``None``, :func:`span` returns
a shared no-op context manager without allocating anything — the hot-path
cost of a disabled tracer is one global read and one ``is None`` test.
Nothing here ever runs *inside* a jit closure, so enabling or disabling
tracing can never change ``trace_count`` (pinned by
``tests/test_obs.py``).

Spans are explicit scopes::

    from repro.obs import trace

    tracer = trace.install()            # tracing on
    with trace.span("serve.dispatch", "serve", bucket=4):
        ...
    tracer.export("trace.json")         # chrome://tracing / Perfetto
    trace.uninstall()                   # tracing off again

The export is the Chrome trace-event format (``ph: "X"`` complete events
with ``ts``/``dur`` in microseconds, ``ph: "i"`` instants), loadable in
``chrome://tracing`` and Perfetto.  :func:`validate_trace` is the schema
check shared by the tests, the example, and CI's obs-smoke job.

Span taxonomy (full table in DESIGN.md §10.1): ``serve.*`` for the
request path, ``node.*``/``region.*`` for per-node executor execution,
``compile.*`` for bucket compilation, ``autotune.*`` for sweeps.

``Tracer(annotate_jax=True)`` additionally enters a
``jax.profiler.TraceAnnotation`` per span so host spans line up with
device events when a ``jax.profiler`` session is active;
:meth:`Tracer.start_jax_profiler` / :meth:`Tracer.stop_jax_profiler`
manage such a session (best-effort — absent profiler support is not an
error).
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable


class _NullSpan:
    """The disabled-tracing span: a shared, stateless context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()

# The process tracer.  ``None`` means disabled — the fast path the serving
# loop and executor read directly (one attribute load per call site).
_TRACER: "Tracer | None" = None


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> "Tracer | None":
    return _TRACER


def install(tracer: "Tracer | None" = None) -> "Tracer":
    """Install (and return) the process tracer; tracing is on after this."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer()
    return _TRACER


def uninstall() -> "Tracer | None":
    """Disable tracing; returns the tracer that was installed (if any)."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def span(name: str, kind: str = "host", **attrs) -> Any:
    """A span scope on the installed tracer — or the shared no-op when
    tracing is disabled (the zero-overhead path)."""
    t = _TRACER
    if t is None:
        return NULL_SPAN
    return t.span(name, kind, **attrs)


def instant(name: str, kind: str = "host", **attrs) -> None:
    """A zero-duration marker event (no-op when disabled)."""
    t = _TRACER
    if t is not None:
        t.instant(name, kind, **attrs)


class Span:
    """One open scope; appends a complete ('X') event on exit."""

    __slots__ = ("_tracer", "name", "kind", "attrs", "_t0", "_ann")

    def __init__(self, tracer: "Tracer", name: str, kind: str,
                 attrs: dict):
        self._tracer = tracer
        self.name = name
        self.kind = kind
        self.attrs = attrs
        self._t0 = 0.0
        self._ann = None

    def set(self, **attrs) -> "Span":
        """Attach attrs discovered mid-span (output shapes, counts)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        if self._tracer.annotate_jax:
            try:
                import jax

                self._ann = jax.profiler.TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:
                self._ann = None
        self._t0 = self._tracer.clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer.clock()
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tracer._emit_complete(self.name, self.kind, self._t0, t1,
                                    self.attrs)
        return False


class Tracer:
    """Collects span/instant events; exports Chrome trace-event JSON.

    ``max_events`` bounds memory on long runs: past it, new events are
    counted in ``dropped_events`` instead of stored (the flight recorder
    is the postmortem surface for long-running servers; traces are for
    bounded captures).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 max_events: int = 200_000, annotate_jax: bool = False,
                 pid: int = 0):
        self.clock = clock
        self.max_events = max_events
        self.annotate_jax = annotate_jax
        self.pid = pid
        self.events: list[dict] = []
        self.dropped_events = 0
        self._epoch = clock()

    # ---- recording --------------------------------------------------------
    def span(self, name: str, kind: str = "host", **attrs) -> Span:
        return Span(self, name, kind, attrs)

    def instant(self, name: str, kind: str = "host", **attrs) -> None:
        ts = (self.clock() - self._epoch) * 1e6
        self._append({"ph": "i", "name": name, "cat": kind,
                      "ts": ts, "s": "t", "pid": self.pid,
                      "tid": threading.get_ident() & 0xFFFF,
                      "args": attrs})

    def _emit_complete(self, name: str, kind: str, t0: float, t1: float,
                       attrs: dict) -> None:
        self._append({"ph": "X", "name": name, "cat": kind,
                      "ts": (t0 - self._epoch) * 1e6,
                      "dur": max((t1 - t0) * 1e6, 0.0),
                      "pid": self.pid,
                      "tid": threading.get_ident() & 0xFFFF,
                      "args": attrs})

    def _append(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped_events += 1
            return
        self.events.append(ev)

    # ---- queries ----------------------------------------------------------
    def spans(self, prefix: str = "") -> list[dict]:
        """Complete ('X') events, optionally filtered by name prefix."""
        return [e for e in self.events
                if e["ph"] == "X" and e["name"].startswith(prefix)]

    # ---- jax.profiler session (optional) ----------------------------------
    def start_jax_profiler(self, logdir: str) -> bool:
        """Start a ``jax.profiler`` trace session alongside host spans
        (best-effort; returns whether it started)."""
        try:
            import jax

            jax.profiler.start_trace(logdir)
            return True
        except Exception:
            return False

    def stop_jax_profiler(self) -> None:
        try:
            import jax

            jax.profiler.stop_trace()
        except Exception:
            pass

    # ---- export -----------------------------------------------------------
    def to_chrome(self, meta: dict | None = None) -> dict:
        """The Chrome trace-event document (sorted by ts for viewers that
        care), stamped with provenance metadata."""
        if meta is None:
            from repro.obs.provenance import provenance_meta

            meta = provenance_meta()
        events = sorted(self.events, key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "metadata": dict(meta, dropped_events=self.dropped_events)}

    def export(self, path: str, meta: dict | None = None) -> dict:
        doc = self.to_chrome(meta)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc


def validate_trace(doc: dict | list) -> list[dict]:
    """Minimal schema check for an exported trace (shared by tests, the
    example, and CI's obs-smoke job): every complete event carries
    name/ts/dur, and complete events on one (pid, tid) track properly
    nest — any two either are disjoint or one contains the other.
    Returns the complete events; raises ``ValueError`` on violation."""
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    complete = []
    for e in events:
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"event without a name: {e!r}")
        if e.get("ph") == "X":
            if not isinstance(e.get("ts"), (int, float)):
                raise ValueError(f"span without ts: {e['name']}")
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                raise ValueError(f"span without dur: {e['name']}")
            complete.append(e)
    by_track: dict[tuple, list[dict]] = {}
    for e in complete:
        by_track.setdefault((e.get("pid"), e.get("tid")), []).append(e)
    for track in by_track.values():
        track.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: list[tuple[float, float]] = []
        for e in track:
            t0, t1 = e["ts"], e["ts"] + e["dur"]
            while stack and t0 >= stack[-1][1]:
                stack.pop()
            if stack and t1 > stack[-1][1] + 1e-6:
                raise ValueError(
                    f"span {e['name']!r} [{t0}, {t1}] overlaps its "
                    f"enclosing span {stack[-1]} without nesting")
            stack.append((t0, t1))
    return complete
