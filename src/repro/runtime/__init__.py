"""Graph runtime: operator IR, optimization passes, memory planning, and a
multi-backend autotuned executor (DESIGN.md §4).

The engine-level half of the PhoneBit reproduction: where ``repro.core``
provides the kernels and the offline parameter transform, this package
provides the *framework* that composes them — the difference §V-C of the
paper (and daBNN/CNNdroid before it) draws between a fast kernel and a
fast engine.

    graph      operator IR (explicit-edge DAG) + lowering from LayerSpec
               sequences, converter artifacts, and trained float params
    passes     layout assignment, conv+BN+binarize integration (Eqns 5-9),
               epilogue fusion, OR-pool absorption — as testable rewrites
    memory     static lifetime analysis + arena planning (peak_bytes)
    executor   jit-compiled topological evaluator, per-node backends
    autotune   times backend candidates per node, caches winners
"""

from repro.runtime.autotune import (Autotuner, cache_path,
                                    default_candidates)
from repro.runtime.executor import (BACKENDS, GraphExecutor,
                                    valid_backends)
from repro.runtime.graph import (DISPATCHABLE_OPS, Graph, Node, TensorType,
                                 infer_types, lower_packed, lower_trained)
from repro.runtime.memory import MemoryPlan, plan_memory
from repro.runtime.passes import (absorb_pools, assign_layouts,
                                  default_pipeline, fuse_epilogues,
                                  fuse_pool_epilogue, integrate_bn)

__all__ = [
    "Autotuner", "BACKENDS", "DISPATCHABLE_OPS", "Graph", "GraphExecutor",
    "MemoryPlan", "Node", "TensorType", "absorb_pools", "assign_layouts",
    "cache_path", "default_candidates", "default_pipeline",
    "fuse_epilogues", "fuse_pool_epilogue", "infer_types", "integrate_bn",
    "lower_packed", "lower_trained", "plan_memory", "valid_backends",
]
