"""Graph runtime: operator IR, optimization passes, memory planning, and a
multi-backend autotuned executor (DESIGN.md §4).

The engine-level half of the PhoneBit reproduction: where ``repro.core``
provides the kernels and the offline parameter transform, this package
provides the *framework* that composes them — the difference §V-C of the
paper (and daBNN/CNNdroid before it) draws between a fast kernel and a
fast engine.

    graph      operator IR (explicit-edge DAG) + lowering from LayerSpec
               sequences, converter artifacts, and trained float params
    passes     layout assignment, conv+BN+binarize integration (Eqns 5-9),
               epilogue fusion, OR-pool absorption — as testable rewrites
    memory     static lifetime analysis + arena planning (peak_bytes)
    executor   jit-compiled topological evaluator, per-node backends
    autotune   times backend candidates per node/chain, caches winners
    regions    chain-fusion region formation: runs of packed ops fused
               into single megakernel calls with VMEM-resident
               intermediates at planner offsets (DESIGN.md §9)
    placement  multi-device placement: pipeline cut candidates at HBM
               touch points, cost-balanced stage planning, and the
               staged per-device executor (DESIGN.md §13)
"""

from repro.runtime.autotune import (Autotuner, cache_path,
                                    default_candidates)
from repro.runtime.executor import (ALL_MODES, BACKENDS, CHAIN_BACKEND,
                                    GraphExecutor, valid_backends)
from repro.runtime.graph import (DISPATCHABLE_OPS, Graph, Node, TensorType,
                                 infer_types, lower_packed, lower_trained)
from repro.runtime.memory import MemoryPlan, VmemPlan, plan_memory, vmem_plan
from repro.runtime.passes import (absorb_pools, assign_layouts,
                                  default_pipeline, fuse_epilogues,
                                  fuse_pool_epilogue, integrate_bn)
from repro.runtime.placement import (StagedExecutor, StagePlan,
                                     cut_candidates, plan_pipeline,
                                     stage_subgraph, staged_executor)
from repro.runtime.regions import (Chain, build_chain, chain_executor,
                                   chain_report, partition_chains)

__all__ = [
    "ALL_MODES", "Autotuner", "BACKENDS", "CHAIN_BACKEND", "Chain",
    "DISPATCHABLE_OPS", "Graph", "GraphExecutor", "MemoryPlan", "Node",
    "StagePlan", "StagedExecutor", "TensorType", "VmemPlan",
    "absorb_pools", "assign_layouts", "build_chain", "cache_path",
    "chain_executor", "chain_report", "cut_candidates",
    "default_candidates", "default_pipeline", "fuse_epilogues",
    "fuse_pool_epilogue", "infer_types", "integrate_bn", "lower_packed",
    "lower_trained", "partition_chains", "plan_memory", "plan_pipeline",
    "stage_subgraph", "staged_executor", "valid_backends", "vmem_plan",
]
