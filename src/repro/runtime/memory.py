"""Static memory planner for scheduled graphs (DESIGN.md §4.4).

PhoneBit's §VI is about never touching more memory than necessary (layer
integration avoids materializing intermediates; packed layouts shrink what
is materialized 32×).  At the graph level the same discipline becomes a
*static* plan: with the schedule fixed (our deterministic topological
order) every intermediate buffer has a known byte size (shape inference)
and a known lifetime [birth, last-use], so buffers whose lifetimes do not
overlap can share arena space.

:func:`plan_memory` computes lifetimes and assigns every intermediate an
offset in a single arena via lifetime-aware first-fit.  ``peak_bytes()``
(the arena size) is the number the serving stack budgets against;
``naive_bytes()`` is the no-reuse sum — the gap between them is the
planner's win, reported per-node by ``report()`` for the benchmarks.

On the XLA per-node path the plan is advisory (XLA does its own buffer
assignment).  On the chain-fusion path (:mod:`repro.runtime.regions`,
DESIGN.md §9) it is *load-bearing*: :func:`vmem_plan` runs the same
lifetime-aware first-fit over a chain's interior intermediates, and the
resulting offsets are the addresses at which the megakernel
(:mod:`repro.kernels.chain_conv`) stores and reloads each stage inside
its VMEM scratch arena.  The test suite checks the shared invariant:
no two overlapping-lifetime buffers may overlap in the arena.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.runtime.graph import Graph, TensorType, infer_types

_ALIGN = 128  # bytes; one VREG lane row of int32


def _align(n: int) -> int:
    return -(-n // _ALIGN) * _ALIGN


@dataclasses.dataclass(frozen=True)
class BufferPlan:
    node_id: int
    op: str
    shape: tuple[int, ...]
    nbytes: int          # aligned size reserved in the arena
    offset: int          # arena offset
    birth: int           # schedule index of the producing node
    death: int           # schedule index of the last consumer


@dataclasses.dataclass
class MemoryPlan:
    schedule: list[int]
    buffers: dict[int, BufferPlan]
    arena_bytes: int

    def peak_bytes(self) -> int:
        """Arena size: peak intermediate memory under slot reuse."""
        return self.arena_bytes

    def naive_bytes(self) -> int:
        """Sum of all intermediate buffers (the no-reuse baseline)."""
        return sum(b.nbytes for b in self.buffers.values())

    def live_peak_bytes(self) -> int:
        """Lower bound: max over schedule steps of live-buffer bytes."""
        peak = 0
        for t in range(len(self.schedule)):
            live = sum(b.nbytes for b in self.buffers.values()
                       if b.birth <= t <= b.death)
            peak = max(peak, live)
        return peak

    def report(self) -> list[dict]:
        rows = []
        for b in sorted(self.buffers.values(), key=lambda b: b.birth):
            rows.append(dict(node=b.node_id, op=b.op,
                             shape="x".join(map(str, b.shape)),
                             bytes=b.nbytes, offset=b.offset,
                             birth=b.birth, death=b.death))
        return rows


def _first_fit(intervals: list[tuple[int, int, int, int]]
               ) -> tuple[dict[int, int], int]:
    """Lifetime-aware first-fit over ``(birth, death, size, key)`` rows:
    place each buffer at the lowest offset that does not collide with an
    already-placed buffer of overlapping lifetime.  Returns
    ``(offsets_by_key, arena_size)``."""
    placed: list[tuple[int, int, int, int]] = []  # (offset, size, birth, death)
    offsets: dict[int, int] = {}
    arena = 0
    for birth, death, size, key in sorted(intervals):
        overlapping = sorted(
            (off, sz) for off, sz, b2, d2 in placed
            if not (d2 < birth or b2 > death))
        offset = 0
        for off, sz in overlapping:
            if offset + size <= off:
                break
            offset = max(offset, off + sz)
        placed.append((offset, size, birth, death))
        offsets[key] = offset
        arena = max(arena, offset + size)
    return offsets, arena


def plan_memory(graph: Graph, input_shape: tuple[int, ...],
                types: dict[int, TensorType] | None = None) -> MemoryPlan:
    """Lifetime analysis + first-fit arena assignment over the schedule.

    The graph input and output are excluded from the arena (they are owned
    by the caller and must survive the whole call); every other node output
    is an intermediate eligible for reuse.
    """
    types = types if types is not None else infer_types(graph, input_shape)
    schedule = graph.topo_order()
    pos = {nid: t for t, nid in enumerate(schedule)}
    cons = graph.consumers()

    intervals: list[tuple[int, int, int, int]] = []  # (birth, death, size, id)
    for nid in schedule:
        if nid in (graph.input_id, graph.output_id):
            continue
        users = cons[nid]
        death = max((pos[u] for u in users), default=pos[nid])
        intervals.append((pos[nid], death, _align(types[nid].nbytes), nid))

    offsets, arena = _first_fit(intervals)
    buffers = {
        nid: BufferPlan(node_id=nid, op=graph.nodes[nid].op,
                        shape=types[nid].shape, nbytes=size,
                        offset=offsets[nid], birth=birth, death=death)
        for birth, death, size, nid in intervals
    }
    return MemoryPlan(schedule=schedule, buffers=buffers, arena_bytes=arena)


# --------------------------------------------------------------------------
# Per-chain VMEM arena planning (DESIGN.md §9)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VmemPlan:
    """The VMEM scratch-arena plan for one fused chain.

    ``offsets``/``arena_bytes`` describe only the chain's *interior*
    intermediates (one per stage boundary, in chain order); the kernel's
    other VMEM residents — entry tile, weights, final tile, accumulator —
    are summed into ``fixed_bytes`` and count against the budget but live
    outside the planned arena (Pallas allocates them as operand blocks).
    """
    offsets: tuple[int, ...]     # byte offset per interior intermediate
    sizes: tuple[int, ...]       # aligned byte size per intermediate
    arena_bytes: int             # planned arena extent (0 when no interior)
    fixed_bytes: int             # non-arena VMEM the chain also occupies
    budget: int | None           # byte budget this plan was checked against

    def total_bytes(self) -> int:
        return self.arena_bytes + self.fixed_bytes

    def fits(self) -> bool:
        return self.budget is None or self.total_bytes() <= self.budget

    def naive_bytes(self) -> int:
        """No-reuse sum of the interior intermediates."""
        return sum(self.sizes)


def vmem_plan(sizes: Sequence[int], *, budget: int | None = None,
              fixed_bytes: int = 0) -> VmemPlan:
    """Plan one chain's VMEM scratch arena (the per-chain planning mode).

    ``sizes[i]`` is the byte size of the chain's i-th interior
    intermediate — stage i's output tile, produced at chain step i and
    consumed at step i+1.  Lifetimes are therefore ``[i, i+1]``, and the
    same lifetime-aware first-fit used for the HBM arena assigns offsets:
    with three or more stages, buffers i and i+2 ping-pong into shared
    space.  The returned offsets are what
    :mod:`repro.kernels.chain_conv` uses to address its flat VMEM
    scratch; ``fits()`` is the region-formation gate
    (:mod:`repro.runtime.regions` splits chains whose plan exceeds the
    budget, spilling the cut boundary to HBM).
    """
    intervals = [(i, i + 1, _align(sz), i) for i, sz in enumerate(sizes)]
    offsets, arena = _first_fit(intervals)
    return VmemPlan(
        offsets=tuple(offsets[i] for i in range(len(sizes))),
        sizes=tuple(_align(sz) for sz in sizes),
        arena_bytes=arena, fixed_bytes=fixed_bytes, budget=budget)
