"""Static memory planner for scheduled graphs (DESIGN.md §4.4).

PhoneBit's §VI is about never touching more memory than necessary (layer
integration avoids materializing intermediates; packed layouts shrink what
is materialized 32×).  At the graph level the same discipline becomes a
*static* plan: with the schedule fixed (our deterministic topological
order) every intermediate buffer has a known byte size (shape inference)
and a known lifetime [birth, last-use], so buffers whose lifetimes do not
overlap can share arena space.

:func:`plan_memory` computes lifetimes and assigns every intermediate an
offset in a single arena via lifetime-aware first-fit.  ``peak_bytes()``
(the arena size) is the number the serving stack budgets against;
``naive_bytes()`` is the no-reuse sum — the gap between them is the
planner's win, reported per-node by ``report()`` for the benchmarks.

The plan is *advisory* on the XLA path (XLA does its own buffer
assignment); it is the contract a future donation/buffer-aliasing executor
and the roofline model consume, and the test suite checks its invariant:
no two overlapping-lifetime buffers may overlap in the arena.
"""

from __future__ import annotations

import dataclasses

from repro.runtime.graph import Graph, TensorType, infer_types

_ALIGN = 128  # bytes; one VREG lane row of int32


def _align(n: int) -> int:
    return -(-n // _ALIGN) * _ALIGN


@dataclasses.dataclass(frozen=True)
class BufferPlan:
    node_id: int
    op: str
    shape: tuple[int, ...]
    nbytes: int          # aligned size reserved in the arena
    offset: int          # arena offset
    birth: int           # schedule index of the producing node
    death: int           # schedule index of the last consumer


@dataclasses.dataclass
class MemoryPlan:
    schedule: list[int]
    buffers: dict[int, BufferPlan]
    arena_bytes: int

    def peak_bytes(self) -> int:
        """Arena size: peak intermediate memory under slot reuse."""
        return self.arena_bytes

    def naive_bytes(self) -> int:
        """Sum of all intermediate buffers (the no-reuse baseline)."""
        return sum(b.nbytes for b in self.buffers.values())

    def live_peak_bytes(self) -> int:
        """Lower bound: max over schedule steps of live-buffer bytes."""
        peak = 0
        for t in range(len(self.schedule)):
            live = sum(b.nbytes for b in self.buffers.values()
                       if b.birth <= t <= b.death)
            peak = max(peak, live)
        return peak

    def report(self) -> list[dict]:
        rows = []
        for b in sorted(self.buffers.values(), key=lambda b: b.birth):
            rows.append(dict(node=b.node_id, op=b.op,
                             shape="x".join(map(str, b.shape)),
                             bytes=b.nbytes, offset=b.offset,
                             birth=b.birth, death=b.death))
        return rows


def plan_memory(graph: Graph, input_shape: tuple[int, ...],
                types: dict[int, TensorType] | None = None) -> MemoryPlan:
    """Lifetime analysis + first-fit arena assignment over the schedule.

    The graph input and output are excluded from the arena (they are owned
    by the caller and must survive the whole call); every other node output
    is an intermediate eligible for reuse.
    """
    types = types if types is not None else infer_types(graph, input_shape)
    schedule = graph.topo_order()
    pos = {nid: t for t, nid in enumerate(schedule)}
    cons = graph.consumers()

    intervals: list[tuple[int, int, int, int]] = []  # (birth, death, size, id)
    for nid in schedule:
        if nid in (graph.input_id, graph.output_id):
            continue
        users = cons[nid]
        death = max((pos[u] for u in users), default=pos[nid])
        intervals.append((pos[nid], death, _align(types[nid].nbytes), nid))

    # First-fit by birth order: place each buffer at the lowest offset that
    # does not collide with an already-placed buffer of overlapping lifetime.
    placed: list[tuple[int, int, int, int]] = []  # (offset, size, birth, death)
    offsets: dict[int, int] = {}
    arena = 0
    for birth, death, size, nid in sorted(intervals):
        overlapping = sorted(
            (off, sz) for off, sz, b2, d2 in placed
            if not (d2 < birth or b2 > death))
        offset = 0
        for off, sz in overlapping:
            if offset + size <= off:
                break
            offset = max(offset, off + sz)
        placed.append((offset, size, birth, death))
        offsets[nid] = offset
        arena = max(arena, offset + size)

    buffers = {
        nid: BufferPlan(node_id=nid, op=graph.nodes[nid].op,
                        shape=types[nid].shape, nbytes=size,
                        offset=offsets[nid], birth=birth, death=death)
        for birth, death, size, nid in intervals
    }
    return MemoryPlan(schedule=schedule, buffers=buffers, arena_bytes=arena)
