"""Chain-fusion region formation (DESIGN.md §9).

Partitions a scheduled graph into maximal *chains* — linear runs of
consecutive packed ops (``packed_conv`` / ``packed_conv_pool`` /
``or_pool`` / ``maxpool_pm1``-on-packed) — that the executor's
``vpu_chain`` backend lowers into a **single Pallas call** whose
intermediates live in a VMEM scratch arena at planner-assigned offsets
(:mod:`repro.kernels.chain_conv`).  Only each chain's entry and exit touch
HBM; everything between runs at VMEM bandwidth with zero kernel-dispatch
boundaries, which is the paper's layers-integration discipline applied
*across* layers instead of within one.

Region-formation rules (§9.1):

* ops must be chainable (the set above; ``maxpool_pm1`` qualifies only
  when its input is already packed — then it is exactly an OR-pool, the
  same rewrite :func:`~repro.runtime.passes.absorb_pools` performs);
* the run must be a pure path: every non-tail member has exactly one
  consumer, the next member (fan-out forces a chain break — the branching
  value must be materialized);
* the chain's VMEM plan must fit the budget
  (:func:`~repro.runtime.memory.vmem_plan`): interior tile intermediates
  under lifetime first-fit reuse, plus the fixed residents (entry tile,
  weights, final tile, popcount accumulator).  A run that exceeds the
  budget is split greedily — the longest fitting prefix becomes a region
  and the cut boundary spills to HBM;
* runs shorter than ``min_nodes`` (default 2) stay on the per-node path.

Chains that fail any rule simply do not form; the executor evaluates
those nodes per-node with its normal backend fallback — there is no
error path, only a smaller fused region.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax.numpy as jnp

from repro.core.packing import num_words
from repro.kernels.chain_conv import (StageSpec, chain_geometry,
                                      chain_word_counts)
from repro.runtime.graph import (PACKED_OPS, Graph, TensorType, infer_types)
from repro.runtime.memory import VmemPlan, vmem_plan

# Per-core VMEM is ~16 MiB on current TPUs; default to half so the chain
# arena coexists with Pallas' double-buffered entry/exit blocks.
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20

CHAIN_OPS = frozenset({"packed_conv", "packed_conv_pool", "or_pool",
                       "maxpool_pm1"})


def node_stages(node) -> tuple[StageSpec, ...]:
    """Lower one graph node to its kernel stage(s).  ``packed_conv_pool``
    decomposes into conv + pool stages — inside a chain the conv output
    goes to the VMEM arena either way, so the decomposition loses
    nothing and keeps the kernel walk uniform."""
    a = node.attrs
    if node.op in ("packed_conv", "packed_conv_pool"):
        stages = [StageSpec("conv", kernel=a["kernel"], stride=a["stride"],
                            pad_lo=a["pad"], pad_hi=a["pad"],
                            channels=a["channels"],
                            first=bool(a.get("first")))]
        if node.op == "packed_conv_pool":
            plo, phi = tuple(a.get("pool_pad", (0, 0)))
            stages.append(StageSpec("pool", kernel=a["pool_window"],
                                    stride=a["pool_stride"],
                                    pad_lo=plo, pad_hi=phi,
                                    channels=a["channels"]))
        return tuple(stages)
    if node.op in ("or_pool", "maxpool_pm1"):
        plo, phi = tuple(a.get("pad", (0, 0)))
        return (StageSpec("pool", kernel=a["window"], stride=a["stride"],
                          pad_lo=plo, pad_hi=phi,
                          channels=a.get("channels") or 0),)
    raise ValueError(f"op {node.op!r} is not chainable")


@dataclasses.dataclass
class Chain:
    """One fused region: schedule-ordered member nodes, their static
    kernel stages, the head's input shape, the VMEM plan at the default
    tile, and the (autotunable) tile config."""
    node_ids: tuple[int, ...]
    stages: tuple[StageSpec, ...]
    in_shape: tuple[int, ...]
    plan: VmemPlan
    tile: dict = dataclasses.field(default_factory=dict)

    @property
    def head(self) -> int:
        return self.node_ids[0]

    @property
    def tail(self) -> int:
        return self.node_ids[-1]

    def arena(self, tile: Mapping[str, int] | None = None
              ) -> tuple[tuple[int, ...], int]:
        """(int32-element offsets per interior stage output, arena words)
        for a concrete tile config — recomputed because tile shape changes
        the interior sizes the planner packs."""
        plan = plan_chain_vmem(self.stages, self.in_shape,
                               tile=dict(tile if tile is not None
                                         else self.tile))
        return (tuple(o // 4 for o in plan.offsets), plan.arena_bytes // 4)

    def hbm_bytes_avoided(self) -> int:
        """Whole-map HBM traffic the fusion removes: one store + one load
        per interior stage boundary (vs the per-node ``vpu_direct`` path,
        which round-trips every boundary — including the conv→pool
        boundary inside ``packed_conv_pool`` — through HBM)."""
        return stages_hbm_bytes_avoided(self.stages, self.in_shape)

    def signature_key(self) -> tuple:
        """Shape/op identity for autotune persistence (chain-shaped
        signatures; see :mod:`repro.runtime.autotune`)."""
        return (tuple(dataclasses.astuple(st) for st in self.stages),
                tuple(self.in_shape))


def stages_hbm_bytes_avoided(stages: Sequence[StageSpec],
                             in_shape: Sequence[int]) -> int:
    """One store + one load of every interior stage output at full-map
    size — the boundary traffic a fused chain never issues.  Shared by
    :meth:`Chain.hbm_bytes_avoided` and the kernel benchmark so the two
    reports can never diverge."""
    n, h, w = in_shape[0], in_shape[1], in_shape[2]
    cws = chain_word_counts(tuple(stages), in_shape[3])
    total = 0
    for k, st in enumerate(stages[:-1]):
        h, w = st.out_size(h), st.out_size(w)
        total += 2 * n * h * w * cws[k + 1] * 4
    return total


def plan_chain_vmem(stages: Sequence[StageSpec], in_shape: Sequence[int],
                    *, tile: Mapping[str, int] | None = None,
                    budget: int | None = None) -> VmemPlan:
    """The VMEM plan for one chain at one tile config: interior stage
    tiles (lifetime [k, k+1]) go through the planner's first-fit; the
    fixed residents (entry tile, conv weights, final tile, widest popcount
    accumulator) are summed into ``fixed_bytes`` for the budget check."""
    tile = dict(tile or {})
    n, h, w, cw0 = in_shape
    bn = max(1, min(tile.get("block_n", 1), n))
    geo = chain_geometry(tuple(stages), h, w, tile.get("block_h"),
                         tile.get("block_w"))
    cws = chain_word_counts(tuple(stages), cw0)

    sizes = [4 * bn * th * tw * cws[k + 1]
             for k, (th, tw) in enumerate(geo.out_tile[:-1])]
    fixed = 4 * bn * geo.entry_tile[0] * geo.entry_tile[1] * cw0
    fh, fw = geo.out_tile[-1]
    fixed += 4 * bn * fh * fw * cws[-1]
    acc = 0
    for k, st in enumerate(stages):
        if st.kind != "conv":
            continue
        o_pad = num_words(st.channels) * 32
        taps = st.kernel * st.kernel * cws[k]
        fixed += 4 * (o_pad * taps + taps + 2 * o_pad)       # w, ww, t, s
        th, tw = geo.out_tile[k]
        acc = max(acc, 4 * bn * th * tw * o_pad)             # accumulator
    return vmem_plan(sizes, budget=budget, fixed_bytes=fixed + acc)


def build_chain(graph: Graph, node_ids: Sequence[int],
                input_shape: Sequence[int],
                types: Mapping[int, TensorType] | None = None,
                budget: int | None = None) -> Chain:
    """Assemble a :class:`Chain` from explicit member ids (must be a valid
    path of chainable ops).  Public so tests can split chains at arbitrary
    boundaries."""
    types = types if types is not None else infer_types(
        graph, tuple(input_shape))
    node_ids = tuple(node_ids)
    stages: list[StageSpec] = []
    for nid in node_ids:
        stages.extend(node_stages(graph.nodes[nid]))
    in_shape = types[graph.nodes[node_ids[0]].inputs[0]].shape
    plan = plan_chain_vmem(stages, in_shape, budget=budget)
    return Chain(node_ids=node_ids, stages=tuple(stages),
                 in_shape=in_shape, plan=plan)


def _chainable(graph: Graph, nid: int) -> bool:
    node = graph.nodes[nid]
    if node.op not in CHAIN_OPS:
        return False
    if node.op == "maxpool_pm1":
        # Only exactly an OR-pool when the input is already packed words.
        prod = graph.nodes[node.inputs[0]]
        if prod.op not in PACKED_OPS:
            return False
    return True


def partition_chains(graph: Graph, input_shape: Sequence[int],
                     *, vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                     min_nodes: int = 2,
                     types: Mapping[int, TensorType] | None = None
                     ) -> list[Chain]:
    """Partition the schedule into maximal budget-fitting chains."""
    types = types if types is not None else infer_types(
        graph, tuple(input_shape))
    cons = graph.consumers()
    schedule = graph.topo_order()
    used: set[int] = set()
    runs: list[list[int]] = []
    for nid in schedule:
        if nid in used or not _chainable(graph, nid):
            continue
        run = [nid]
        used.add(nid)
        cur = nid
        while True:
            users = cons[cur]
            if len(users) != 1:
                break
            nxt = users[0]
            if (nxt in used or not _chainable(graph, nxt)
                    or graph.nodes[nxt].inputs != (cur,)):
                break
            run.append(nxt)
            used.add(nxt)
            cur = nxt
        runs.append(run)

    chains: list[Chain] = []
    for run in runs:
        start = 0
        while start < len(run):
            # Longest prefix whose VMEM plan fits the budget.
            best = None
            for end in range(start + 1, len(run) + 1):
                cand = build_chain(graph, run[start:end], input_shape,
                                   types=types, budget=vmem_budget)
                if not cand.plan.fits():
                    break
                best = cand
            if best is None:          # even a single node busts the budget
                start += 1
                continue
            if len(best.node_ids) >= min_nodes:
                chains.append(best)
            start += len(best.node_ids)
    return chains


def chain_stage_arrays(chain: Chain, params_by_node: Mapping[str, Mapping]
                       ) -> tuple:
    """Flatten member-node params into the kernel's per-conv-stage tuple
    ``(w_packed, word_weights|None, threshold, sign_flip)``.  Looked up
    from the executor's *traced* param pytree so the arrays stay jit
    operands, never closure constants."""
    arrays: list = []
    for nid in chain.node_ids:
        p = params_by_node.get(str(nid), {})
        if "w_packed" not in p:
            continue                               # pool node: no params
        thr = p["thresh"]
        arrays += [p["w_packed"], p.get("word_weights"),
                   thr.threshold, thr.sign_flip]
    return tuple(arrays)


def eval_chain(chain: Chain, params_by_node: Mapping[str, Mapping],
               x: jnp.ndarray) -> jnp.ndarray:
    """Run one region through the megakernel (dispatch via
    :mod:`repro.kernels.ops` so interpret mode follows the platform)."""
    from repro.kernels import ops as kops

    offsets, words = chain.arena(chain.tile)
    return kops.chain_forward(
        x, chain.stages, chain_stage_arrays(chain, params_by_node),
        arena_offsets=offsets, arena_words=words, **chain.tile)


def chain_executor(graph: Graph, input_shape: Sequence[int],
                   *, vmem_budget: int | None = DEFAULT_VMEM_BUDGET,
                   tuner=None, donate_input: bool = False):
    """Build the region-fused executor: partition the schedule into
    budget-fitting chains, optionally sweep per-chain tile shapes with an
    :class:`~repro.runtime.autotune.Autotuner` (pass one on TPU; interpret
    -mode timings are validators, not contenders), and freeze everything
    into a :class:`~repro.runtime.executor.GraphExecutor` whose leftover
    per-node ops degrade along the normal fallback order."""
    from repro.runtime.executor import CHAIN_BACKEND, GraphExecutor

    chains = partition_chains(graph, input_shape, vmem_budget=vmem_budget)
    if tuner is not None:
        tuner.tune_chains(graph, chains)
    return GraphExecutor(graph, CHAIN_BACKEND, regions=chains,
                         donate_input=donate_input)


def chain_report(chains: Sequence[Chain]) -> list[dict]:
    """One row per region: members, stage count, arena plan, HBM savings."""
    rows = []
    for c in chains:
        rows.append(dict(
            nodes="+".join(map(str, c.node_ids)),
            n_stages=len(c.stages),
            in_shape="x".join(map(str, c.in_shape)),
            arena_bytes=c.plan.arena_bytes,
            vmem_bytes=c.plan.total_bytes(),
            hbm_bytes_avoided=c.hbm_bytes_avoided(),
            tile=dict(c.tile)))
    return rows
