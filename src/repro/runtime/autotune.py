"""Per-node backend + tile-shape autotuning (DESIGN.md §4.6, §5.4).

All executor backends are bit-exact, so the fastest one per node is a free
win — but the winner depends on shape: popcount formulations win when the
packed reduction dim is long relative to the matmul engine's tile economics,
±1-matmul wins for fat output dims, and the direct (im2col-free) conv
kernel wins whenever patch traffic would dominate — except for large K on
tiny spatial grids, where the im2col matmul's tiling amortizes better
(the crossover benchmarks measure this globally; here it is decided *per
node*).  For the direct backends the kernel's tile shape
``(block_h, block_w, block_n)`` is part of the search space: each backend
candidate is timed over a small shape-derived sweep and the winning tile
rides along with the winning backend.

:class:`Autotuner` times each candidate on a zero-filled input of the
node's inferred shape (timing is layout/shape-dependent, not
value-dependent — binary kernels have no data-dependent control flow) and
caches the winner under a shape/attr/device signature.  The cache is keyed
so structurally identical layers across graphs (or across engine restarts
sharing a cache dict) reuse measurements instead of re-timing, and the
resulting backend map is frozen into a new :class:`GraphExecutor` — so the
serving path never re-times or re-compiles.

Bucketed serving tunes the same graph at several batch sizes
(``PhoneBitEngine.compile`` per bucket).  A winner measured at one batch
is usually still the winner at another — the reduction geometry per
example is unchanged — *except* when the winning tile spans the batch dim
(``block_n``).  So each fresh measurement is additionally recorded under a
batch-agnostic signature (batch dim replaced by a placeholder), and a
cache miss at a new batch size first consults that record: if the winner's
tile carries no ``block_n``, it is adopted without re-timing (the entry is
marked ``reused_across_batch`` so reports can tell a measured winner from
an inherited one).  Batch-agnostic records persist to disk alongside the
exact ones under a ``batchless::`` key prefix.

Fused regions (DESIGN.md §9) get their own sweep: :meth:`tune_chains`
times the chain megakernel over per-chain tile shapes — a new search
space, since a chain tile couples every stage through halo growth — and
caches winners under ``chain::``-prefixed chain-shaped signatures (stage
specs + entry shape + device kind) in the same stores.

The cache additionally persists to disk (``~/.cache/repro/autotune.json``,
keyed by the same signatures — which embed the device kind) so repeated
engine startups skip re-timing entirely.  ``REPRO_AUTOTUNE_CACHE=0``
disables persistence; any other value overrides the cache file path.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _trace
from repro.runtime.executor import (BACKENDS, GraphExecutor, eval_node,
                                    valid_backends)
from repro.runtime.graph import DISPATCHABLE_OPS, Graph, infer_types

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_DEFAULT_CACHE = "~/.cache/repro/autotune.json"

# Default candidates: the pure-XLA formulations everywhere; the Pallas
# kernels only compete where they are compiled (on TPU) — in interpret mode
# they are validators, not contenders.
def default_candidates() -> tuple[str, ...]:
    if jax.default_backend() == "tpu":
        return BACKENDS
    return ("xla", "xla_pm1")


def cache_path() -> pathlib.Path | None:
    """Resolved on-disk cache location; None when persistence is off."""
    val = os.environ.get(_CACHE_ENV)
    if val == "0":
        return None
    if val:
        return pathlib.Path(val).expanduser()
    return pathlib.Path(_DEFAULT_CACHE).expanduser()


def _env_stamp() -> dict:
    """The provenance stamp every cache entry carries (DESIGN.md §12.3):
    jax/jaxlib versions.  The device kind is already part of the
    signature; the *toolchain* version was not — winners tuned under one
    jax silently applied under another.  Stamped at measurement time and
    checked at disk-lookup time (:func:`entry_env_ok`)."""
    try:
        import jaxlib

        jaxlib_version = jaxlib.__version__
    except Exception:                   # noqa: BLE001 — stamp best-effort
        jaxlib_version = None
    return {"jax": jax.__version__, "jaxlib": jaxlib_version}


def entry_env_ok(entry) -> bool:
    """Whether a persisted tuning entry was measured under this process's
    toolchain.  Unstamped (pre-stamp) entries are stale by definition."""
    return isinstance(entry, dict) and entry.get("env") == _env_stamp()


def _device_kind() -> str:
    """Concrete accelerator model (e.g. 'TPU v4'), not just the platform:
    tile winners tuned for one VMEM/lane geometry must not warm-start a
    different generation."""
    try:
        return f"{jax.default_backend()}:{jax.devices()[0].device_kind}"
    except (IndexError, RuntimeError):
        return jax.default_backend()


def _node_signature(node, in_shape: tuple[int, ...],
                    candidates: tuple[str, ...] = ()) -> str:
    """Stable string key: op + static attrs + shapes + candidate set +
    device kind (strings so the cache round-trips through JSON)."""
    attrs = tuple(sorted((k, v) for k, v in node.attrs.items()
                         if isinstance(v, (int, bool, str, tuple))))
    pshapes = tuple(sorted(
        (k, tuple(np.shape(v))) for k, v in node.params.items()
        if not hasattr(v, "_fields")))
    return repr((node.op, attrs, tuple(in_shape), pshapes, candidates,
                 _device_kind()))


def _agnostic_signature(node, in_shape: tuple[int, ...],
                        candidates: tuple[str, ...] = ()) -> str:
    """Batch-agnostic variant of :func:`_node_signature`: the batch dim is
    replaced by a placeholder so winners can transfer across serving
    buckets (valid unless the winning tile spans the batch — ``block_n``)."""
    return "batchless::" + _node_signature(
        node, ("B",) + tuple(in_shape[1:]), candidates)


def _out_rows(node, in_shape: tuple[int, ...]) -> int:
    """Final output rows of a conv(/pool) node — what block_h tiles."""
    from repro.core.binary_conv import conv_out_size

    a = node.attrs
    oh = conv_out_size(in_shape[1], a["kernel"], a["stride"], a["pad"])
    if node.op == "packed_conv_pool":
        pp = sum(a.get("pool_pad", (0, 0)))
        oh = (oh + pp - a["pool_window"]) // a["pool_stride"] + 1
    return max(oh, 1)


def _tile_candidates(backend: str, node,
                     in_shape: tuple[int, ...]) -> list[dict]:
    """Shape-derived (block_h, block_w, block_n) sweep for the direct
    kernels; the im2col backends have no per-node tile knobs here.
    Candidates are expressed in *effective* (clamped) tile sizes and
    deduplicated so no configuration is compiled or timed twice."""
    if backend not in ("vpu_direct", "vpu_direct_pool"):
        return [{}]
    n, fh = in_shape[0], _out_rows(node, in_shape)
    default_bh = min(8, fh)                        # the kernel's default
    cands: list[dict] = [{}]
    seen = {default_bh}
    for bh in (4, 16, fh):
        eff = min(bh, fh)
        if eff not in seen:
            seen.add(eff)
            cands.append({"block_h": eff})
    if fh > 8:
        cands.append({"block_h": default_bh, "block_w": 8})
    if n > 1:
        cands.append({"block_n": n})
    return cands


def _label(backend: str, tile: dict) -> str:
    if not tile:
        return backend
    inner = ",".join(f"{k.replace('block_', '')}{v}"
                     for k, v in sorted(tile.items()))
    return f"{backend}[{inner}]"


def _tuning_event(outcome: str, op: str, key: str, entry: dict) -> None:
    """Record one tuning decision in the process registry: an
    ``autotune.{hit,disk_hit,disk_miss,xfer_hit,miss}`` counter bump plus a
    structured ``autotune`` event carrying the signature and, for fresh
    sweeps, how many candidates were timed."""
    reg = _obs_metrics.get_registry()
    reg.counter(f"autotune.{outcome}").inc()
    reg.event("autotune", outcome=outcome, op=op, signature=key,
              sweep_size=(len(entry.get("timings_ms", {}))
                          if outcome == "miss" else 0))


def _chain_signature(chain) -> str:
    """Chain-shaped cache key: the stage-spec tuple + head input shape +
    device kind, ``chain::``-prefixed so per-node and per-chain records
    share one disk cache without colliding."""
    return "chain::" + repr((chain.signature_key(), _device_kind()))


def _chain_tile_candidates(chain) -> list[dict]:
    """Per-chain tile sweep.  Chain tiles couple the stages through halo
    growth (a smaller final tile shrinks every interior tile but raises
    the recompute overlap fraction), so the sweep is over the *final*
    tile: whole-map (no recompute — the default), a few spatial splits,
    and a batch-spanning tile.  Candidates whose VMEM plan no longer fits
    the chain's budget are dropped before timing."""
    from repro.kernels.chain_conv import chain_geometry
    from repro.runtime.regions import plan_chain_vmem

    n, h, w = chain.in_shape[0], chain.in_shape[1], chain.in_shape[2]
    fh = chain_geometry(chain.stages, h, w, None, None).final_hw[0]
    cands: list[dict] = [{}]
    seen = {fh}
    for bh in (4, 8, 16, max(1, fh // 2)):
        eff = min(bh, fh)
        if eff not in seen:
            seen.add(eff)
            cands.append({"block_h": eff})
    if n > 1:
        cands.append({"block_n": n})
    return [t for t in cands
            if plan_chain_vmem(chain.stages, chain.in_shape, tile=t,
                               budget=chain.plan.budget).fits()]


class Autotuner:
    """Times candidates once per node signature; caches winners in memory
    and (by default) on disk."""

    def __init__(self, cache: dict | None = None,
                 candidates: Iterable[str] | None = None,
                 warmup: int = 1, iters: int = 3, persist: bool = True,
                 agnostic_cache: dict | None = None):
        self.cache: dict = cache if cache is not None else {}
        # batch-agnostic winners (``batchless::`` keys), kept out of
        # ``cache`` so its per-node-signature shape stays 1:1.
        self.agnostic_cache: dict = (agnostic_cache
                                     if agnostic_cache is not None else {})
        self.candidates = tuple(candidates if candidates is not None
                                else default_candidates())
        for c in self.candidates:
            if c not in BACKENDS:
                raise ValueError(f"unknown candidate backend {c!r}")
        self.warmup = warmup
        self.iters = iters
        # persist=False forces fresh measurements and writes nothing —
        # what benchmarks use so reported timings are from *this* run.
        self.persist = persist
        self._disk: dict = self._load_disk() if persist else {}

    # ---- persistence -----------------------------------------------------
    def _load_disk(self) -> dict:
        path = cache_path()
        if path is None or not path.exists():
            return {}
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return {}

    def _save_disk(self, new_entries: dict) -> None:
        path = cache_path()
        if path is None or not new_entries or not self.persist:
            return
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            merged = dict(self._load_disk())
            merged.update(new_entries)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(merged, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
            self._disk = merged
        except OSError:
            pass  # persistence is best-effort; tuning already succeeded

    # ---- measurement -----------------------------------------------------
    def _time_node(self, node, x, backend: str, tile: dict) -> float:
        fn = jax.jit(lambda params, xx: eval_node(
            node.op, node.attrs, params, [xx], backend=backend, tile=tile))
        for _ in range(self.warmup):
            jax.block_until_ready(fn(node.params, x))
        times = []
        for _ in range(self.iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(node.params, x))
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    def _tune_node(self, node, in_shape, in_dtype) -> dict:
        x = jnp.zeros(in_shape, in_dtype)
        timings: dict[str, float] = {}
        best = (float("inf"), None, {})
        for backend in self.candidates:
            if backend not in valid_backends(node.op):
                continue
            for tile in _tile_candidates(backend, node, in_shape):
                t = self._time_node(node, x, backend, tile)
                timings[_label(backend, tile)] = t
                if t < best[0]:
                    best = (t, backend, tile)
        if best[1] is None:
            raise ValueError(
                f"no candidate in {self.candidates} applies to op "
                f"{node.op!r}; include a universal backend (e.g. 'xla')")
        return dict(winner=best[1], tile=best[2],
                    timings_ms={lbl: round(t * 1e3, 4)
                                for lbl, t in timings.items()},
                    env=_env_stamp())

    def entry(self, node, in_shape: tuple[int, ...]) -> dict | None:
        """The cached tuning record for a node signature, if any."""
        return self.cache.get(
            _node_signature(node, in_shape, self.candidates))

    def tune(self, graph: Graph, input_shape: tuple[int, ...],
             ) -> dict[int, str]:
        """Pick a backend per dispatchable node; returns the backend map.
        (:meth:`tune_with_tiles` also returns the per-node tile shapes.)"""
        return self.tune_with_tiles(graph, input_shape)[0]

    def _cross_batch_entry(self, akey: str) -> dict | None:
        """A winner measured at another batch size, if transferable.
        Disk records must also pass the toolchain stamp — a cross-batch
        winner from another jax version is as stale as an exact one."""
        entry = self.agnostic_cache.get(akey)
        if entry is None:
            disk = self._disk.get(akey)
            if disk is not None and entry_env_ok(disk):
                entry = disk
        if entry and not (entry.get("tile") or {}).get("block_n"):
            return entry
        return None

    def tune_with_tiles(self, graph: Graph, input_shape: tuple[int, ...],
                        ) -> tuple[dict[int, str], dict[int, dict]]:
        types = infer_types(graph, input_shape)
        choices: dict[int, str] = {}
        tiles: dict[int, dict] = {}
        fresh: dict[str, dict] = {}
        for nid in graph.topo_order():
            node = graph.nodes[nid]
            if node.op not in DISPATCHABLE_OPS:
                continue
            in_t = types[node.inputs[0]]
            key = _node_signature(node, in_t.shape, self.candidates)
            akey = _agnostic_signature(node, in_t.shape, self.candidates)
            if key in self.cache:
                outcome = "hit"             # warm in-memory winner
            elif key in self._disk and entry_env_ok(self._disk[key]):
                # warm start from a prior run under the same toolchain
                self.cache[key] = self._disk[key]
                outcome = "disk_hit"
            elif key in self._disk:
                # A winner exists on disk but was tuned under a different
                # (or unstamped) jax/jaxlib — re-sweep rather than trust it.
                _tuning_event("disk_miss", node.op, key, self._disk[key])
                with _trace.span("autotune.sweep", "autotune",
                                 op=node.op):
                    self.cache[key] = fresh[key] = self._tune_node(
                        node, in_t.shape, in_t.dtype)
                outcome = "miss"
            elif (xfer := self._cross_batch_entry(akey)) is not None:
                # Winner measured at another serving bucket; tile has
                # no block_n, so it transfers without re-timing.
                self.cache[key] = dict(xfer, reused_across_batch=True)
                outcome = "xfer_hit"
            else:
                with _trace.span("autotune.sweep", "autotune",
                                 op=node.op):
                    self.cache[key] = fresh[key] = self._tune_node(
                        node, in_t.shape, in_t.dtype)
                outcome = "miss"
            entry = self.cache[key]
            _tuning_event(outcome, node.op, key, entry)
            if akey not in self.agnostic_cache and \
                    not entry.get("reused_across_batch"):
                record = {k: v for k, v in entry.items()
                          if k != "reused_across_batch"}
                self.agnostic_cache[akey] = record
                if key in fresh:
                    fresh[akey] = record
            choices[nid] = entry["winner"]
            tile = entry.get("tile") or {}
            if tile:
                tiles[nid] = dict(tile)
        self._save_disk(fresh)
        return choices, tiles

    def tuned_executor(self, graph: Graph, input_shape: tuple[int, ...],
                       donate_input: bool = False) -> GraphExecutor:
        choices, tiles = self.tune_with_tiles(graph, input_shape)
        return GraphExecutor(graph, choices, tiles,
                             donate_input=donate_input)

    # ---- chain (region) tuning -------------------------------------------
    def _time_chain(self, chain, stage_arrays, x, tile: dict) -> float:
        from repro.kernels import ops as kops

        offs, words = chain.arena(tile)
        fn = jax.jit(lambda arrs, xx: kops.chain_forward(
            xx, chain.stages, arrs, arena_offsets=offs, arena_words=words,
            **tile))
        for _ in range(self.warmup):
            jax.block_until_ready(fn(stage_arrays, x))
        times = []
        for _ in range(self.iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(stage_arrays, x))
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    def _tune_chain(self, chain, graph: Graph) -> dict:
        from repro.runtime.regions import chain_stage_arrays

        arrays = chain_stage_arrays(
            chain, {str(nid): graph.nodes[nid].params
                    for nid in chain.node_ids})
        x = jnp.zeros(chain.in_shape, jnp.int32)
        timings: dict[str, float] = {}
        best = (float("inf"), {})
        for tile in _chain_tile_candidates(chain):
            t = self._time_chain(chain, arrays, x, tile)
            timings[_label("vpu_chain", tile)] = t
            if t < best[0]:
                best = (t, tile)
        return dict(winner="vpu_chain", tile=best[1],
                    timings_ms={lbl: round(t * 1e3, 4)
                                for lbl, t in timings.items()},
                    env=_env_stamp())

    def tune_chains(self, graph: Graph, chains) -> None:
        """Pick a tile shape per chain (set in place on ``chain.tile``).
        Winners cache/persist under chain-shaped ``chain::`` signatures —
        structurally identical regions across graphs or restarts reuse
        the measurement, exactly like per-node winners."""
        from repro.runtime.regions import plan_chain_vmem

        fresh: dict[str, dict] = {}
        for chain in chains:
            key = _chain_signature(chain)
            if key in self.cache:
                outcome = "hit"
            elif key in self._disk and entry_env_ok(self._disk[key]):
                self.cache[key] = self._disk[key]
                outcome = "disk_hit"
            else:
                if key in self._disk:
                    _tuning_event("disk_miss", "chain", key,
                                  self._disk[key])
                with _trace.span("autotune.sweep", "autotune", op="chain"):
                    self.cache[key] = fresh[key] = self._tune_chain(
                        chain, graph)
                outcome = "miss"
            _tuning_event(outcome, "chain", key, self.cache[key])
            tile = dict(self.cache[key].get("tile") or {})
            # The signature does not embed the VMEM budget, so a winner
            # cached under a larger budget may no longer fit this
            # chain's: re-check, and degrade to the default tile (which
            # region formation already proved fits) rather than compile
            # an over-budget arena.
            if tile and not plan_chain_vmem(chain.stages, chain.in_shape,
                                            tile=tile,
                                            budget=chain.plan.budget
                                            ).fits():
                tile = {}
            chain.tile = tile
        self._save_disk(fresh)
