"""Per-node backend autotuning (DESIGN.md §4.6).

All executor backends are bit-exact, so the fastest one per node is a free
win — but the winner depends on shape: popcount formulations win when the
packed reduction dim is long relative to the matmul engine's tile economics,
±1-matmul wins for fat output dims (the crossover benchmarks measure this
globally; here it is decided *per node*).

:class:`Autotuner` times each candidate backend on a zero-filled input of
the node's inferred shape (timing is layout/shape-dependent, not
value-dependent — binary kernels have no data-dependent control flow) and
caches the winner under a shape/attr signature.  The cache is keyed so
structurally identical layers across graphs (or across engine restarts
sharing a cache dict) reuse measurements instead of re-timing, and the
resulting backend map is frozen into a new :class:`GraphExecutor` — so the
serving path never re-times or re-compiles.
"""

from __future__ import annotations

import time
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.executor import BACKENDS, GraphExecutor, eval_node
from repro.runtime.graph import DISPATCHABLE_OPS, Graph, infer_types

# Default candidates: the pure-XLA formulations everywhere; the Pallas
# kernels only compete where they are compiled (on TPU) — in interpret mode
# they are validators, not contenders.
def default_candidates() -> tuple[str, ...]:
    if jax.default_backend() == "tpu":
        return ("xla", "xla_pm1", "mxu_pm1", "vpu_popcount")
    return ("xla", "xla_pm1")


def _node_signature(node, in_shape: tuple[int, ...],
                    candidates: tuple[str, ...] = ()) -> tuple:
    attrs = tuple(sorted((k, v) for k, v in node.attrs.items()
                         if isinstance(v, (int, bool, str, tuple))))
    pshapes = tuple(sorted(
        (k, tuple(np.shape(v))) for k, v in node.params.items()
        if not hasattr(v, "_fields")))
    return (node.op, attrs, tuple(in_shape), pshapes, candidates,
            jax.default_backend())


class Autotuner:
    """Times candidates once per node signature; caches winners."""

    def __init__(self, cache: dict | None = None,
                 candidates: Iterable[str] | None = None,
                 warmup: int = 1, iters: int = 3):
        self.cache: dict = cache if cache is not None else {}
        self.candidates = tuple(candidates if candidates is not None
                                else default_candidates())
        for c in self.candidates:
            if c not in BACKENDS:
                raise ValueError(f"unknown candidate backend {c!r}")
        self.warmup = warmup
        self.iters = iters

    # ---- measurement -----------------------------------------------------
    def _time_node(self, node, x, backend: str) -> float:
        fn = jax.jit(lambda params, xx: eval_node(
            node.op, node.attrs, params, [xx], backend=backend))
        for _ in range(self.warmup):
            jax.block_until_ready(fn(node.params, x))
        times = []
        for _ in range(self.iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(node.params, x))
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    def tune(self, graph: Graph, input_shape: tuple[int, ...],
             ) -> dict[int, str]:
        """Pick a backend per dispatchable node; returns the backend map."""
        types = infer_types(graph, input_shape)
        choices: dict[int, str] = {}
        for nid in graph.topo_order():
            node = graph.nodes[nid]
            if node.op not in DISPATCHABLE_OPS:
                continue
            in_t = types[node.inputs[0]]
            key = _node_signature(node, in_t.shape, self.candidates)
            if key not in self.cache:
                x = jnp.zeros(in_t.shape, in_t.dtype)
                timings = {b: self._time_node(node, x, b)
                           for b in self.candidates}
                self.cache[key] = dict(
                    winner=min(timings, key=timings.get),
                    timings_ms={b: round(t * 1e3, 4)
                                for b, t in timings.items()})
            choices[nid] = self.cache[key]["winner"]
        return choices

    def tuned_executor(self, graph: Graph, input_shape: tuple[int, ...]
                       ) -> GraphExecutor:
        return GraphExecutor(graph, self.tune(graph, input_shape))
