"""Topological graph executor with per-node backend dispatch (DESIGN.md §4.5).

Evaluates a :class:`~repro.runtime.graph.Graph` in its deterministic
schedule under one ``jax.jit`` closure: the graph structure, static attrs,
per-node backend choices and kernel tile shapes are compile-time constants;
only the parameter arrays and the input image are traced operands.
Per-node backends:

* ``"xla"``             pure-JAX xor+popcount (paper Eqn 1; always available),
* ``"xla_pm1"``         pure-JAX ±1-matmul reformulation (XLA maps it to the
                        platform matmul engine),
* ``"mxu_pm1"``         ±1-matmul routed for the TPU MXU (same numerics as
                        ``xla_pm1``; distinct name so autotune/benchmarks can
                        report the intended engine),
* ``"vpu_popcount"``    the fused im2col Pallas kernel (interpret off-TPU),
* ``"vpu_direct"``      the direct (im2col-free) Pallas kernel — conv ops
                        only (DESIGN.md §5),
* ``"vpu_direct_pool"`` the direct kernel with the OR-pool fused into its
                        epilogue — ``packed_conv_pool`` nodes only.

Above the per-node backends sits the region-level ``"vpu_chain"`` mode
(DESIGN.md §9): the executor accepts ``regions=`` — chains formed by
:mod:`repro.runtime.regions` — and evaluates each whole region in one
Pallas megakernel call with VMEM-resident intermediates; member nodes are
skipped in the schedule and nodes outside every region degrade per-node
along ``_FALLBACK``.

All backends are bit-exact w.r.t. each other, so backend choice is purely a
performance decision — which is what makes per-node autotuning
(:mod:`repro.runtime.autotune`) safe.  Backends that do not apply to an op
(e.g. ``vpu_direct`` on ``packed_dense``) degrade along ``_FALLBACK`` when
the executor is built from a single mode string, and are rejected when
explicitly assigned per node.

``trace_count`` increments only when JAX retraces the closure, which the
tests use to pin the no-recompile-at-serve-time contract.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (binary_conv, binary_ops, bitplanes,
                        layer_integration, packing)
from repro.core.bnn_model import _BN_EPS
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _trace
from repro.runtime.graph import DISPATCHABLE_OPS, Graph
from repro.serving import faults as _faults

BACKENDS = ("xla", "xla_pm1", "mxu_pm1", "vpu_popcount", "vpu_direct",
            "vpu_direct_pool")
# The region-level megakernel mode (DESIGN.md §9): not a per-node backend
# — chains are evaluated whole via ``regions`` — but a valid engine
# ``matmul_mode``; per-node leftovers degrade along _FALLBACK.
CHAIN_BACKEND = "vpu_chain"
ALL_MODES = BACKENDS + (CHAIN_BACKEND,)

_IMPL = {"xla": "xor", "xla_pm1": "pm1", "mxu_pm1": "pm1"}
# Graceful degradation when a single mode string hits an op it cannot run.
_FALLBACK = {"vpu_chain": "vpu_direct_pool",
             "vpu_direct_pool": "vpu_direct", "vpu_direct": "vpu_popcount"}


def valid_backends(op: str) -> tuple[str, ...]:
    """The backends an op can dispatch to (autotune candidate filter)."""
    if op == "packed_conv_pool":
        return BACKENDS
    if op == "packed_conv":
        return tuple(b for b in BACKENDS if b != "vpu_direct_pool")
    if op == "packed_dense":
        return ("xla", "xla_pm1", "mxu_pm1", "vpu_popcount")
    return ()


def resolve_backend(op: str, backend: str) -> str:
    """Degrade a requested mode along _FALLBACK until the op supports it."""
    requested = backend
    while backend not in valid_backends(op):
        if backend not in _FALLBACK:
            raise ValueError(
                f"backend {requested!r} unusable for op {op!r}; want one "
                f"of {valid_backends(op)} (or 'auto' at the engine)")
        backend = _FALLBACK[backend]
    return backend


def _pallas_interpret() -> bool:
    return jax.default_backend() != "tpu"


_DONATION_FILTER_INSTALLED = False


def _ignore_donation_warning() -> None:
    """Install (once) a lowest-priority filter for XLA's failed-donation
    warning — expected on every donated call off-TPU.  ``append=True``
    keeps caller-installed filters (including ``error``) winning."""
    global _DONATION_FILTER_INSTALLED
    if not _DONATION_FILTER_INSTALLED:
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable",
            append=True)
        _DONATION_FILTER_INSTALLED = True


def _pool_attrs(a: dict) -> tuple[int, int, tuple[int, int]] | None:
    if "pool_window" not in a:
        return None
    return (a["pool_window"], a["pool_stride"],
            tuple(a.get("pool_pad", (0, 0))))


def _eval_packed_conv(a: dict, p: dict, x, backend: str, tile: dict):
    from repro.kernels import ops as kops

    k, s, pad = a["kernel"], a["stride"], a["pad"]
    ww = p.get("word_weights")
    pool = _pool_attrs(a)
    block_kw = dict(tile) if backend.startswith("vpu") else {}
    if backend == "vpu_direct_pool":
        # Pool rides the direct kernel's epilogue: the pre-pool conv
        # output never reaches HBM.
        return kops.fused_binary_conv2d(
            x, p["w_packed"], p["thresh"], k, k, s, pad, word_weights=ww,
            mode="vpu_direct", pool=pool, **block_kw)
    out = kops.fused_binary_conv2d(
        x, p["w_packed"], p["thresh"], k, k, s, pad, word_weights=ww,
        mode=backend, **block_kw)
    if pool is not None:
        out = binary_conv.binary_or_maxpool(out, pool[0], pool[1],
                                            pad=pool[2])
    return out


def _eval_packed_dense(a: dict, p: dict, x, backend: str, tile: dict):
    from repro.kernels import ops as kops

    block_kw = dict(tile) if backend.startswith("vpu") else {}
    return kops.fused_binary_dense(x, p["w_packed"], p["thresh"],
                                   mode=backend, **block_kw)


def _eval_bn_binarize(a: dict, p: dict, cnt):
    sigma = jnp.sqrt(p["var"] + _BN_EPS)
    if a.get("first"):
        # wcnt -> Eqn-2 dot: s = 255*(K + w_sum)/2 - wcnt
        const = 255.0 * (jnp.float32(a["k_valid"]) +
                         p["w_sum"].astype(jnp.float32)) / 2.0
        dot = const - cnt.astype(jnp.float32)
    else:
        dot = jnp.float32(a["k_valid"]) - 2.0 * cnt.astype(jnp.float32)
    x3 = p["gamma"] * ((dot + p.get("bias", 0.0)) - p["mu"]) / sigma + p["beta"]
    return packing.pack_bits((x3 >= 0), axis=-1)


def _eval_maxpool_pm1(a: dict, x):
    xv = packing.unpack_to_pm1(x, a["channels"], dtype=jnp.float32)
    pad = tuple(a.get("pad", (0, 0)))
    if pad != (0, 0):
        xv = jnp.pad(xv, ((0, 0), pad, pad, (0, 0)), constant_values=-1.0)
    xv = lax.reduce_window(
        xv, -jnp.inf, lax.max,
        (1, a["window"], a["window"], 1),
        (1, a["stride"], a["stride"], 1), "VALID")
    return packing.pack_bits((xv >= 0), axis=-1)


def eval_node(node_op: str, attrs: dict, params: dict, inputs: list,
              backend: str = "xla", tile: dict | None = None):
    """Evaluate one node given its already-computed input values."""
    a, p = attrs, params
    tile = tile or {}
    if node_op == "bitplane_expand":
        planes = bitplanes.pack_bitplanes(inputs[0])
        n, h, w, np_, cw = planes.shape
        return planes.reshape(n, h, w, np_ * cw)
    if node_op in ("packed_conv", "packed_conv_pool"):
        return _eval_packed_conv(a, p, inputs[0], backend, tile)
    if node_op == "packed_dense":
        return _eval_packed_dense(a, p, inputs[0], backend, tile)
    if node_op == "or_pool":
        return binary_conv.binary_or_maxpool(
            inputs[0], a["window"], a["stride"],
            pad=tuple(a.get("pad", (0, 0))))
    if node_op == "conv_counts":
        return binary_conv.binary_conv2d_counts(
            inputs[0], p["w_packed"], a["kernel"], a["kernel"],
            a["stride"], a["pad"], word_weights=p.get("word_weights"))
    if node_op == "dense_counts":
        flat = inputs[0].reshape(inputs[0].shape[0], -1)
        return binary_ops.binary_dense_counts(flat, p["w_packed"])
    if node_op == "bn_binarize":
        return _eval_bn_binarize(a, p, inputs[0])
    if node_op == "threshold_pack":
        bits = layer_integration.apply_threshold(inputs[0], p["thresh"])
        return packing.pack_bits(bits, axis=-1)
    if node_op == "maxpool_pm1":
        return _eval_maxpool_pm1(a, inputs[0])
    if node_op == "unpack_pm1":
        return packing.unpack_to_pm1(inputs[0], a["channels"],
                                     dtype=jnp.float32)
    if node_op == "float_dense":
        flat = inputs[0].reshape(inputs[0].shape[0], -1)
        return flat @ p["w"] + p["b"]
    if node_op == "float_conv":
        return lax.conv_general_dilated(
            inputs[0], p["w"], (a["stride"], a["stride"]),
            [(a["pad"], a["pad"])] * 2,
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    if node_op == "concat_packed":
        return jnp.concatenate(inputs, axis=-1)
    raise ValueError(f"cannot evaluate op {node_op!r}")


class GraphExecutor:
    """Jit-compiled topological evaluator with frozen per-node backends.

    The backend map and per-node kernel tile shapes are part of the
    compile-time closure: changing them means building a new executor
    (``with_backends``), never silently retracing an existing one —
    serve-time calls hit the same compiled function.
    """

    def __init__(self, graph: Graph,
                 backends: str | Mapping[int, str] = "xla",
                 tile_configs: Mapping[int, Mapping[str, int]] | None = None,
                 donate_input: bool = False,
                 regions: Sequence[Any] | None = None):
        graph.validate()
        self.graph = graph
        self.donate_input = donate_input
        # Fused regions (runtime.regions.Chain): each is evaluated whole by
        # the chain megakernel when the schedule reaches its head; member
        # nodes are skipped and the result binds to the tail's id.
        self.regions = tuple(regions or ())
        self._region_head = {c.head: c for c in self.regions}
        self._region_members = {nid for c in self.regions
                                for nid in c.node_ids}
        if len(self._region_members) != sum(len(c.node_ids)
                                            for c in self.regions):
            raise ValueError("regions overlap")
        if isinstance(backends, str):
            backends = {nid: resolve_backend(n.op, backends)
                        for nid, n in graph.nodes.items()
                        if n.op in DISPATCHABLE_OPS}
        self.backends: dict[int, str] = {
            nid: b for nid, b in backends.items()
            if graph.nodes[nid].op in DISPATCHABLE_OPS}
        for nid, b in self.backends.items():
            op = graph.nodes[nid].op
            if b not in BACKENDS:
                raise ValueError(f"unknown backend {b!r} for node {nid}; "
                                 f"want one of {BACKENDS}")
            if b not in valid_backends(op):
                raise ValueError(f"backend {b!r} does not apply to node "
                                 f"{nid} ({op})")
        self.tile_configs: dict[int, dict] = {
            nid: dict(cfg) for nid, cfg in (tile_configs or {}).items()
            if nid in self.backends and cfg}
        # Params are traced operands (a pytree keyed by node id);
        # IntegratedParams is a NamedTuple and flattens naturally.
        self.arrays = {str(nid): dict(n.params)
                       for nid, n in graph.nodes.items() if n.params}
        self._schedule = graph.topo_order()
        self.trace_count = 0
        self._node_jits: dict[int, Any] = {}  # traced_call's own cache
        if donate_input:
            # The serving path hands each batch's input buffer to the
            # device for reuse (arg 1 = x; arg 0, the params, is never
            # donated).  Off-TPU XLA declines uint8 donations with a
            # warning — donation is permission, not a requirement.
            _ignore_donation_warning()
            self._jitted = jax.jit(self._run, donate_argnums=(1,))
        else:
            self._jitted = jax.jit(self._run)

    # ---- execution -------------------------------------------------------
    def _run(self, arrays, x):
        self.trace_count += 1  # increments at trace time only
        # Runtime-wide retrace series (DESIGN.md §10.2).  This runs at
        # trace time only — a host-side side effect exactly like the
        # counter above — so the compiled hot path carries no obs work.
        _obs_metrics.get_registry().counter("runtime.retraces").inc()
        g = self.graph
        env: dict[int, Any] = {}
        for nid in self._schedule:
            node = g.nodes[nid]
            if node.op == "input":
                env[nid] = x
                continue
            if nid in self._region_members:
                if nid in self._region_head:
                    from repro.runtime import regions as _regions

                    chain = self._region_head[nid]
                    env[chain.tail] = _regions.eval_chain(
                        chain, arrays, env[node.inputs[0]])
                continue
            env[nid] = eval_node(
                node.op, node.attrs, arrays.get(str(nid), {}),
                [env[i] for i in node.inputs],
                backend=self.backends.get(nid, "xla"),
                tile=self.tile_configs.get(nid))
        return env[g.output_id]

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        # Fault-injection site (DESIGN.md §11.1): host-side, before the
        # compiled closure — a plan can make this executable "fail" or
        # stall without touching what jit compiled.  Disabled: one read.
        if _faults._PLAN is not None:
            _faults.maybe_fault("executor.call", nodes=len(self._schedule))
        # The disabled-tracing fast path is one global read: no span
        # object, no frame beyond this test (DESIGN.md §10.4).
        if _trace._TRACER is None:
            return self._jitted(self.arrays, x)
        with _trace.span("executor.call", "runtime",
                         nodes=len(self._schedule),
                         regions=len(self.regions)):
            return self._jitted(self.arrays, x)

    # ---- traced (diagnostic) execution -----------------------------------
    def _node_fn(self, nid: int):
        """Per-node jit'd callables for :meth:`traced_call`, cached so
        repeated traced calls never re-trace.  Kept apart from the fused
        closure: building these does not touch ``trace_count``."""
        fn = self._node_jits.get(nid)
        if fn is None:
            node = self.graph.nodes[nid]
            if nid in self._region_head:
                from repro.runtime import regions as _regions

                chain = self._region_head[nid]
                fn = jax.jit(lambda arrays, x:
                             _regions.eval_chain(chain, arrays, x))
            else:
                op, attrs = node.op, dict(node.attrs)
                backend = self.backends.get(nid, "xla")
                tile = self.tile_configs.get(nid)
                fn = jax.jit(lambda params, *ins: eval_node(
                    op, attrs, params, list(ins), backend=backend,
                    tile=tile))
            self._node_jits[nid] = fn
        return fn

    def traced_call(self, x: jnp.ndarray) -> jnp.ndarray:
        """Per-node execution with one span per node / chain region.

        The diagnostic answer to "where did this forward's time go":
        walks the schedule host-side, blocking after every node so each
        span's duration is real wall time (the fused ``__call__`` cannot
        attribute time below the whole closure).  Bit-exact with
        ``__call__`` — same backends, same tiles, same region evaluation
        — and runs through its own per-node jit cache, so the fused
        closure is never retraced (``trace_count`` unchanged).  Blocking
        per node forfeits inter-node overlap: this is a profiling tool,
        not a serving path.
        """
        g = self.graph
        env: dict[int, Any] = {}
        with _trace.span("executor.traced_call", "runtime",
                         nodes=len(self._schedule)):
            for nid in self._schedule:
                node = g.nodes[nid]
                if node.op == "input":
                    env[nid] = x
                    continue
                if nid in self._region_members:
                    chain = self._region_head.get(nid)
                    if chain is None:
                        continue
                    label = "+".join(map(str, chain.node_ids))
                    with _trace.span(f"region.{label}", "executor",
                                     op="chain", stages=len(chain.stages)):
                        out = self._node_fn(nid)(self.arrays,
                                                 env[node.inputs[0]])
                        jax.block_until_ready(out)
                    env[chain.tail] = out
                    continue
                with _trace.span(f"node.{node.op}", "executor", node=nid,
                                 backend=self.backends.get(nid)) as sp:
                    out = self._node_fn(nid)(
                        self.arrays.get(str(nid), {}),
                        *[env[i] for i in node.inputs])
                    jax.block_until_ready(out)
                    sp.set(shape=list(getattr(out, "shape", ())))
                env[nid] = out
        return env[g.output_id]

    # ---- variants --------------------------------------------------------
    def with_backends(self, backends: str | Mapping[int, str],
                      tile_configs: Mapping[int, Mapping[str, int]]
                      | None = None) -> "GraphExecutor":
        return GraphExecutor(self.graph, backends, tile_configs,
                             donate_input=self.donate_input,
                             regions=self.regions)

    def backend_report(self) -> list[dict]:
        rows = []
        for nid in self._schedule:
            node = self.graph.nodes[nid]
            if nid in self._region_members:
                chain = self._region_head.get(nid)
                if chain is not None:
                    rows.append(dict(
                        node="+".join(map(str, chain.node_ids)), op="chain",
                        channels=self.graph.nodes[chain.tail]
                                     .attrs.get("channels"),
                        backend=CHAIN_BACKEND, tile=dict(chain.tile)))
                continue
            if node.op in DISPATCHABLE_OPS:
                rows.append(dict(node=nid, op=node.op,
                                 channels=node.attrs.get("channels"),
                                 backend=self.backends.get(nid, "xla"),
                                 tile=self.tile_configs.get(nid, {})))
        return rows
