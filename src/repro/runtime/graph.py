"""Operator IR for the PhoneBit graph runtime (DESIGN.md §4.1).

A model is a DAG of :class:`Node` objects with explicit edges, replacing the
flat ``LayerSpec`` walk of ``bnn_model.packed_forward``.  Explicit edges make
branching topologies (residual adds, multi-head detectors, concat trunks)
expressible, and give the optimization passes (:mod:`repro.runtime.passes`)
and the static memory planner (:mod:`repro.runtime.memory`) a substrate to
work on.

Two lowering entry points produce graphs:

* :func:`lower_packed` — from a ``converter.convert`` artifact (the serving
  path; works on loaded ``.npz`` artifacts where the float params are gone).
  Emits the *fused* ops (``packed_conv`` / ``packed_dense``) directly.
* :func:`lower_trained` — from trained latent-float params.  Emits the
  *unfused* pipeline (``conv_counts`` → ``bn_binarize``, ``maxpool_pm1``)
  so the fusion/absorption/layout passes can be exercised and tested as
  explicit rewrites; running the default pass pipeline converges to the
  same fused graph the artifact path produces.

Op vocabulary (``attrs`` are static python values; ``params`` are arrays):

===============  ============================================================
op               semantics (layouts in DESIGN.md §4.2)
===============  ============================================================
input            graph input placeholder; uint8 NHWC image
bitplane_expand  uint8 (N,H,W,C) → (N,H,W,8·Cw) int32 bit-plane words
packed_conv      fused conv+BN+binarize on packed words → packed words
packed_conv_pool packed_conv with an OR-pool epilogue fused in
                 (``passes.fuse_pool_epilogue``); the pre-pool conv output
                 is never materialized on the direct-kernel backend
packed_dense     fused dense+BN+binarize, flattens input → (N, Ow)
or_pool          max-pool in the packed domain = windowed bitwise OR
conv_counts      unfused conv: weighted xor-popcounts (N,OH,OW,O) int32
dense_counts     unfused dense counts (N, O) int32
bn_binarize      float-BN epilogue on counts → packed bits (oracle form)
threshold_pack   integer-threshold epilogue on counts → packed bits
maxpool_pm1      semantic max-pool: unpack ±1 → reduce-max → repack
unpack_pm1       packed words → float ±1 (c_per_pos valid channels)
float_dense      full-precision head: flatten, x@w+b
float_conv       full-precision conv (paper's conv9)
concat_packed    channel-concat of packed words (each input C ≡ 0 mod 32)
===============  ============================================================

Every node carries ``attrs["channels"]`` — the number of *valid* binary
channels per spatial position of its output — which downstream lowering and
the layout pass use to materialize unpack widths.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes, packing
from repro.core.binary_conv import conv_out_size, pack_conv_weights
from repro.core.bnn_model import (BConv, BDense, FloatConv, FloatDense,
                                  LayerSpec, Pool)

# Ops whose output stays in the packed-word domain.
PACKED_OPS = frozenset({
    "packed_conv", "packed_conv_pool", "packed_dense", "or_pool",
    "bn_binarize", "threshold_pack", "maxpool_pm1", "concat_packed",
})
# Ops the executor can dispatch to more than one backend.
DISPATCHABLE_OPS = frozenset({"packed_conv", "packed_conv_pool",
                              "packed_dense"})


@dataclasses.dataclass
class Node:
    """One operator instance.  ``inputs`` are producer node ids (explicit
    edges); ``attrs`` are static (hashed into the jit closure); ``params``
    are arrays traced as operands."""
    id: int
    op: str
    inputs: tuple[int, ...]
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    params: dict[str, Any] = dataclasses.field(default_factory=dict)

    def with_(self, **kw) -> "Node":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class Graph:
    nodes: dict[int, Node] = dataclasses.field(default_factory=dict)
    input_id: int = -1
    output_id: int = -1
    input_hw: tuple[int, int] | None = None

    # ---- construction ----------------------------------------------------
    def new_id(self) -> int:
        return max(self.nodes, default=-1) + 1

    def add(self, op: str, inputs: Sequence[int] = (), attrs=None,
            params=None) -> int:
        nid = self.new_id()
        self.nodes[nid] = Node(nid, op, tuple(inputs), dict(attrs or {}),
                               dict(params or {}))
        return nid

    # ---- structure -------------------------------------------------------
    def consumers(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {nid: [] for nid in self.nodes}
        for node in self.nodes.values():
            for src in node.inputs:
                out[src].append(node.id)
        return out

    def topo_order(self) -> list[int]:
        """Deterministic topological order (Kahn, smallest-id first)."""
        indeg = {nid: len(set(n.inputs)) for nid, n in self.nodes.items()}
        cons = self.consumers()
        ready = sorted(nid for nid, d in indeg.items() if d == 0)
        order: list[int] = []
        while ready:
            nid = ready.pop(0)
            order.append(nid)
            for c in cons[nid]:
                uniq = set(self.nodes[c].inputs)
                indeg[c] -= 1 if nid in uniq else 0
                if indeg[c] == 0:
                    ready.append(c)
            ready.sort()
        if len(order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return order

    def copy(self) -> "Graph":
        return Graph(
            nodes={nid: Node(n.id, n.op, n.inputs, dict(n.attrs),
                             dict(n.params))
                   for nid, n in self.nodes.items()},
            input_id=self.input_id, output_id=self.output_id,
            input_hw=self.input_hw)

    def validate(self) -> None:
        for node in self.nodes.values():
            for src in node.inputs:
                if src not in self.nodes:
                    raise ValueError(f"node {node.id} ({node.op}) references "
                                     f"missing input {src}")
        if self.input_id not in self.nodes:
            raise ValueError("missing input node")
        if self.output_id not in self.nodes:
            raise ValueError("missing output node")
        self.topo_order()  # raises on cycles


# --------------------------------------------------------------------------
# Shape / dtype inference (memory planner + autotune substrate)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TensorType:
    shape: tuple[int, ...]
    dtype: Any

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize


def _conv_hw(shape, k, stride, pad):
    return (conv_out_size(shape[1], k, stride, pad),
            conv_out_size(shape[2], k, stride, pad))


def infer_types(graph: Graph,
                input_shape: tuple[int, ...]) -> dict[int, TensorType]:
    """Output TensorType of every node given the graph-input shape."""
    types: dict[int, TensorType] = {}
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        ins = [types[i] for i in node.inputs]
        a = node.attrs
        if node.op == "input":
            t = TensorType(tuple(input_shape), jnp.uint8)
        elif node.op == "bitplane_expand":
            n, h, w, c = ins[0].shape
            t = TensorType(
                (n, h, w, bitplanes.NUM_PLANES * packing.num_words(c)),
                jnp.int32)
        elif node.op in ("packed_conv", "packed_conv_pool", "conv_counts"):
            oh, ow = _conv_hw(ins[0].shape, a["kernel"], a["stride"],
                              a["pad"])
            if node.op == "packed_conv_pool":
                pp = sum(a.get("pool_pad", (0, 0)))
                oh = (oh + pp - a["pool_window"]) // a["pool_stride"] + 1
                ow = (ow + pp - a["pool_window"]) // a["pool_stride"] + 1
            last = (a["channels"] if node.op == "conv_counts"
                    else packing.num_words(a["channels"]))
            t = TensorType((ins[0].shape[0], oh, ow, last), jnp.int32)
        elif node.op in ("or_pool", "maxpool_pm1"):
            n, h, w, cw = ins[0].shape
            ph, pw = a.get("pad", (0, 0))
            oh = (h + ph + pw - a["window"]) // a["stride"] + 1
            ow = (w + ph + pw - a["window"]) // a["stride"] + 1
            t = TensorType((n, oh, ow, cw), jnp.int32)
        elif node.op == "packed_dense":
            t = TensorType(
                (ins[0].shape[0], packing.num_words(a["channels"])),
                jnp.int32)
        elif node.op == "dense_counts":
            t = TensorType((ins[0].shape[0], a["channels"]), jnp.int32)
        elif node.op in ("bn_binarize", "threshold_pack"):
            s = ins[0].shape
            t = TensorType(s[:-1] + (packing.num_words(s[-1]),), jnp.int32)
        elif node.op == "unpack_pm1":
            s = ins[0].shape
            t = TensorType(s[:-1] + (a["channels"],), jnp.float32)
        elif node.op == "float_dense":
            t = TensorType((ins[0].shape[0], a["channels"]), jnp.float32)
        elif node.op == "float_conv":
            oh, ow = _conv_hw(ins[0].shape, a["kernel"], a["stride"],
                              a["pad"])
            t = TensorType((ins[0].shape[0], oh, ow, a["channels"]),
                           jnp.float32)
        elif node.op == "concat_packed":
            base = ins[0].shape
            last = sum(i.shape[-1] for i in ins)
            t = TensorType(base[:-1] + (last,), jnp.int32)
        else:
            raise ValueError(f"no shape rule for op {node.op!r}")
        types[nid] = t
    return types


# --------------------------------------------------------------------------
# Lowering: LayerSpec + converter artifact -> fused graph
# --------------------------------------------------------------------------

def _input_channels(spec: Sequence[LayerSpec]) -> int | None:
    for layer in spec:
        if isinstance(layer, (BConv, FloatConv)):
            return layer.c_in
    return None

def lower_packed(spec: Sequence[LayerSpec], packed: Sequence[dict],
                 input_hw: tuple[int, int]) -> Graph:
    """Lower a flat spec + ``converter.convert`` artifact to a fused graph.

    This is the serving-path lowering (Fig 2's load step): it needs only the
    deployable artifact, so it also works for ``converter.load_artifact``
    output where the latent float params no longer exist.
    """
    g = Graph(input_hw=input_hw)
    cur = g.add("input", attrs=dict(channels=_input_channels(spec)))
    g.input_id = cur
    channels: int | None = None

    for layer, p in zip(spec, packed):
        if isinstance(layer, BConv):
            if layer.first:
                cur = g.add("bitplane_expand", [cur],
                            attrs=dict(c_in=layer.c_in, channels=layer.c_in))
            cur = g.add(
                "packed_conv", [cur],
                attrs=dict(kernel=layer.kernel, stride=layer.stride,
                           pad=layer.pad, channels=layer.c_out,
                           first=layer.first),
                params=dict(w_packed=p["w_packed"], thresh=p["thresh"],
                            **({"word_weights": p["word_weights"]}
                               if "word_weights" in p else {})))
            channels = layer.c_out
        elif isinstance(layer, Pool):
            cur = g.add("or_pool", [cur],
                        attrs=dict(window=layer.window, stride=layer.stride,
                                   pad=tuple(layer.pad), channels=channels))
        elif isinstance(layer, BDense):
            cur = g.add("packed_dense", [cur],
                        attrs=dict(channels=layer.d_out),
                        params=dict(w_packed=p["w_packed"],
                                    thresh=p["thresh"]))
            channels = layer.d_out
        elif isinstance(layer, FloatDense):
            cur = g.add("unpack_pm1", [cur],
                        attrs=dict(channels=int(p["c_per_pos"])))
            cur = g.add("float_dense", [cur],
                        attrs=dict(channels=layer.d_out),
                        params=dict(w=p["w"], b=p["b"]))
            channels = layer.d_out
        elif isinstance(layer, FloatConv):
            cur = g.add("unpack_pm1", [cur],
                        attrs=dict(channels=int(p["c_per_pos"])))
            cur = g.add("float_conv", [cur],
                        attrs=dict(kernel=layer.kernel, stride=layer.stride,
                                   pad=layer.pad, channels=layer.c_out),
                        params=dict(w=p["w"], b=p["b"]))
            channels = layer.c_out
        else:
            raise ValueError(f"cannot lower layer {layer!r}")
    g.output_id = cur
    g.validate()
    return g


# --------------------------------------------------------------------------
# Lowering: trained float params -> unfused graph (pass-pipeline input)
# --------------------------------------------------------------------------

def _first_layer_packed_weights(layer: BConv, w) -> tuple[jnp.ndarray,
                                                          jnp.ndarray]:
    cw = packing.num_words(layer.c_in)
    wp = packing.pack_signs(w, axis=2)                        # KH,KW,Cw,O
    wp = jnp.repeat(wp[:, :, None, :, :], bitplanes.NUM_PLANES, axis=2)
    wp = jnp.transpose(wp, (4, 0, 1, 2, 3)).reshape(layer.c_out, -1)
    ww = jnp.tile(bitplanes.plane_word_weights(cw),
                  layer.kernel * layer.kernel)
    return wp, ww


def lower_trained(spec: Sequence[LayerSpec], params: Sequence[dict],
                  input_hw: tuple[int, int]) -> Graph:
    """Lower trained latent-float params to the *unfused* graph.

    Weight bit-packing happens here (packing is layout, not fusion), but BN
    stays a float epilogue (``bn_binarize``), pools stay semantic max-pools
    (``maxpool_pm1``), and no layout adapters (``bitplane_expand`` /
    ``unpack_pm1``) are emitted — those are the job of the
    :mod:`repro.runtime.passes` pipeline, mirroring what
    ``converter.convert`` hard-codes today (Eqns 5-9, §V-B).
    """
    g = Graph(input_hw=input_hw)
    cur = g.add("input", attrs=dict(channels=_input_channels(spec)))
    g.input_id = cur
    h, w = input_hw
    channels: int | None = None
    flat = False

    for layer, p in zip(spec, params):
        if isinstance(layer, BConv):
            if layer.first:
                wp, ww = _first_layer_packed_weights(layer, p["w"])
                wb = jnp.where(p["w"] >= 0, 1.0, -1.0)
                w_sum = jnp.sum(wb, axis=(0, 1, 2))
                conv_params = dict(w_packed=wp, word_weights=ww)
                bn_extra = dict(w_sum=w_sum)
            else:
                conv_params = dict(w_packed=pack_conv_weights(p["w"]))
                bn_extra = {}
            cur = g.add("conv_counts", [cur],
                        attrs=dict(kernel=layer.kernel, stride=layer.stride,
                                   pad=layer.pad, channels=layer.c_out,
                                   first=layer.first, k_valid=layer.k_valid),
                        params=conv_params)
            cur = g.add("bn_binarize", [cur],
                        attrs=dict(k_valid=layer.k_valid, first=layer.first,
                                   channels=layer.c_out),
                        params=dict(gamma=p["gamma"], beta=p["beta"],
                                    mu=p["mu"], var=p["var"], **bn_extra))
            h = conv_out_size(h, layer.kernel, layer.stride, layer.pad)
            w = conv_out_size(w, layer.kernel, layer.stride, layer.pad)
            channels = layer.c_out
        elif isinstance(layer, Pool):
            cur = g.add("maxpool_pm1", [cur],
                        attrs=dict(window=layer.window, stride=layer.stride,
                                   pad=tuple(layer.pad), channels=channels))
            h = (h + sum(layer.pad) - layer.window) // layer.stride + 1
            w = (w + sum(layer.pad) - layer.window) // layer.stride + 1
        elif isinstance(layer, BDense):
            if not flat:
                assert h * w * channels == layer.d_in, (
                    f"BDense d_in={layer.d_in} != {h}x{w}x{channels}")
                w4 = p["w"].reshape(h, w, channels, layer.d_out)
                wp = pack_conv_weights(w4)
            else:
                wp = jnp.transpose(packing.pack_signs(p["w"], axis=0), (1, 0))
            cur = g.add("dense_counts", [cur],
                        attrs=dict(channels=layer.d_out,
                                   k_valid=layer.d_in),
                        params=dict(w_packed=wp))
            cur = g.add("bn_binarize", [cur],
                        attrs=dict(k_valid=layer.d_in, first=False,
                                   channels=layer.d_out),
                        params=dict(gamma=p["gamma"], beta=p["beta"],
                                    mu=p["mu"], var=p["var"]))
            channels = layer.d_out
            flat = True
        elif isinstance(layer, FloatDense):
            cur = g.add("float_dense", [cur],
                        attrs=dict(channels=layer.d_out),
                        params=dict(w=p["w"].astype(jnp.float32),
                                    b=p["b"].astype(jnp.float32)))
            channels = layer.d_out
            flat = True
        elif isinstance(layer, FloatConv):
            cur = g.add("float_conv", [cur],
                        attrs=dict(kernel=layer.kernel, stride=layer.stride,
                                   pad=layer.pad, channels=layer.c_out),
                        params=dict(w=p["w"].astype(jnp.float32),
                                    b=p["b"].astype(jnp.float32)))
            h = conv_out_size(h, layer.kernel, layer.stride, layer.pad)
            w = conv_out_size(w, layer.kernel, layer.stride, layer.pad)
            channels = layer.c_out
        else:
            raise ValueError(f"cannot lower layer {layer!r}")
    g.output_id = cur
    g.validate()
    return g
