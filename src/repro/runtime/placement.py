"""Multi-device placement on the runtime IR (DESIGN.md §13).

The placement pass maps one serving graph onto several devices.  Two
placement kinds share the abstraction (duck-typed on ``.kind`` so the
serving layer never imports this module at import time):

* **data-parallel** — the batch dim is sharded over a mesh axis; the
  graph itself is untouched (one executable, ``NamedSharding`` inputs).
  The concrete placement object lives in
  :mod:`repro.distributed.sharding` (``DataParallel``).
* **pipeline-parallel** — the *schedule* is cut into contiguous stages,
  each compiled into its own per-device executable, with explicit
  cross-stage transfer steps between them (``Pipelined`` in
  :mod:`repro.distributed.pipeline`).

Pipeline cuts are only legal at the graph's **HBM touch points**: a
schedule position where exactly one live value crosses the cut (the
boundary tensor).  Chain regions (DESIGN.md §9) keep their interiors in
VMEM, so when the serving mode is ``vpu_chain`` the pass additionally
refuses to cut inside a chain — stage boundaries then coincide with
region boundaries, which were already the only activations reaching
HBM.  Cut positions are chosen by a small DP that minimizes the
heaviest stage under a static per-node cost model (xor-popcount MAC
count for conv/dense, output bytes otherwise) — the pipeline's
steady-state throughput is gated by its slowest stage.

:class:`StagedExecutor` is the executor half: one
:class:`~repro.runtime.executor.GraphExecutor` per stage, its params
committed to the stage's device, with a ``jax.device_put`` transfer
moving the boundary tensor to the next stage's device.  Dispatch stays
async end to end — each stage's work is enqueued on its own device and
the transfer is itself async — so under the server's double-buffered
dispatch, batch *k+1* occupies stage 0 while batch *k* is still in
stage 1: the classic pipeline overlap, with no bespoke scheduler.  All
stage executables are bit-exact with the single-device graph (stage
boundaries are exact tensor handoffs), so placement — like backend
choice — is purely a performance/capacity decision.

``trace_count`` sums over stages, preserving the serve-time
no-recompile contract end to end.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax

from repro.core.binary_conv import conv_out_size
from repro.runtime.graph import Graph, Node, TensorType, infer_types

_CONV_OPS = ("packed_conv", "packed_conv_pool", "conv_counts")


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def node_cost(node: Node, types: Mapping[int, TensorType]) -> float:
    """Static work estimate for one node (relative units).

    Conv/dense ops: xor-popcount MACs — output positions × kernel area ×
    input words.  Everything else: output bytes (layout shuffles and
    pools are bandwidth-bound).  Only *relative* stage balance matters,
    so a crude model is enough; the forced-mesh bench rows measure the
    real split.
    """
    t = types[node.id]
    a = node.attrs
    if node.op in _CONV_OPS:
        # Pre-pool conv dims: packed_conv_pool's output type is the
        # *pooled* map, but the xor-popcount work happens at conv size.
        in_t = types[node.inputs[0]]
        oh = conv_out_size(in_t.shape[1], a["kernel"], a["stride"],
                           a["pad"])
        ow = conv_out_size(in_t.shape[2], a["kernel"], a["stride"],
                           a["pad"])
        return float(oh * ow * a["kernel"] * a["kernel"] * in_t.shape[-1]
                     * a["channels"] * t.shape[0])
    if node.op in ("packed_dense", "dense_counts", "float_dense"):
        in_t = types[node.inputs[0]]
        k = 1
        for d in in_t.shape[1:]:
            k *= d
        return float(k * a["channels"] * t.shape[0])
    if node.op == "float_conv":
        in_t = types[node.inputs[0]]
        return float(t.shape[1] * t.shape[2] * a["kernel"] * a["kernel"]
                     * in_t.shape[-1] * a["channels"] * t.shape[0])
    return float(t.nbytes)


# ---------------------------------------------------------------------------
# Cut candidates: the schedule's HBM touch points
# ---------------------------------------------------------------------------

def cut_candidates(graph: Graph,
                   forbidden: frozenset[int] | set[int] = frozenset()
                   ) -> list[tuple[int, int]]:
    """Legal pipeline cut positions as ``(schedule_index, boundary_id)``.

    A cut after ``schedule[i]`` is legal when exactly one live value
    crosses it — that value is the stage-boundary tensor the transfer
    step will ship.  ``forbidden`` node ids (chain-region interiors)
    disqualify a position when the boundary or the next node sits inside
    a fused region.
    """
    schedule = graph.topo_order()
    pos = {nid: i for i, nid in enumerate(schedule)}
    cons = graph.consumers()
    out: list[tuple[int, int]] = []
    for i in range(len(schedule) - 1):
        live = [nid for nid in schedule[:i + 1]
                if any(pos[c] > i for c in cons[nid])
                or (nid == graph.output_id)]
        if len(live) != 1:
            continue
        boundary = live[0]
        if boundary in forbidden or schedule[i + 1] in forbidden:
            continue
        out.append((i, boundary))
    return out


def chain_interiors(chains: Sequence[Any]) -> frozenset[int]:
    """Node ids strictly inside a chain region (every member but the
    tail): cutting there would split an activation that never reaches
    HBM.  Chain *tails* stay legal boundaries — they are exactly the
    region boundaries DESIGN.md §9 identifies as the HBM touch points."""
    ids: set[int] = set()
    for c in chains:
        ids.update(c.node_ids[:-1])
    return frozenset(ids)


# ---------------------------------------------------------------------------
# Stage planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagePlan:
    """A pipeline partition of one graph's schedule.

    ``stages``     node ids per stage, schedule order, contiguous;
    ``boundaries`` the producer node id shipped across each cut
                   (``len == len(stages) - 1``);
    ``costs``      static cost-model total per stage.
    """
    stages: tuple[tuple[int, ...], ...]
    boundaries: tuple[int, ...]
    costs: tuple[float, ...]

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def report(self) -> list[dict]:
        total = sum(self.costs) or 1.0
        rows = []
        for i, (ids, cost) in enumerate(zip(self.stages, self.costs)):
            rows.append(dict(
                stage=i, nodes=list(ids), cost=cost,
                share=round(cost / total, 4),
                boundary=(self.boundaries[i]
                          if i < len(self.boundaries) else None)))
        return rows


def plan_pipeline(graph: Graph, input_shape: Sequence[int],
                  n_stages: int, *,
                  forbidden: frozenset[int] | set[int] = frozenset(),
                  types: Mapping[int, TensorType] | None = None
                  ) -> StagePlan:
    """Cut the schedule into ≤ ``n_stages`` cost-balanced stages.

    Chooses cut positions among :func:`cut_candidates` minimizing the
    maximum stage cost (pipeline throughput is gated by the slowest
    stage) via DP.  When the graph offers fewer legal cuts than
    requested stages, the plan degrades to what is legal — callers get
    ``plan.n_stages`` back, not an error.
    """
    graph.validate()
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1, got {n_stages}")
    types = types if types is not None else infer_types(
        graph, tuple(input_shape))
    schedule = graph.topo_order()
    costs = [node_cost(graph.nodes[nid], types) for nid in schedule]
    cands = cut_candidates(graph, forbidden)
    # A boundary must be produced by the stage immediately before its
    # cut; a value crossing an *entire* stage would leave that stage
    # output-less.  Cuts are chosen left to right, so it is enough to
    # drop candidate pairs that would sandwich a stage with no cost —
    # the DP below never selects two cuts at the same position anyway.
    k = min(n_stages - 1, len(cands))
    if k == 0:
        return StagePlan((tuple(schedule),), (), (sum(costs),))

    # prefix[i] = cost of schedule[0..i-1]
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    def seg(a: int, b: int) -> float:
        """Cost of schedule[a..b] inclusive."""
        return prefix[b + 1] - prefix[a]

    n = len(schedule)
    positions = [p for p, _ in cands]
    # best[j][ci]: minimal max-stage-cost using j cuts, the last at
    # candidate index ci.  O(k · |cands|²) — graphs are tens of nodes.
    best = [[float("inf")] * len(positions) for _ in range(k + 1)]
    back = [[-1] * len(positions) for _ in range(k + 1)]
    for ci, p in enumerate(positions):
        best[1][ci] = seg(0, p)
    for j in range(2, k + 1):
        for ci, p in enumerate(positions):
            for pi in range(ci):
                if positions[pi] >= p:
                    continue
                cand = max(best[j - 1][pi], seg(positions[pi] + 1, p))
                if cand < best[j][ci]:
                    best[j][ci] = cand
                    back[j][ci] = pi
    # close with the tail stage
    final_best, final_ci = float("inf"), -1
    for ci, p in enumerate(positions):
        cand = max(best[k][ci], seg(p + 1, n - 1))
        if cand < final_best:
            final_best, final_ci = cand, ci
    chosen: list[int] = []
    j, ci = k, final_ci
    while j >= 1 and ci >= 0:
        chosen.append(ci)
        ci = back[j][ci]
        j -= 1
    chosen.reverse()
    cut_pos = [positions[c] for c in chosen]
    boundary = {p: b for p, b in cands}

    stages: list[tuple[int, ...]] = []
    stage_costs: list[float] = []
    start = 0
    for p in cut_pos + [n - 1]:
        stages.append(tuple(schedule[start:p + 1]))
        stage_costs.append(seg(start, p))
        start = p + 1
    boundaries = tuple(boundary[p] for p in cut_pos)
    for ids, b in zip(stages, boundaries):
        assert b in ids, (b, ids)   # boundary produced by its own stage
    return StagePlan(tuple(stages), boundaries, tuple(stage_costs))


# ---------------------------------------------------------------------------
# Stage subgraphs
# ---------------------------------------------------------------------------

def stage_subgraph(graph: Graph, node_ids: Sequence[int],
                   boundary_in: int | None,
                   device=None) -> Graph:
    """One stage as a self-contained Graph.

    ``boundary_in`` (the previous stage's boundary producer) is replaced
    by an ``input`` placeholder *keeping its node id*, so every
    intra-stage edge survives untouched.  Stage 0 passes ``None`` and
    keeps the original graph input.  When ``device`` is given, node
    params are committed there — jit then compiles and runs the stage on
    that device (committed-operand placement, no deprecated
    ``jit(device=)``).
    """
    g = Graph(input_hw=graph.input_hw)
    if boundary_in is not None:
        src = graph.nodes[boundary_in]
        g.nodes[boundary_in] = Node(
            boundary_in, "input", (),
            attrs=dict(channels=src.attrs.get("channels")))
        g.input_id = boundary_in
    for nid in node_ids:
        n = graph.nodes[nid]
        params = dict(n.params)
        if device is not None and params:
            params = jax.tree.map(lambda a: jax.device_put(a, device),
                                  params)
        g.nodes[nid] = Node(nid, n.op, n.inputs, dict(n.attrs), params)
        if n.op == "input":
            g.input_id = nid
    g.output_id = node_ids[-1]
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Staged (pipeline-parallel) executor
# ---------------------------------------------------------------------------

class StagedExecutor:
    """Per-stage executables with cross-stage transfers (DESIGN.md §13).

    Presents the :class:`GraphExecutor` serve surface — ``__call__``,
    ``trace_count``, ``backend_report`` — so the engine's per-bucket
    executable cache and the server dispatch path work unchanged.  Each
    call walks the stages: move the boundary tensor to the stage's
    device (async transfer), invoke the stage executable (async
    dispatch).  The caller blocks only at the final readback, exactly as
    on one device.
    """

    def __init__(self, graph: Graph, input_shape: Sequence[int],
                 devices: Sequence[Any], *, mode: str = "xla",
                 tuner=None, donate_input: bool = False,
                 vmem_budget: int | None = None):
        from repro.runtime import regions as _regions
        from repro.runtime.executor import GraphExecutor

        if not devices:
            raise ValueError("pipeline placement needs >= 1 device")
        self.graph = graph
        self.mode = mode
        types = infer_types(graph, tuple(input_shape))
        forbidden: frozenset[int] = frozenset()
        if mode == "vpu_chain":
            budget = (vmem_budget if vmem_budget is not None
                      else _regions.DEFAULT_VMEM_BUDGET)
            forbidden = chain_interiors(_regions.partition_chains(
                graph, tuple(input_shape), vmem_budget=budget))
        self.plan = plan_pipeline(graph, input_shape, len(devices),
                                  forbidden=forbidden, types=types)
        self.devices = tuple(devices[:self.plan.n_stages])
        self._stage_exes = []
        shape = tuple(input_shape)
        for i, ids in enumerate(self.plan.stages):
            boundary_in = (self.plan.boundaries[i - 1] if i else None)
            sub = stage_subgraph(graph, ids, boundary_in,
                                 device=self.devices[i])
            if mode == "vpu_chain":
                exe = _regions.chain_executor(
                    sub, shape, tuner=tuner, donate_input=donate_input,
                    **({"vmem_budget": vmem_budget}
                       if vmem_budget is not None else {}))
            elif mode == "auto":
                if tuner is None:
                    raise ValueError("mode='auto' needs a tuner")
                exe = tuner.tuned_executor(sub, shape,
                                           donate_input=donate_input)
            else:
                exe = GraphExecutor(sub, mode, donate_input=donate_input)
            self._stage_exes.append(exe)
            if i < len(self.plan.boundaries):
                shape = types[self.plan.boundaries[i]].shape

    # ---- serve surface ---------------------------------------------------
    def __call__(self, x):
        for dev, exe in zip(self.devices, self._stage_exes):
            x = jax.device_put(x, dev)     # cross-stage transfer (async)
            x = exe(x)
        return x

    @property
    def trace_count(self) -> int:
        return sum(e.trace_count for e in self._stage_exes)

    @property
    def regions(self) -> tuple:
        return tuple(r for e in self._stage_exes
                     for r in getattr(e, "regions", ()))

    @property
    def stage_executors(self) -> tuple:
        return tuple(self._stage_exes)

    def backend_report(self) -> list[dict]:
        rows: list[dict] = []
        for i, (dev, exe) in enumerate(zip(self.devices,
                                           self._stage_exes)):
            for row in exe.backend_report():
                rows.append(dict(row, stage=i, device=str(dev)))
        return rows

    def stage_report(self) -> list[dict]:
        """The placement decision, one row per stage: nodes, static cost
        share, assigned device, boundary tensor shipped downstream."""
        rows = self.plan.report()
        for row, dev in zip(rows, self.devices):
            row["device"] = str(dev)
        return rows


def staged_executor(graph: Graph, input_shape: Sequence[int],
                    devices: Sequence[Any], *, mode: str = "xla",
                    tuner=None, donate_input: bool = False,
                    vmem_budget: int | None = None) -> StagedExecutor:
    """Build the pipeline-parallel executor for ``graph`` over
    ``devices`` (the engine's ``compile(pipeline=...)`` entry point)."""
    return StagedExecutor(graph, input_shape, devices, mode=mode,
                          tuner=tuner, donate_input=donate_input,
                          vmem_budget=vmem_budget)
