"""Graph rewrite passes (DESIGN.md §4.3).

These generalize what ``converter.convert`` + ``packed_forward`` hard-code
into explicit, individually testable rewrites over the operator IR:

* :func:`assign_layouts`   — layout assignment: label every edge with its
  data layout (u8 / bitplane / counts / packed / float) and insert the
  adapter nodes (``bitplane_expand``, ``unpack_pm1``) where producer and
  consumer disagree (§V-A's locality-friendly layouts made explicit).
* :func:`integrate_bn`     — conv+BN+binarize integration (Eqns 5-9):
  rewrite the float ``bn_binarize`` epilogue into the integer
  ``threshold_pack`` form via ``layer_integration.fold_bn`` /
  ``fold_bn_first_layer``.
* :func:`fuse_epilogues`   — merge ``conv_counts → threshold_pack`` into the
  single fused ``packed_conv`` operator (and dense likewise), so no
  unpacked count tensor is ever materialized (§V-B's layer integration).
* :func:`absorb_pools`     — OR-pool absorption: rewrite semantic
  ``maxpool_pm1`` nodes whose input is packed-binary into ``or_pool``,
  keeping pooling inside the packed domain (sign is monotone, so
  binarize-then-OR == max-then-binarize).
* :func:`fuse_pool_epilogue` — merge ``packed_conv → or_pool`` into the
  single ``packed_conv_pool`` operator so the direct-conv backend can run
  the pool in its epilogue and the pre-pool conv output is never
  materialized (DESIGN.md §5.3).  Not part of :func:`default_pipeline`
  (whose contract is convergence to the artifact lowering); the serving
  engine applies it on top.

:func:`default_pipeline` runs them in dependency order; applied to
:func:`~repro.runtime.graph.lower_trained` output it converges to the same
fused graph :func:`~repro.runtime.graph.lower_packed` builds from a
converter artifact.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import layer_integration
from repro.core.bnn_model import _BN_EPS
from repro.runtime.graph import PACKED_OPS, Graph

# Output layout per op ("same" = inherit from first input).
_OUT_LAYOUT = {
    "input": "u8",
    "bitplane_expand": "bitplane",
    "conv_counts": "counts",
    "dense_counts": "counts",
    "packed_conv": "packed",
    "packed_conv_pool": "packed",
    "packed_dense": "packed",
    "bn_binarize": "packed",
    "threshold_pack": "packed",
    "or_pool": "packed",
    "maxpool_pm1": "packed",
    "concat_packed": "packed",
    "unpack_pm1": "float",
    "float_dense": "float",
    "float_conv": "float",
}

# Layout each op requires of its inputs (None = anything).
_IN_LAYOUT = {
    "bitplane_expand": "u8",
    "packed_conv": None,  # bitplane when first else packed — checked below
    "packed_conv_pool": None,
    "conv_counts": None,
    "packed_dense": "packed",
    "dense_counts": "packed",
    "bn_binarize": "counts",
    "threshold_pack": "counts",
    "or_pool": "packed",
    "maxpool_pm1": "packed",
    "concat_packed": "packed",
    "unpack_pm1": "packed",
    "float_dense": "float",
    "float_conv": "float",
}


def _expected_in_layout(op: str, attrs: dict) -> str | None:
    if op in ("packed_conv", "packed_conv_pool", "conv_counts"):
        return "bitplane" if attrs.get("first") else "packed"
    return _IN_LAYOUT.get(op)


def assign_layouts(graph: Graph) -> Graph:
    """Label nodes with their output layout; insert adapters on mismatched
    edges.  Returns a new graph; raises on un-adaptable mismatches."""
    g = graph.copy()
    # Iterate in topo order so inserted adapters are final before their
    # consumers are visited.
    for nid in g.topo_order():
        node = g.nodes[nid]
        want = _expected_in_layout(node.op, node.attrs)
        if want is None:
            continue
        new_inputs = []
        for src in node.inputs:
            prod = g.nodes[src]
            have = prod.attrs.get("layout", _OUT_LAYOUT[prod.op])
            if have == want:
                new_inputs.append(src)
            elif have == "u8" and want == "bitplane":
                c_in = prod.attrs.get("channels")
                a = g.add("bitplane_expand", [src],
                          attrs=dict(c_in=c_in, channels=c_in,
                                     layout="bitplane"))
                new_inputs.append(a)
            elif have == "packed" and want == "float":
                a = g.add("unpack_pm1", [src],
                          attrs=dict(channels=prod.attrs["channels"],
                                     layout="float"))
                new_inputs.append(a)
            else:
                raise ValueError(
                    f"no layout adapter {have!r} -> {want!r} on edge "
                    f"{src}({prod.op}) -> {nid}({node.op})")
        node.inputs = tuple(new_inputs)
    for node in g.nodes.values():
        node.attrs["layout"] = node.attrs.get("layout",
                                              _OUT_LAYOUT[node.op])
    g.validate()
    return g


def integrate_bn(graph: Graph) -> Graph:
    """Fold each float ``bn_binarize`` epilogue into the integer-threshold
    form (Eqns 5-9 + DESIGN.md §3.4's strengthening)."""
    g = graph.copy()
    for nid, node in list(g.nodes.items()):
        if node.op != "bn_binarize":
            continue
        p = node.params
        sigma = jnp.sqrt(p["var"] + _BN_EPS)
        bias = p.get("bias", 0.0)
        if node.attrs.get("first"):
            thresh = layer_integration.fold_bn_first_layer(
                node.attrs["k_valid"], p["w_sum"], p["gamma"], p["beta"],
                p["mu"], sigma, bias=bias)
        else:
            thresh = layer_integration.fold_bn(
                node.attrs["k_valid"], p["gamma"], p["beta"], p["mu"],
                sigma, bias=bias)
        attrs = {k: v for k, v in node.attrs.items() if k != "k_valid"}
        g.nodes[nid] = node.with_(op="threshold_pack", attrs=attrs,
                                  params=dict(thresh=thresh))
    return g


def fuse_epilogues(graph: Graph) -> Graph:
    """Merge ``conv_counts → threshold_pack`` into fused ``packed_conv``
    (and ``dense_counts`` → ``packed_dense``): the epilogue happens in the
    producer's registers and the count tensor is never materialized."""
    g = graph.copy()
    cons = g.consumers()
    for nid, node in list(g.nodes.items()):
        if node.op != "threshold_pack" or nid not in g.nodes:
            continue
        (src,) = node.inputs
        prod = g.nodes[src]
        if prod.op not in ("conv_counts", "dense_counts"):
            continue
        if len(cons[src]) != 1:
            continue  # counts fan out elsewhere: keep them materialized
        fused_op = ("packed_conv" if prod.op == "conv_counts"
                    else "packed_dense")
        attrs = {k: v for k, v in prod.attrs.items() if k != "k_valid"}
        attrs["layout"] = node.attrs.get("layout", "packed")
        params = dict(prod.params)
        params["thresh"] = node.params["thresh"]
        # Keep the epilogue node's id so its consumers stay wired.
        g.nodes[nid] = node.with_(op=fused_op, inputs=prod.inputs,
                                  attrs=attrs, params=params)
        del g.nodes[src]
    g.validate()
    return g


def absorb_pools(graph: Graph) -> Graph:
    """Rewrite semantic max-pools over packed-binary inputs into OR-pools
    that never leave the packed domain (paper §VI-B)."""
    g = graph.copy()
    for node in g.nodes.values():
        if node.op != "maxpool_pm1":
            continue
        prod = g.nodes[node.inputs[0]]
        if prod.op in PACKED_OPS:
            node.op = "or_pool"
    return g


def fuse_pool_epilogue(graph: Graph) -> Graph:
    """Merge ``packed_conv → or_pool`` into fused ``packed_conv_pool``.

    Max-pool on packed binary maps is a windowed OR, and OR distributes
    over the conv tile boundary, so the pool can ride the conv kernel's
    epilogue: on the ``vpu_direct_pool`` backend the pre-pool conv output
    never reaches HBM, and for every backend the planner drops the
    (larger) unpooled intermediate from the arena.  Fusion requires the
    conv output to feed *only* the pool (no other consumer may need the
    unpooled map).
    """
    g = graph.copy()
    cons = g.consumers()
    for nid, node in list(g.nodes.items()):
        if node.op != "or_pool" or nid not in g.nodes:
            continue
        (src,) = node.inputs
        prod = g.nodes[src]
        if prod.op != "packed_conv" or len(cons[src]) != 1:
            continue
        attrs = dict(prod.attrs)
        attrs["pool_window"] = node.attrs["window"]
        attrs["pool_stride"] = node.attrs["stride"]
        attrs["pool_pad"] = tuple(node.attrs.get("pad", (0, 0)))
        attrs["layout"] = node.attrs.get("layout", "packed")
        # Keep the pool node's id so its consumers stay wired.
        g.nodes[nid] = node.with_(op="packed_conv_pool", inputs=prod.inputs,
                                  attrs=attrs, params=dict(prod.params))
        del g.nodes[src]
    g.validate()
    return g


def default_pipeline(graph: Graph) -> Graph:
    """The standard lowering pipeline: layouts → BN integration → epilogue
    fusion → pool absorption."""
    g = assign_layouts(graph)
    g = integrate_bn(g)
    g = fuse_epilogues(g)
    g = absorb_pools(g)
    return g
