"""Continuous-batching LM decode server.

Serving loop tying the pieces together: the BatchScheduler admits prompts,
the KVCacheManager assigns cache slots, prefill fills a slot, and one
jitted decode step advances *all* active slots each tick (continuous
batching — new sequences join between ticks, finished ones free their slot
without stalling the rest).

Simplifications vs a production server (recorded in DESIGN.md): one global
position per tick (slot positions are tracked but the decode step uses the
max — correct because attention masks by per-slot validity), greedy
sampling, single-host loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Rules
from repro.models import transformer
from repro.serving.kv_cache import KVCacheManager


@dataclasses.dataclass
class LMServer:
    cfg: transformer.LMConfig
    rules: Rules
    params: Any
    n_slots: int
    max_seq: int
    eos_id: int | None = None

    def __post_init__(self):
        self.cache = transformer.init_cache(self.cfg, self.n_slots,
                                            self.max_seq)
        self.manager = KVCacheManager(self.n_slots, self.max_seq)
        self.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        self.pos = 0
        self._decode = jax.jit(transformer.make_decode_step(
            self.cfg, self.rules, self.max_seq))
        # Single-sequence prefill at a fixed bucket keeps one compilation.
        self._fwd = jax.jit(
            lambda p, t: transformer.forward(p, t, self.cfg, self.rules))

    # ---- admission -------------------------------------------------------
    def add_prompt(self, prompt: list[int], max_new: int = 32):
        """Prefill a prompt token-by-token into a slot (compilation-free
        path: reuses the decode step; a bucketed prefill step is the
        optimization the prefill_32k cell lowers)."""
        seq = self.manager.admit(len(prompt), max_new)
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[seq.slot, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos + i))
        self.pos += len(prompt)
        nxt = int(jnp.argmax(logits[seq.slot]))
        seq.tokens.append(nxt)
        self.tokens = self.tokens.at[seq.slot, 0].set(nxt)
        return seq

    # ---- decode tick ---------------------------------------------------------
    def step(self) -> dict[int, int]:
        """One decode tick for all active sequences.  Returns
        {seq_id: new_token} for sequences still active."""
        if not self.manager.active:
            return {}
        logits, self.cache = self._decode(
            self.params, self.cache, self.tokens, jnp.int32(self.pos))
        self.pos += 1
        out: dict[int, int] = {}
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for seq_id, seq in list(self.manager.active.items()):
            tok = int(next_tokens[seq.slot])
            out[seq_id] = tok
            self.manager.record_token(seq_id, tok, self.eos_id)
            self.tokens = self.tokens.at[seq.slot, 0].set(tok)
        return out

    def generate(self, prompt: list[int], max_new: int = 16) -> list[int]:
        """Convenience: run one sequence to completion."""
        seq = self.manager.admit(len(prompt), max_new)
        sid = seq.slot
        out: list[int] = []
        tok = prompt[0]
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[sid, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
        for _ in range(max_new):
            nxt = int(jnp.argmax(logits[sid]))
            out.append(nxt)
            toks = self.tokens.at[sid, 0].set(nxt)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
            if self.eos_id is not None and nxt == self.eos_id:
                break
        if seq.seq_id in self.manager.active:
            self.manager.release(seq.seq_id)
        return out
