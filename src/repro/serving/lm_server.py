"""Continuous-batching LM decode server.

Serving loop tying the pieces together: submitted prompts queue as
:class:`Request` objects, the KVCacheManager assigns cache slots, prefill
fills a slot, and one jitted decode step advances *all* active slots each
tick (continuous batching — new sequences join between ticks, finished
ones free their slot without stalling the rest).

The server speaks the same protocol as the BNN
:class:`~repro.serving.server.InferenceServer` (DESIGN.md §7):
``submit(prompt)`` → Request, ``poll``, ``step``, ``drain`` and
``metrics()`` with the same p50/p95/served/dropped/queue-depth
definitions (latency here is submit → last token).  Deadline-carrying
requests that expire while waiting for a KV slot are shed at admission
and counted in ``dropped``.

Simplifications vs a production server (recorded in DESIGN.md): one global
position per tick (slot positions are tracked but the decode step uses the
max — correct because attention masks by per-slot validity), greedy
sampling, single-host loop.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Rules
from repro.models import transformer
from repro.obs import trace as _trace
from repro.obs.metrics import ServingMetrics
from repro.serving.kv_cache import KVCacheManager
from repro.serving.scheduler import Request, shed_expired_requests


@dataclasses.dataclass
class LMServer:
    cfg: transformer.LMConfig
    rules: Rules
    params: Any
    n_slots: int
    max_seq: int
    eos_id: int | None = None
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self.cache = transformer.init_cache(self.cfg, self.n_slots,
                                            self.max_seq)
        self.manager = KVCacheManager(self.n_slots, self.max_seq)
        self.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        self.pos = 0
        self._decode = jax.jit(transformer.make_decode_step(
            self.cfg, self.rules, self.max_seq))
        # Single-sequence prefill at a fixed bucket keeps one compilation.
        self._fwd = jax.jit(
            lambda p, t: transformer.forward(p, t, self.cfg, self.rules))
        # ---- server-protocol state (submit/poll/drain/metrics) ----------
        self._waiting: deque[Request] = deque()
        self._by_seq: dict[int, tuple[Request, Any]] = {}
        self._metrics = ServingMetrics(self.clock)
        self.dropped = 0

    # ---- admission -------------------------------------------------------
    def add_prompt(self, prompt: list[int], max_new: int = 32):
        """Prefill a prompt token-by-token into a slot (compilation-free
        path: reuses the decode step; a bucketed prefill step is the
        optimization the prefill_32k cell lowers)."""
        seq = self.manager.admit(len(prompt), max_new)
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[seq.slot, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos + i))
        self.pos += len(prompt)
        nxt = int(jnp.argmax(logits[seq.slot]))
        # First generated token goes through the manager so ``generated``
        # counts it — a max_new=1 sequence finishes right here.
        self.manager.record_token(seq.seq_id, nxt, self.eos_id)
        self.tokens = self.tokens.at[seq.slot, 0].set(nxt)
        return seq

    # ---- decode tick ---------------------------------------------------------
    def step(self) -> dict[int, int]:
        """One decode tick for all active sequences.  Returns
        {seq_id: new_token} for sequences still active."""
        if not self.manager.active:
            return {}
        logits, self.cache = self._decode(
            self.params, self.cache, self.tokens, jnp.int32(self.pos))
        self.pos += 1
        out: dict[int, int] = {}
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for seq_id, seq in list(self.manager.active.items()):
            tok = int(next_tokens[seq.slot])
            out[seq_id] = tok
            self.manager.record_token(seq_id, tok, self.eos_id)
            self.tokens = self.tokens.at[seq.slot, 0].set(tok)
        return out

    # ---- server protocol (same surface as InferenceServer) ---------------
    def submit(self, prompt: list[int], max_new: int = 16,
               deadline_s: float | None = None,
               now: float | None = None) -> Request:
        """Queue a prompt; it joins the continuous batch when a KV slot
        frees.  ``request.result`` becomes the generated token list.
        Invalid requests are rejected here, at the protocol edge — an
        assertion inside drain() would strand every other queued
        request."""
        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"max_seq ({self.max_seq})")
        r = Request((prompt, max_new), deadline_s=deadline_s)
        # one clock domain for arrival and completion (fake-clock tests)
        r.arrival_s = self.clock() if now is None else now
        self._waiting.append(r)
        _trace.instant("serve.submit", "serve", req=r.id)
        return r

    def poll(self, request: Request) -> bool:
        return request.done

    def _admit_waiting(self, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        # Shed expired requests anywhere in the queue — a full KV cache
        # must not protect queued requests from their deadlines.
        self._waiting, shed = shed_expired_requests(self._waiting, now)
        self.dropped += len(shed)
        self._metrics.record_dropped(len(shed))
        while self._waiting and self.manager.can_admit():
            r = self._waiting.popleft()
            prompt, max_new = r.payload
            self._metrics.mark_dispatch()
            seq = self.add_prompt(prompt, max_new=max_new)
            self._by_seq[seq.seq_id] = (r, seq)

    def serve_tick(self, now: float | None = None) -> list[Request]:
        """One serving tick: admit waiting prompts into free slots, run a
        decode step, complete any sequences that finished."""
        self._admit_waiting(now)
        self.step()
        now = self.clock() if now is None else now
        done: list[Request] = []
        for seq_id, (r, seq) in list(self._by_seq.items()):
            if seq_id not in self.manager.active:    # finished + released
                r.result, r.done = list(seq.tokens), True
                self._metrics.record([now - r.arrival_s])
                del self._by_seq[seq_id]
                done.append(r)
        return done

    def drain(self, now: float | None = None) -> list[Request]:
        """Serve until every submitted prompt has completed (or shed)."""
        done: list[Request] = []
        while self._waiting or self._by_seq:
            done += self.serve_tick(now)
        return done

    @property
    def metrics_registry(self):
        """This server's metric series (same shape as InferenceServer's)."""
        return self._metrics.registry

    @property
    def queue_depth(self) -> int:
        return len(self._waiting) + len(self._by_seq)

    def metrics(self) -> dict:
        """Same definitions as InferenceServer (§7.4); latency is submit →
        last token."""
        return self._metrics.snapshot(
            dropped=self.dropped,
            queue_depth=self.queue_depth,
            kv_utilization=self.manager.utilization)

    def generate(self, prompt: list[int], max_new: int = 16) -> list[int]:
        """Convenience: run one sequence to completion."""
        seq = self.manager.admit(len(prompt), max_new)
        sid = seq.slot
        out: list[int] = []
        tok = prompt[0]
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[sid, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
        for _ in range(max_new):
            nxt = int(jnp.argmax(logits[sid]))
            out.append(nxt)
            toks = self.tokens.at[sid, 0].set(nxt)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
            if self.eos_id is not None and nxt == self.eos_id:
                break
        if seq.seq_id in self.manager.active:
            self.manager.release(seq.seq_id)
        return out
