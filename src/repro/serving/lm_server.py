"""Continuous-batching LM decode server.

Serving loop tying the pieces together: submitted prompts queue as
:class:`Request` objects, the KVCacheManager assigns cache slots, prefill
fills a slot, and one jitted decode step advances *all* active slots each
tick (continuous batching — new sequences join between ticks, finished
ones free their slot without stalling the rest).

The server speaks the same protocol as the BNN
:class:`~repro.serving.server.InferenceServer` (DESIGN.md §7):
``submit(prompt)`` → Request, ``poll``, ``step``, ``drain`` and
``metrics()`` with the same p50/p95/served/dropped/queue-depth
definitions (latency here is submit → last token).  Deadline-carrying
requests that expire while waiting for a KV slot are shed at admission
and counted in ``dropped``.

Resilience (DESIGN.md §11): the LM server speaks the same terminal-
outcome protocol as the BNN server — every submitted request ends
``done=True`` with ``outcome`` ∈ {served, shed, error, rejected}.
Invalid prompts and queue-full submits resolve ``rejected`` (structured,
at the protocol edge) instead of raising; a faulted decode tick retries
under the shared :class:`RetryPolicy` and, exhausted, resolves the
in-flight sequences ``error`` and releases their KV slots so the batch
keeps moving; ``drain`` is iteration-bounded.

Simplifications vs a production server (recorded in DESIGN.md): one global
position per tick (slot positions are tracked but the decode step uses the
max — correct because attention masks by per-slot validity), greedy
sampling, single-host loop.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Rules
from repro.models import transformer
from repro.obs import FlightRecorder
from repro.obs import trace as _trace
from repro.obs.metrics import ServingMetrics
from repro.serving import faults as _faults
from repro.serving.faults import RetryPolicy
from repro.serving.kv_cache import KVCacheManager
from repro.serving.scheduler import Request, shed_expired_requests


@dataclasses.dataclass
class LMServer:
    cfg: transformer.LMConfig
    rules: Rules
    params: Any
    n_slots: int
    max_seq: int
    eos_id: int | None = None
    clock: Callable[[], float] = time.monotonic
    retry: RetryPolicy | None = dataclasses.field(
        default_factory=RetryPolicy)
    max_queue: int | None = None
    flight_capacity: int = 256

    def __post_init__(self):
        self.cache = transformer.init_cache(self.cfg, self.n_slots,
                                            self.max_seq)
        self.manager = KVCacheManager(self.n_slots, self.max_seq)
        self.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        self.pos = 0
        self._decode = jax.jit(transformer.make_decode_step(
            self.cfg, self.rules, self.max_seq))
        # Single-sequence prefill at a fixed bucket keeps one compilation.
        self._fwd = jax.jit(
            lambda p, t: transformer.forward(p, t, self.cfg, self.rules))
        # ---- server-protocol state (submit/poll/drain/metrics) ----------
        self._waiting: deque[Request] = deque()
        self._by_seq: dict[int, tuple[Request, Any]] = {}
        self._metrics = ServingMetrics(self.clock)
        self.dropped = 0
        self.flight = FlightRecorder(self.flight_capacity)
        self._tick_failures = 0   # consecutive faulted decode ticks

    # ---- admission -------------------------------------------------------
    def add_prompt(self, prompt: list[int], max_new: int = 32):
        """Prefill a prompt token-by-token into a slot (compilation-free
        path: reuses the decode step; a bucketed prefill step is the
        optimization the prefill_32k cell lowers)."""
        seq = self.manager.admit(len(prompt), max_new)
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[seq.slot, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos + i))
        self.pos += len(prompt)
        nxt = int(jnp.argmax(logits[seq.slot]))
        # First generated token goes through the manager so ``generated``
        # counts it — a max_new=1 sequence finishes right here.
        self.manager.record_token(seq.seq_id, nxt, self.eos_id)
        self.tokens = self.tokens.at[seq.slot, 0].set(nxt)
        return seq

    # ---- decode tick ---------------------------------------------------------
    def step(self) -> dict[int, int]:
        """One decode tick for all active sequences.  Returns
        {seq_id: new_token} for sequences still active."""
        if not self.manager.active:
            return {}
        if _faults._PLAN is not None:
            _faults.maybe_fault("lm.step", active=len(self.manager.active),
                                pos=self.pos)
        logits, self.cache = self._decode(
            self.params, self.cache, self.tokens, jnp.int32(self.pos))
        self.pos += 1
        out: dict[int, int] = {}
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for seq_id, seq in list(self.manager.active.items()):
            tok = int(next_tokens[seq.slot])
            out[seq_id] = tok
            self.manager.record_token(seq_id, tok, self.eos_id)
            self.tokens = self.tokens.at[seq.slot, 0].set(tok)
        return out

    # ---- server protocol (same surface as InferenceServer) ---------------
    def submit(self, prompt: list[int], max_new: int = 16,
               deadline_s: float | None = None,
               now: float | None = None) -> Request:
        """Queue a prompt; it joins the continuous batch when a KV slot
        frees.  ``request.result`` becomes the generated token list.
        Invalid requests are rejected here, at the protocol edge — with
        a structured ``rejected`` outcome (same protocol as the BNN
        server, DESIGN.md §11.2): raising inside drain() would strand
        every other queued request, and raising here would force every
        caller to wrap submit."""
        now = self.clock() if now is None else now
        prompt = list(prompt)
        err = None
        if not prompt:
            err = "empty prompt"
        elif any(not isinstance(t, (int, np.integer)) for t in prompt):
            err = "prompt tokens must be ints"
        elif len(prompt) + max_new > self.max_seq:
            err = (f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                   f"max_seq ({self.max_seq})")
        elif self.max_queue is not None \
                and len(self._waiting) >= self.max_queue:
            err = (f"queue full ({len(self._waiting)} >= "
                   f"max_queue={self.max_queue})")
        r = Request((prompt, max_new), deadline_s=deadline_s)
        # one clock domain for arrival and completion (fake-clock tests)
        r.arrival_s = now
        if err is not None:
            r.resolve("rejected", error=err)
            self._metrics.record_rejected()
            self.flight.record(id=r.id, outcome="rejected", error=err,
                               arrival_s=now, done_s=now, latency_s=0.0)
            _trace.instant("serve.reject", "serve", req=r.id, reason=err)
            return r
        self._waiting.append(r)
        _trace.instant("serve.submit", "serve", req=r.id)
        return r

    def poll(self, request: Request) -> bool:
        return request.done

    def _admit_waiting(self, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        # Shed expired requests anywhere in the queue — a full KV cache
        # must not protect queued requests from their deadlines.
        self._waiting, shed = shed_expired_requests(self._waiting, now)
        self.dropped += len(shed)
        self._metrics.record_dropped(len(shed))
        for r in shed:
            self.flight.record(id=r.id, outcome="shed",
                               arrival_s=r.arrival_s, done_s=now,
                               latency_s=now - r.arrival_s)
        while self._waiting and self.manager.can_admit():
            r = self._waiting.popleft()
            prompt, max_new = r.payload
            self._metrics.mark_dispatch()
            seq = self.add_prompt(prompt, max_new=max_new)
            self._by_seq[seq.seq_id] = (r, seq)

    def _fail_inflight(self, exc: Exception, now: float) -> list[Request]:
        """Retry budget for the decode tick exhausted: resolve every
        in-flight sequence ``error`` and release its KV slot so waiting
        prompts can still admit (the decode fault poisons the shared
        cache state for the sequences that were mid-flight, not the
        server)."""
        failed: list[Request] = []
        for seq_id, (r, _seq) in list(self._by_seq.items()):
            r.resolve("error", error=f"{type(exc).__name__}: {exc}")
            self._metrics.record_error()
            self.flight.record(id=r.id, outcome="error", error=r.error,
                               arrival_s=r.arrival_s, done_s=now,
                               latency_s=now - r.arrival_s)
            if seq_id in self.manager.active:
                self.manager.release(seq_id)
            del self._by_seq[seq_id]
            failed.append(r)
        _trace.instant("serve.error", "serve", n=len(failed))
        return failed

    def serve_tick(self, now: float | None = None) -> list[Request]:
        """One serving tick: admit waiting prompts into free slots, run a
        decode step, complete any sequences that finished.  A faulted
        decode tick never escapes: it retries (up to
        ``retry.max_attempts`` consecutive faults) and then resolves the
        in-flight sequences ``error`` (DESIGN.md §11.2)."""
        self._admit_waiting(now)
        done: list[Request] = []
        try:
            self.step()
            self._tick_failures = 0
        except Exception as e:          # noqa: BLE001 — never kill the loop
            self._tick_failures += 1
            budget = self.retry.max_attempts if self.retry else 1
            t = self.clock() if now is None else now
            if self._tick_failures >= budget:
                self._tick_failures = 0
                done += self._fail_inflight(e, t)
            else:
                self._metrics.record_retry()
                _trace.instant("serve.retry", "serve",
                               attempt=self._tick_failures)
        now = self.clock() if now is None else now
        for seq_id, (r, seq) in list(self._by_seq.items()):
            if seq_id not in self.manager.active:    # finished + released
                r.resolve("served", list(seq.tokens))
                self._metrics.record([now - r.arrival_s])
                self.flight.record(
                    id=r.id, outcome="served", arrival_s=r.arrival_s,
                    done_s=now, latency_s=now - r.arrival_s,
                    n_tokens=len(seq.tokens))
                del self._by_seq[seq_id]
                done.append(r)
        return done

    def drain(self, now: float | None = None,
              max_steps: int | None = None) -> list[Request]:
        """Serve until every submitted prompt has completed (or shed).

        Bounded (DESIGN.md §11.2): at most ``max_steps`` ticks — default
        generous for the outstanding work (each sequence needs at most
        ``max_seq`` decode ticks, plus the retry budget) — after which
        anything still outstanding resolves ``error`` instead of
        hanging the caller on a wedged batch."""
        if max_steps is None:
            budget = self.retry.max_attempts if self.retry else 1
            outstanding = len(self._waiting) + len(self._by_seq) + 1
            max_steps = outstanding * (self.max_seq + budget) * 2 + 16
        done: list[Request] = []
        steps = 0
        while self._waiting or self._by_seq:
            if steps >= max_steps:
                t = self.clock() if now is None else now
                wedged = list(self._waiting)
                self._waiting.clear()
                for r in wedged:
                    r.resolve("error",
                              error="drain wedged: step budget exhausted")
                    self._metrics.record_error()
                    self.flight.record(
                        id=r.id, outcome="error", error=r.error,
                        arrival_s=r.arrival_s, done_s=t,
                        latency_s=t - r.arrival_s)
                done += wedged
                done += self._fail_inflight(
                    RuntimeError("drain wedged: step budget exhausted"), t)
                break
            steps += 1
            done += self.serve_tick(now)
        return done

    @property
    def metrics_registry(self):
        """This server's metric series (same shape as InferenceServer's)."""
        return self._metrics.registry

    @property
    def queue_depth(self) -> int:
        return len(self._waiting) + len(self._by_seq)

    def metrics(self) -> dict:
        """Same definitions as InferenceServer (§7.4); latency is submit →
        last token."""
        return self._metrics.snapshot(
            dropped=self.dropped,
            queue_depth=self.queue_depth,
            kv_utilization=self.manager.utilization)

    def generate(self, prompt: list[int], max_new: int = 16) -> list[int]:
        """Convenience: run one sequence to completion."""
        seq = self.manager.admit(len(prompt), max_new)
        sid = seq.slot
        out: list[int] = []
        tok = prompt[0]
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[sid, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
        for _ in range(max_new):
            nxt = int(jnp.argmax(logits[sid]))
            out.append(nxt)
            toks = self.tokens.at[sid, 0].set(nxt)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
            if self.eos_id is not None and nxt == self.eos_id:
                break
        if seq.seq_id in self.manager.active:
            self.manager.release(seq.seq_id)
        return out
