"""Continuous-batching LM decode server.

Serving loop tying the pieces together: submitted prompts queue as
:class:`Request` objects, the KVCacheManager assigns cache slots, prefill
fills a slot, and one jitted decode step advances *all* active slots each
tick (continuous batching — new sequences join between ticks, finished
ones free their slot without stalling the rest).

The server speaks the same protocol as the BNN
:class:`~repro.serving.server.InferenceServer` (DESIGN.md §7):
``submit(prompt)`` → Request, ``poll``, ``step``, ``drain`` and
``metrics()`` with the same p50/p95/served/dropped/queue-depth
definitions (latency here is submit → last token).  Deadline-carrying
requests that expire while waiting for a KV slot are shed at admission
and counted in ``dropped``.

Resilience (DESIGN.md §11): the LM server speaks the same terminal-
outcome protocol as the BNN server — every submitted request ends
``done=True`` with ``outcome`` ∈ {served, shed, error, rejected}.
Invalid prompts and queue-full submits resolve ``rejected`` (structured,
at the protocol edge) instead of raising; a faulted decode tick retries
under the shared :class:`RetryPolicy`; ``drain`` is iteration-bounded.

Crash safety (DESIGN.md §14): with ``checkpoint_every=N`` the server
takes consistent-cut KV checkpoints — every active sequence snapshotted
to host at one global position — every N decode ticks *and* after each
admission batch (admissions break the pure-decode window the replay
math needs).  When the decode retry budget is exhausted, instead of
erroring the in-flight sequences it rebuilds the cache from the last
cut into fresh slots and lockstep-replays the ≤N uncheckpointed tokens
(bit-exact — §14.2), bounded by ``max_restore_attempts``; with an
``evacuate`` hook installed (a replica group), exhausted restores hand
the sequences to a healthy lane instead of erroring.  A
:class:`~repro.serving.recovery.RequestJournal` makes accepted submits
durable across hard crashes.

Simplifications vs a production server (recorded in DESIGN.md): one global
position per tick (slot positions are tracked but the decode step uses the
max — correct because attention masks by per-slot validity), greedy
sampling, single-host loop.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Rules
from repro.models import transformer
from repro.obs import FlightRecorder
from repro.obs import trace as _trace
from repro.obs.metrics import ServingMetrics
from repro.serving import faults as _faults
from repro.serving.faults import RetryPolicy
from repro.serving.kv_cache import KVCacheManager
from repro.serving.recovery import CheckpointSet, KVCheckpointer
from repro.serving.scheduler import Request, shed_expired_requests


@dataclasses.dataclass
class LMServer:
    cfg: transformer.LMConfig
    rules: Rules
    params: Any
    n_slots: int
    max_seq: int
    eos_id: int | None = None
    clock: Callable[[], float] = time.monotonic
    retry: RetryPolicy | None = dataclasses.field(
        default_factory=RetryPolicy)
    max_queue: int | None = None
    flight_capacity: int = 256
    tenant: str | None = None
    # ---- crash safety (DESIGN.md §14) -----------------------------------
    # Consistent-cut checkpoint cadence in decode ticks; None disables
    # checkpoint/restore (a decode fault errors the in-flight batch, the
    # pre-§14 behavior).  The replay bound after a fault is ≤ N tokens.
    checkpoint_every: int | None = None
    max_restore_attempts: int = 2
    journal: Any = None               # recovery.RequestJournal | None
    # Migration hook (set by LMReplicaGroup): called with the in-flight
    # [(Request, Sequence)] when restore attempts are exhausted; True
    # means another lane adopted them all.
    evacuate: Callable[[list], bool] | None = None

    def __post_init__(self):
        self.cache = transformer.init_cache(self.cfg, self.n_slots,
                                            self.max_seq)
        self.manager = KVCacheManager(self.n_slots, self.max_seq)
        self.tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        self.pos = 0
        self._decode = jax.jit(transformer.make_decode_step(
            self.cfg, self.rules, self.max_seq))
        # Single-sequence prefill at a fixed bucket keeps one compilation.
        self._fwd = jax.jit(
            lambda p, t: transformer.forward(p, t, self.cfg, self.rules))
        # ---- server-protocol state (submit/poll/drain/metrics) ----------
        self._waiting: deque[Request] = deque()
        self._by_seq: dict[int, tuple[Request, Any]] = {}
        self._metrics = ServingMetrics(self.clock)
        self.dropped = 0
        self.flight = FlightRecorder(
            self.flight_capacity,
            tags={"tenant": self.tenant} if self.tenant is not None
            else None)
        self._tick_failures = 0   # consecutive faulted decode ticks
        # ---- recovery state (DESIGN.md §14) -----------------------------
        self.checkpointer = KVCheckpointer()
        self._ticks_since_ckpt = 0
        self._restore_attempts = 0  # consecutive restores without a
        #                             clean tick in between
        self.restores = 0
        self.evacuations = 0

    # ---- admission -------------------------------------------------------
    def add_prompt(self, prompt: list[int], max_new: int = 32):
        """Prefill a prompt token-by-token into a slot (compilation-free
        path: reuses the decode step; a bucketed prefill step is the
        optimization the prefill_32k cell lowers)."""
        seq = self.manager.admit(len(prompt), max_new, prompt=prompt)
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[seq.slot, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos + i))
        self.pos += len(prompt)
        nxt = int(jnp.argmax(logits[seq.slot]))
        # First generated token goes through the manager so ``generated``
        # counts it — a max_new=1 sequence finishes right here.
        self.manager.record_token(seq.seq_id, nxt, self.eos_id)
        self.tokens = self.tokens.at[seq.slot, 0].set(nxt)
        return seq

    # ---- decode tick ---------------------------------------------------------
    def step(self) -> dict[int, int]:
        """One decode tick for all active sequences.  Returns
        {seq_id: new_token} for sequences still active."""
        if not self.manager.active:
            return {}
        if _faults._PLAN is not None:
            _faults.maybe_fault("lm.step", active=len(self.manager.active),
                                pos=self.pos, tenant=self.tenant)
        logits, self.cache = self._decode(
            self.params, self.cache, self.tokens, jnp.int32(self.pos))
        self.pos += 1
        out: dict[int, int] = {}
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for seq_id, seq in list(self.manager.active.items()):
            tok = int(next_tokens[seq.slot])
            out[seq_id] = tok
            self.manager.record_token(seq_id, tok, self.eos_id)
            self.tokens = self.tokens.at[seq.slot, 0].set(tok)
        return out

    # ---- server protocol (same surface as InferenceServer) ---------------
    def _journal_resolve(self, r: Request) -> None:
        if self.journal is not None and r.jid is not None:
            self.journal.resolve(r.jid, r.outcome, error=r.error)

    def submit(self, prompt: list[int], max_new: int = 16,
               deadline_s: float | None = None,
               now: float | None = None, jid: int | None = None) -> Request:
        """Queue a prompt; it joins the continuous batch when a KV slot
        frees.  ``request.result`` becomes the generated token list.
        Invalid requests are rejected here, at the protocol edge — with
        a structured ``rejected`` outcome (same protocol as the BNN
        server, DESIGN.md §11.2): raising inside drain() would strand
        every other queued request, and raising here would force every
        caller to wrap submit.  ``jid`` is the journal-replay path
        (§14.3): the submit record is already on disk, so the journaled
        identity is attached instead of re-journaled."""
        now = self.clock() if now is None else now
        prompt = list(prompt)
        err = None
        if not prompt:
            err = "empty prompt"
        elif any(not isinstance(t, (int, np.integer)) for t in prompt):
            err = "prompt tokens must be ints"
        elif len(prompt) + max_new > self.max_seq:
            err = (f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                   f"max_seq ({self.max_seq})")
        elif self.max_queue is not None \
                and len(self._waiting) >= self.max_queue:
            err = (f"queue full ({len(self._waiting)} >= "
                   f"max_queue={self.max_queue})")
        r = Request((prompt, max_new), deadline_s=deadline_s)
        r.jid = jid
        # one clock domain for arrival and completion (fake-clock tests)
        r.arrival_s = now
        if err is not None:
            r.resolve("rejected", error=err)
            self._journal_resolve(r)
            self._metrics.record_rejected()
            self.flight.record(id=r.id, outcome="rejected", error=err,
                               arrival_s=now, deadline_s=deadline_s,
                               done_s=now, latency_s=0.0)
            _trace.instant("serve.reject", "serve", req=r.id, reason=err)
            return r
        if self.journal is not None and jid is None:
            # WAL order: the submit record hits disk before the request
            # joins the queue — a crash in between replays it.
            r.jid = self.journal.submit("lm", (prompt, max_new))
        self._waiting.append(r)
        _trace.instant("serve.submit", "serve", req=r.id)
        return r

    def poll(self, request: Request) -> bool:
        return request.done

    def _admit_waiting(self, now: float | None = None) -> None:
        now = self.clock() if now is None else now
        # Shed expired requests anywhere in the queue — a full KV cache
        # must not protect queued requests from their deadlines.
        self._waiting, shed = shed_expired_requests(self._waiting, now)
        self.dropped += len(shed)
        self._metrics.record_dropped(len(shed))
        for r in shed:
            self._journal_resolve(r)
            self.flight.record(id=r.id, outcome="shed",
                               arrival_s=r.arrival_s,
                               deadline_s=r.deadline_s, done_s=now,
                               latency_s=now - r.arrival_s)
        admitted = 0
        while self._waiting and self.manager.can_admit():
            r = self._waiting.popleft()
            prompt, max_new = r.payload
            self._metrics.mark_dispatch()
            seq = self.add_prompt(prompt, max_new=max_new)
            self._by_seq[seq.seq_id] = (r, seq)
            admitted += 1
        if admitted and self.checkpoint_every is not None:
            # Admissions advance ``pos`` through prefill, breaking the
            # pure-decode window the replay math needs — re-cut here
            # (§14.2).  If nothing survived admission (max_new=1
            # finishing in prefill), the stale cut is merely dropped.
            if self.manager.active:
                self._take_checkpoint("admission")
            else:
                self.checkpointer.invalidate()

    def _fail_inflight(self, exc: Exception, now: float) -> list[Request]:
        """Recovery exhausted (or disabled): resolve every in-flight
        sequence ``error`` and release its KV slot so waiting prompts
        can still admit (the decode fault poisons the shared cache
        state for the sequences that were mid-flight, not the
        server)."""
        failed: list[Request] = []
        for seq_id, (r, seq) in list(self._by_seq.items()):
            r.resolve("error", error=f"{type(exc).__name__}: {exc}")
            self._journal_resolve(r)
            self._metrics.record_error()
            self.flight.record(id=r.id, outcome="error", error=r.error,
                               arrival_s=r.arrival_s,
                               deadline_s=r.deadline_s, done_s=now,
                               latency_s=now - r.arrival_s,
                               n_tokens=len(seq.tokens))
            if seq_id in self.manager.active:
                self.manager.release(seq_id)
            del self._by_seq[seq_id]
            failed.append(r)
        self.checkpointer.invalidate()
        _trace.instant("serve.error", "serve", n=len(failed))
        return failed

    # ---- checkpoint / restore (DESIGN.md §14.2) ---------------------------
    def _take_checkpoint(self, reason: str) -> None:
        """Snapshot a consistent cut.  Snapshot-fault policy: a faulted
        *cadence* snapshot keeps the previous cut (still consistent —
        the replay bound just grows, and the next tick retries); a
        faulted *admission*/*restore* snapshot invalidates it (the old
        cut predates a prefill or refers to pre-restore sequence ids)."""
        try:
            self.checkpointer.take(self.cache, self.manager, self.tokens,
                                   self.pos, reason=reason)
        except Exception as e:          # noqa: BLE001 — kv.snapshot site
            if reason != "cadence":
                self.checkpointer.invalidate()
            _trace.instant("serve.ckpt_failed", "serve", reason=reason,
                           error=f"{type(e).__name__}: {e}")
            return
        self._ticks_since_ckpt = 0
        _trace.instant("serve.ckpt", "serve", pos=self.pos,
                       seqs=len(self.manager.active), reason=reason)

    def _restore(self, ck: CheckpointSet) -> int:
        """Rebuild the decode state from the last consistent cut and
        lockstep-replay the uncheckpointed ticks.  Bit-exact (§14.2):
        attention reads only the owning slot's pages, so restored
        sequences may land in fresh slots; between cuts only pure
        decode ticks ran, so every surviving sequence has exactly
        ``m = pos − ck.pos`` known uncheckpointed tokens, and
        force-feeding them reproduces every K/V write verbatim.
        Returns ``m``.  Raises (state untouched) if the ``kv.restore``
        fault site fires or the cut is unusable."""
        if _faults._PLAN is not None:
            _faults.maybe_fault("kv.restore", pos=ck.pos,
                                active=len(self._by_seq),
                                tenant=self.tenant)
        m = self.pos - ck.pos
        for seq_id in self._by_seq:
            if seq_id not in ck.seqs:
                # Admission re-cuts should make this impossible; an
                # unusable cut burns a restore attempt, not the batch.
                raise RuntimeError(f"sequence {seq_id} missing from cut "
                                   f"@pos={ck.pos}")
        cache = transformer.init_cache(self.cfg, self.n_slots,
                                       self.max_seq)
        manager = KVCacheManager(self.n_slots, self.max_seq)
        tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        remapped: dict[int, tuple[Request, Any]] = {}
        replay: list[tuple[Any, list]] = []
        for seq_id, (r, old_seq) in self._by_seq.items():
            c = ck.seqs[seq_id]
            extra = old_seq.tokens[c.generated:]
            assert len(extra) == m, (len(extra), m)
            new_seq = manager.adopt(old_seq.length, old_seq.max_new,
                                    old_seq.generated,
                                    list(old_seq.tokens),
                                    prompt=old_seq.prompt)
            k_host, v_host = c.materialize()
            cache["k"] = cache["k"].at[:, new_seq.slot].set(
                jnp.asarray(k_host))
            cache["v"] = cache["v"].at[:, new_seq.slot].set(
                jnp.asarray(v_host))
            tokens = tokens.at[new_seq.slot, 0].set(c.register)
            remapped[new_seq.seq_id] = (r, new_seq)
            replay.append((new_seq, extra))
        # Install the rebuilt cut, then force-fed lockstep replay: tick
        # i writes the register K/V at pos and loads the token the
        # original tick generated (logits are discarded — the outcome
        # is already known and must not be resampled).
        self.cache, self.manager, self.tokens = cache, manager, tokens
        self.pos = ck.pos
        self._by_seq = remapped
        for i in range(m):
            _, self.cache = self._decode(self.params, self.cache,
                                         self.tokens, jnp.int32(self.pos))
            self.pos += 1
            for new_seq, extra in replay:
                self.tokens = self.tokens.at[new_seq.slot, 0].set(
                    extra[i])
        # The restored state is itself a consistent cut — re-cut so a
        # repeated fault replays from here, not from the stale set
        # (whose sequence ids no longer exist).
        self._take_checkpoint("restore")
        return m

    def _evacuate_inflight(self, now: float) -> bool:
        """Hand the in-flight sequences to the migration hook (a
        replica group adopts them on a healthy lane, §14.4).  All-or-
        nothing: True means the adopter now owns the requests and this
        lane forgets them un-resolved; False falls back to the error
        outcome."""
        items = [(r, seq) for _sid, (r, seq) in self._by_seq.items()]
        try:
            ok = bool(self.evacuate(items))
        except Exception:               # noqa: BLE001 — hook must not kill
            ok = False
        if not ok:
            return False
        for seq_id in list(self._by_seq):
            if seq_id in self.manager.active:
                self.manager.release(seq_id)
        self._by_seq.clear()
        self.checkpointer.invalidate()
        self.evacuations += 1
        self.flight.record(kind="evacuation", outcome="evacuated",
                           seqs=len(items), done_s=now)
        _trace.instant("serve.evacuate", "serve", n=len(items))
        return True

    def _recover(self, exc: Exception, now: float) -> list[Request]:
        """Decode retry budget exhausted: restore from the last cut
        (bounded attempts), else migrate via ``evacuate``, else resolve
        the in-flight sequences ``error`` (the pre-§14 outcome)."""
        while self.checkpoint_every is not None and self._by_seq \
                and self.checkpointer.set is not None \
                and self._restore_attempts < self.max_restore_attempts:
            self._restore_attempts += 1
            try:
                replayed = self._restore(self.checkpointer.set)
            except Exception as re:     # noqa: BLE001 — kv.restore site
                self.flight.record(kind="restore",
                                   outcome="restore_failed",
                                   error=f"{type(re).__name__}: {re}",
                                   attempt=self._restore_attempts,
                                   done_s=now)
                _trace.instant("serve.restore_failed", "serve",
                               attempt=self._restore_attempts)
                continue
            self.restores += 1
            self.flight.record(kind="restore", outcome="restored",
                               pos=self.pos, replayed=replayed,
                               seqs=len(self._by_seq),
                               attempt=self._restore_attempts, done_s=now)
            _trace.instant("serve.restore", "serve", pos=self.pos,
                           replayed=replayed)
            return []
        if self.evacuate is not None and self._by_seq \
                and self._evacuate_inflight(now):
            return []
        return self._fail_inflight(exc, now)

    def serve_tick(self, now: float | None = None) -> list[Request]:
        """One serving tick: admit waiting prompts into free slots, run a
        decode step, complete any sequences that finished.  A faulted
        decode tick never escapes: it retries (up to
        ``retry.max_attempts`` consecutive faults) and then either
        restores from the last KV checkpoint (§14.2) or resolves the
        in-flight sequences ``error`` (DESIGN.md §11.2)."""
        self._admit_waiting(now)
        done: list[Request] = []
        try:
            self.step()
            self._tick_failures = 0
            self._restore_attempts = 0
            if self.checkpoint_every is not None and self.manager.active:
                self._ticks_since_ckpt += 1
                if self._ticks_since_ckpt >= self.checkpoint_every:
                    self._take_checkpoint("cadence")
        except Exception as e:          # noqa: BLE001 — never kill the loop
            self._tick_failures += 1
            budget = self.retry.max_attempts if self.retry else 1
            t = self.clock() if now is None else now
            if self._tick_failures >= budget:
                self._tick_failures = 0
                done += self._recover(e, t)
            else:
                self._metrics.record_retry()
                _trace.instant("serve.retry", "serve",
                               attempt=self._tick_failures)
        now = self.clock() if now is None else now
        for seq_id, (r, seq) in list(self._by_seq.items()):
            if seq_id not in self.manager.active:    # finished + released
                r.resolve("served", list(seq.tokens))
                self._journal_resolve(r)
                self._metrics.record([now - r.arrival_s])
                self.flight.record(
                    id=r.id, outcome="served", arrival_s=r.arrival_s,
                    deadline_s=r.deadline_s, done_s=now,
                    latency_s=now - r.arrival_s, n_tokens=len(seq.tokens))
                del self._by_seq[seq_id]
                done.append(r)
        return done

    # ---- migration (DESIGN.md §14.4) --------------------------------------
    def adopt_sequence(self, request: Request, prompt: list[int],
                       tokens: list[int], max_new: int):
        """Adopt a sequence evacuated from another lane: replay-prefill
        its prompt plus already-generated tokens into a fresh slot
        *here*, register the last generated token, and resume decoding.
        Prefix-preserving, not bit-exact across lanes (RoPE positions
        and cache history differ between lanes), so the already-emitted
        prefix is kept verbatim and only future tokens are computed on
        this lane."""
        assert tokens, "adopted sequence must have generated tokens"
        seq = self.manager.adopt(len(prompt) + len(tokens), max_new,
                                 len(tokens), list(tokens),
                                 prompt=list(prompt))
        feed = list(prompt) + list(tokens[:-1])
        for i, tok in enumerate(feed):
            toks = self.tokens.at[seq.slot, 0].set(tok)
            _, self.cache = self._decode(self.params, self.cache, toks,
                                         jnp.int32(self.pos + i))
        self.pos += len(feed)
        self.tokens = self.tokens.at[seq.slot, 0].set(tokens[-1])
        self._by_seq[seq.seq_id] = (request, seq)
        self._metrics.mark_dispatch()
        # Adoption is an admission event: it advances ``pos`` through
        # the replay prefill, so the lane must re-cut.
        if self.checkpoint_every is not None:
            self._take_checkpoint("admission")
        return seq

    def drain(self, now: float | None = None,
              max_steps: int | None = None) -> list[Request]:
        """Serve until every submitted prompt has completed (or shed).

        Bounded (DESIGN.md §11.2): at most ``max_steps`` ticks — default
        generous for the outstanding work (each sequence needs at most
        ``max_seq`` decode ticks, plus the retry budget) — after which
        anything still outstanding resolves ``error`` instead of
        hanging the caller on a wedged batch."""
        if max_steps is None:
            budget = self.retry.max_attempts if self.retry else 1
            outstanding = len(self._waiting) + len(self._by_seq) + 1
            max_steps = outstanding * (self.max_seq + budget) * 2 + 16
        done: list[Request] = []
        steps = 0
        while self._waiting or self._by_seq:
            if steps >= max_steps:
                t = self.clock() if now is None else now
                wedged = list(self._waiting)
                self._waiting.clear()
                for r in wedged:
                    r.resolve("error",
                              error="drain wedged: step budget exhausted")
                    self._journal_resolve(r)
                    self._metrics.record_error()
                    self.flight.record(
                        id=r.id, outcome="error", error=r.error,
                        arrival_s=r.arrival_s, deadline_s=r.deadline_s,
                        done_s=t, latency_s=t - r.arrival_s)
                _trace.instant("serve.drain_wedged", "serve",
                               n=len(wedged) + len(self._by_seq))
                done += wedged
                done += self._fail_inflight(
                    RuntimeError("drain wedged: step budget exhausted"), t)
                break
            steps += 1
            done += self.serve_tick(now)
        return done

    @property
    def metrics_registry(self):
        """This server's metric series (same shape as InferenceServer's)."""
        return self._metrics.registry

    @property
    def queue_depth(self) -> int:
        return len(self._waiting) + len(self._by_seq)

    def metrics(self) -> dict:
        """Same definitions as InferenceServer (§7.4); latency is submit →
        last token."""
        extra: dict = {}
        if self.tenant is not None:
            extra["tenant"] = self.tenant
        if self.checkpoint_every is not None:
            extra["recovery"] = {
                "checkpoint_every": self.checkpoint_every,
                "restores": self.restores,
                "evacuations": self.evacuations,
                **self.checkpointer.snapshot(),
            }
        return self._metrics.snapshot(
            dropped=self.dropped,
            queue_depth=self.queue_depth,
            kv_utilization=self.manager.utilization, **extra)

    def generate(self, prompt: list[int], max_new: int = 16) -> list[int]:
        """Convenience: run one sequence to completion."""
        seq = self.manager.admit(len(prompt), max_new)
        sid = seq.slot
        out: list[int] = []
        tok = prompt[0]
        for i, tok in enumerate(prompt):
            toks = self.tokens.at[sid, 0].set(tok)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
        for _ in range(max_new):
            nxt = int(jnp.argmax(logits[sid]))
            out.append(nxt)
            toks = self.tokens.at[sid, 0].set(nxt)
            logits, self.cache = self._decode(
                self.params, self.cache, toks, jnp.int32(self.pos))
            self.pos += 1
            if self.eos_id is not None and nxt == self.eos_id:
                break
        if seq.seq_id in self.manager.active:
            self.manager.release(seq.seq_id)
        return out
