"""Fault injection, retry policy, and backend degradation (DESIGN.md §11).

The resilience layer's three pieces, in one module so the serve path has
a single vocabulary for "what can go wrong and what we do about it":

* **Fault injection** — a seeded, deterministic :class:`FaultPlan` of
  :class:`FaultSpec` rules, installed process-wide exactly like the
  tracer (module slot + ``install``/``uninstall``; disabled is one
  global read).  Instrumented *sites* in the serve path call
  :func:`maybe_fault(site, **ctx)`; a matching spec raises the typed
  fault (``DeviceOOM``/``DeviceFault``/``CompileFault``/
  ``PreprocessFault``) or, for ``latency_spike``, sleeps through the
  plan's injectable ``sleep``.  Sites (the registry below) live in
  ``server.py`` (``server.preprocess``/``server.dispatch``/
  ``server.device``), ``engine.py`` (``engine.compile``),
  ``executor.py`` (``executor.call``) and ``lm_server.py``
  (``lm.step``).  Every decision is a function of (seed, per-spec call
  count) — the same plan replays the same faults, which is what makes
  the fault-matrix tests and the endurance storm reproducible.

* **Retry policy** — :class:`RetryPolicy`: capped exponential backoff
  with seeded jitter.  The *server* owns the clock; the policy only
  does the math, so backoff works identically under a fake clock.

* **Degradation ladder** — :data:`DEGRADE_LADDER` orders the serving
  backends fast-but-fragile → slow-but-safe (the executor's
  ``_FALLBACK`` chain extended to the ``xla`` floor).
  :class:`BackendHealth` demotes the serving mode after
  ``demote_after`` consecutive executable failures, quarantines the
  failed mode, and re-probes it after a (failure-doubling) interval —
  CNNdroid's lesson: mobile serving degrades to the safe path, it does
  not crash.

Everything here is host-side bookkeeping: nothing is ever traced, and
with no plan installed every site costs one module-global read.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.obs import metrics as _obs_metrics

# ---------------------------------------------------------------------------
# Fault taxonomy
# ---------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base of every injected fault; carries the site it fired at.

    ``transient`` distinguishes faults a retry may outlive (device OOM
    under memory pressure, a transient device fault) from deterministic
    ones (a compile error reproduces every attempt) — the retry policy
    retries both (capped), but the distinction is recorded for
    postmortems.
    """

    kind = "fault"
    transient = False

    def __init__(self, site: str, **ctx):
        self.site, self.ctx = site, dict(ctx)
        extra = f" ({ctx})" if ctx else ""
        super().__init__(f"injected {self.kind} at {site}{extra}")


class DeviceOOM(FaultError):
    """Device allocator refused the batch (transient under load)."""

    kind = "device_oom"
    transient = True


class DeviceFault(FaultError):
    """Generic transient device/executable failure."""

    kind = "device_fault"
    transient = True


class CompileFault(FaultError):
    """Executable build failed (deterministic: retries re-raise)."""

    kind = "compile_error"


class PreprocessFault(FaultError):
    """Host preprocessing of one payload raised."""

    kind = "preprocess_error"


class WatchdogTimeout(RuntimeError):
    """The dispatch watchdog expired waiting on a device readback."""


# ``latency_spike`` is the one non-raising kind: the site stalls for
# ``duration_s`` (through the plan's injectable sleep) and proceeds.
LATENCY_SPIKE = "latency_spike"
FAULT_KINDS: dict[str, type[FaultError]] = {
    cls.kind: cls
    for cls in (DeviceOOM, DeviceFault, CompileFault, PreprocessFault)}

#: The instrumented sites (DESIGN.md §11.1).  ``maybe_fault`` accepts
#: any site string, but plans targeting unknown sites never fire — the
#: constructor rejects them to catch typos.  ``kv.snapshot`` /
#: ``kv.restore`` instrument the crash-safe recovery path itself
#: (DESIGN.md §14.1): a snapshot fault skips (or invalidates) a
#: checkpoint, a restore fault burns one bounded resume attempt.
SITES = ("server.preprocess", "server.dispatch", "server.device",
         "engine.compile", "executor.call", "lm.step",
         "kv.snapshot", "kv.restore")


# ---------------------------------------------------------------------------
# Fault plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FaultSpec:
    """One injection rule: *where* (site + ctx match), *what* (kind),
    and *when* (deterministic schedule or seeded rate).

    Scheduling, evaluated against this spec's own eligible-call counter:

    * ``after``  — skip the first ``after`` eligible calls;
    * ``every``  — then fire on every ``every``-th call (default 1:
      every call), unless ``rate`` is set;
    * ``rate``   — fire i.i.d. with this probability (plan-seeded rng);
    * ``times``  — stop after this many fires (None = unlimited).

    ``match`` restricts eligibility to calls whose ctx carries the given
    values (e.g. ``{"mode": "vpu_chain"}`` faults only the fast backend,
    which is how the degradation tests leave the fallback path healthy).
    """

    site: str
    kind: str
    rate: float | None = None
    times: int | None = None
    after: int = 0
    every: int = 1
    duration_s: float = 0.05          # latency_spike stall
    match: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"want one of {SITES}")
        if self.kind != LATENCY_SPIKE and self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; want one of "
                f"{(*FAULT_KINDS, LATENCY_SPIKE)}")

    def eligible(self, ctx: dict) -> bool:
        return all(ctx.get(k) == v for k, v in self.match.items())

    def fires(self, n_eligible: int, n_fired: int,
              rng: np.random.Generator) -> bool:
        """Decide for eligible call ``n_eligible`` (0-based)."""
        if n_eligible < self.after:
            return False
        if self.times is not None and n_fired >= self.times:
            return False
        if self.rate is not None:
            return bool(rng.random() < self.rate)
        return (n_eligible - self.after) % self.every == 0


class FaultPlan:
    """A seeded set of :class:`FaultSpec` rules plus the injection log.

    ``sleep`` is what latency spikes stall through — tests inject a
    fake-clock advancer; the default is real ``time.sleep``.
    """

    def __init__(self, specs: list[FaultSpec] | tuple[FaultSpec, ...],
                 *, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        self.specs = list(specs)
        self.sleep = sleep
        self._rng = np.random.default_rng(seed)
        self._eligible = [0] * len(self.specs)
        self._fired = [0] * len(self.specs)
        self.log: list[dict] = []

    def fired(self, site: str | None = None) -> list[dict]:
        return [f for f in self.log if site is None or f["site"] == site]

    def check(self, site: str, **ctx) -> None:
        """Evaluate every spec against one site call; raises the first
        matching fault (latency spikes stall and keep evaluating)."""
        for i, spec in enumerate(self.specs):
            if spec.site != site or not spec.eligible(ctx):
                continue
            n = self._eligible[i]
            self._eligible[i] += 1
            if not spec.fires(n, self._fired[i], self._rng):
                continue
            self._fired[i] += 1
            entry = dict(site=site, kind=spec.kind, call=n, **ctx)
            self.log.append(entry)
            reg = _obs_metrics.get_registry()
            reg.counter("faults.injected").inc()
            reg.event("fault", **entry)
            if spec.kind == LATENCY_SPIKE:
                self.sleep(spec.duration_s)
                continue
            raise FAULT_KINDS[spec.kind](site, **ctx)


# Module slot, same shape as the tracer's: disabled sites cost one
# global read (call sites guard with ``if faults._PLAN is not None``).
_PLAN: FaultPlan | None = None


def install(plan: FaultPlan) -> FaultPlan:
    global _PLAN
    _PLAN = plan
    return plan


def uninstall() -> None:
    global _PLAN
    _PLAN = None


def get_plan() -> FaultPlan | None:
    return _PLAN


def maybe_fault(site: str, **ctx) -> None:
    """The one injection hook every instrumented site calls."""
    plan = _PLAN
    if plan is not None:
        plan.check(site, **ctx)


@contextlib.contextmanager
def inject(specs: FaultPlan | list[FaultSpec] | tuple[FaultSpec, ...],
           **kw):
    """Scoped installation (tests / the endurance storm)."""
    plan = specs if isinstance(specs, FaultPlan) else FaultPlan(specs, **kw)
    install(plan)
    try:
        yield plan
    finally:
        uninstall()


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RetryPolicy:
    """Capped exponential backoff with seeded jitter (DESIGN.md §11.2).

    ``max_attempts`` counts *total* tries (1 = no retry).  The delay
    before retry ``k`` (first retry is ``k=1``) is::

        min(base * 2**(k-1), cap) * (1 + jitter * U[-1, 1))

    The policy only does the math — the server applies the delay on its
    own (injectable) clock by stamping ``Request.not_before``, so fake
    clocks see exactly the same schedule as real ones.
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 1.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError("jitter must be in [0, 1)")
        self._rng = np.random.default_rng(self.seed)

    def backoff_s(self, attempt: int) -> float:
        exp = min(self.backoff_base_s * 2.0 ** (max(attempt, 1) - 1),
                  self.backoff_cap_s)
        if not self.jitter:
            return exp
        return exp * (1.0 + self.jitter * (2.0 * self._rng.random() - 1.0))


# ---------------------------------------------------------------------------
# Backend degradation ladder
# ---------------------------------------------------------------------------

#: Serving modes ordered fast-but-fragile → slow-but-safe: the
#: executor's ``_FALLBACK`` chain extended down to the pure-XLA floor.
#: Every rung computes the identical binarized network (each is
#: cross-checked bit-exact against its own oracle, DESIGN.md §4.5); the
#: pm1-family vs xor-family rungs may differ in the *float epilogue's*
#: last-ulp accumulation order, so a demotion changes latency — and at
#: most float associativity — never the packed computation.
DEGRADE_LADDER = ("vpu_chain", "vpu_direct_pool", "vpu_direct",
                  "vpu_popcount", "xla_pm1", "xla")


def ladder_rank(mode: str) -> int:
    """Position in the ladder; modes outside it (``auto``, ``mxu_pm1``)
    rank above everything — their one demotion is straight to the
    floor, and a successful re-probe restores them."""
    try:
        return DEGRADE_LADDER.index(mode)
    except ValueError:
        return -1


def demote_mode(mode: str) -> str | None:
    """The next-safer serving mode; None at the ``xla`` floor."""
    if mode == DEGRADE_LADDER[-1]:
        return None
    rank = ladder_rank(mode)
    if rank < 0:
        return DEGRADE_LADDER[-1]
    return DEGRADE_LADDER[rank + 1]


class BackendHealth:
    """Tracks the live serving mode through failures, demotions,
    quarantine, and re-probe (DESIGN.md §11.3).

    * ``record_failure`` — one executable failure at the current mode;
      after ``demote_after`` consecutive ones the mode is quarantined
      (until now + its probe interval, doubling on each re-offense) and
      the ladder's next mode becomes current.  Returns the new mode on
      demotion, else None.
    * ``record_success`` — resets the consecutive-failure count.
    * ``probe_due`` — the best quarantined mode whose quarantine has
      expired (to try ahead of the current one), if any.
    * ``promote`` / ``probe_failed`` — resolve a probe: adopt the probed
      mode, or re-quarantine it with a doubled interval.
    """

    def __init__(self, mode: str, *, demote_after: int = 2,
                 probe_after_s: float = 30.0, probe_backoff: float = 2.0):
        if demote_after < 1:
            raise ValueError("demote_after must be >= 1")
        self.mode = mode
        self.demote_after = demote_after
        self.probe_after_s = probe_after_s
        self.probe_backoff = probe_backoff
        self._consecutive = 0
        # mode -> (quarantined-until, current interval)
        self._quarantine: dict[str, tuple[float, float]] = {}
        self.demotions: list[dict] = []

    # ---- failure accounting ----------------------------------------------
    def record_failure(self, now: float) -> str | None:
        self._consecutive += 1
        if self._consecutive < self.demote_after:
            return None
        return self._demote(now)

    def record_success(self) -> None:
        self._consecutive = 0

    def _demote(self, now: float) -> str | None:
        self._consecutive = 0
        nxt = demote_mode(self.mode)
        if nxt is None:                       # already at the floor
            return None
        self._quarantine_mode(self.mode, now)
        old, self.mode = self.mode, nxt
        self.demotions.append(dict(t=now, from_mode=old, to_mode=nxt))
        return nxt

    def _quarantine_mode(self, mode: str, now: float) -> None:
        prev = self._quarantine.get(mode)
        interval = (prev[1] * self.probe_backoff if prev
                    else self.probe_after_s)
        self._quarantine[mode] = (now + interval, interval)

    # ---- re-probe ---------------------------------------------------------
    def probe_due(self, now: float) -> str | None:
        best: str | None = None
        for mode, (until, _) in self._quarantine.items():
            if now < until or ladder_rank(mode) >= ladder_rank(self.mode):
                continue
            if best is None or ladder_rank(mode) < ladder_rank(best):
                best = mode
        return best

    def promote(self, mode: str) -> None:
        self._quarantine.pop(mode, None)
        self.mode = mode
        self._consecutive = 0

    def probe_failed(self, mode: str, now: float) -> None:
        self._quarantine_mode(mode, now)

    def snapshot(self, now: float) -> dict:
        return {
            "mode": self.mode,
            "demotions": len(self.demotions),
            "quarantined": {m: max(0.0, until - now)
                            for m, (until, _) in self._quarantine.items()},
        }


class BucketHealth:
    """Per-``(bucket, mode)`` degradation ladders (DESIGN.md §14.3).

    PR 7's :class:`BackendHealth` tracked one ladder for the whole
    server, so a single pathological bucket shape (one batch size whose
    tile config trips the fast backend) demoted *every* bucket to the
    safe path.  This registry scopes the whole ladder protocol —
    consecutive-failure demotion, quarantine, re-probe, promotion — to
    the offending bucket: each compiled batch bucket gets its own
    :class:`BackendHealth`, created lazily at first dispatch, while the
    other buckets keep serving their fast backend untouched.

    The aggregate views (``mode`` = the most-demoted bucket's current
    mode, ``demotions`` = the chronological union with each entry
    stamped with its ``bucket``) keep the PR 7 introspection surface —
    ``server.health.mode`` / ``server.health.demotions`` — meaningful
    for callers that want one number.
    """

    def __init__(self, mode: str, *, demote_after: int = 2,
                 probe_after_s: float = 30.0, probe_backoff: float = 2.0):
        self.base_mode = mode
        self._kw = dict(demote_after=demote_after,
                        probe_after_s=probe_after_s,
                        probe_backoff=probe_backoff)
        self.ladders: dict[int, BackendHealth] = {}

    def ladder(self, bucket: int) -> BackendHealth:
        """The (lazily created) ladder for one batch bucket."""
        lad = self.ladders.get(bucket)
        if lad is None:
            lad = self.ladders[bucket] = BackendHealth(self.base_mode,
                                                       **self._kw)
        return lad

    # ---- the BackendHealth protocol, bucket-scoped ------------------------
    def mode_for(self, bucket: int) -> str:
        lad = self.ladders.get(bucket)
        return lad.mode if lad is not None else self.base_mode

    def record_failure(self, bucket: int, now: float) -> str | None:
        lad = self.ladder(bucket)
        demoted = lad.record_failure(now)
        if demoted is not None:
            lad.demotions[-1]["bucket"] = bucket
        return demoted

    def record_success(self, bucket: int) -> None:
        lad = self.ladders.get(bucket)
        if lad is not None:
            lad.record_success()

    def probe_due(self, bucket: int, now: float) -> str | None:
        lad = self.ladders.get(bucket)
        return lad.probe_due(now) if lad is not None else None

    def promote(self, bucket: int, mode: str) -> None:
        self.ladder(bucket).promote(mode)

    def probe_failed(self, bucket: int, mode: str, now: float) -> None:
        self.ladder(bucket).probe_failed(mode, now)

    # ---- aggregate views --------------------------------------------------
    @property
    def mode(self) -> str:
        """The most-demoted bucket's current mode (the server's
        worst-case serving rung); ``base_mode`` when nothing demoted."""
        worst = self.base_mode
        for lad in self.ladders.values():
            if ladder_rank(lad.mode) > ladder_rank(worst):
                worst = lad.mode
        return worst

    @property
    def demotions(self) -> list[dict]:
        """Chronological union of every bucket's demotion log, each
        entry carrying its ``bucket``."""
        rows = [d for lad in self.ladders.values() for d in lad.demotions]
        return sorted(rows, key=lambda d: d["t"])

    def snapshot(self, now: float) -> dict:
        return {
            "mode": self.mode,
            "demotions": len(self.demotions),
            "buckets": {b: lad.snapshot(now)
                        for b, lad in sorted(self.ladders.items())},
        }
