"""AOT executable artifacts: pay the compile cost offline (DESIGN.md §12).

PhoneBit's deployment story (Fig 2) is that everything expensive — layout,
layer integration, kernel selection — happens once, offline, and the
device only ever runs the optimized binary path.  The serving stack
honors that for *tracing* (per-bucket executable cache) but still pays
full trace + XLA compile on every process boot.  This module closes the
gap: :func:`export_artifact` serializes every compiled bucket executable
via JAX AOT (``jax.jit(...).lower(...).compile()`` +
``jax.experimental.serialize_executable``) into one versioned directory,
together with the autotune winner table, the backend/memory report, and
a provenance meta block; :func:`load_artifact` restores them into an
engine's per-bucket executable cache with **zero serve-time traces**
(``engine.trace_count == 0`` after load — the executables never pass
through ``jax.jit`` tracing at all).

Artifact layout (one directory)::

    artifact/
      meta.json        schema + provenance + compat fields + bucket index
      autotune.json    the winner table (exact + batchless + chain:: keys)
      b{N}.fwd.bin     pickled (payload, in_tree, out_tree) per bucket
      b{N}.head.bin    the workload postprocess head, when exported

Compatibility policy (DESIGN.md §12.2): *environment* mismatches —
artifact schema version, device kind, jax/jaxlib version, engine mode,
graph fingerprint, donation/data-parallel flags — degrade **per bucket**
to the live compile path, each recorded as a structured ``artifact``
event with ``outcome="miss"`` and counted on ``artifact.miss`` (boot
still succeeds, just slower).  *Integrity* failures — checksum mismatch,
unpicklable or undeserializable executable bytes — raise a clean
:class:`ArtifactError` instead of handing corrupt bytes to XLA.

Export is restricted to ``data_parallel == 1`` executables: a sharded
executable bakes in the exporting host's device mesh, which is exactly
the kind of silent environment coupling the meta block exists to refuse.
"""

from __future__ import annotations

import hashlib
import json
import pathlib
import pickle
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _trace
from repro.serving import faults as _faults

ARTIFACT_SCHEMA = "phonebit-aot-v1"
_META = "meta.json"
_AUTOTUNE = "autotune.json"

#: The meta fields a loading process must match bucket-for-bucket; a
#: mismatch on any of them is a per-bucket ``artifact.miss`` (DESIGN.md
#: §12.2), never an error.
COMPAT_FIELDS = ("schema", "device_kind", "jax", "mode", "fingerprint",
                 "donate_input", "data_parallel")


class ArtifactError(RuntimeError):
    """An artifact is unreadable or fails integrity checks (corrupted
    executable bytes, bad checksum, missing files).  Environment
    mismatches are NOT errors — they fall back per bucket."""


# ---------------------------------------------------------------------------
# meta / fingerprints
# ---------------------------------------------------------------------------

def _device_kind() -> str:
    try:
        return f"{jax.default_backend()}:{jax.devices()[0].device_kind}"
    except (IndexError, RuntimeError):
        return jax.default_backend()


def graph_fingerprint(graph) -> str:
    """Stable digest of the serving graph's *structure*: ops, static
    attrs, edges, and parameter shapes/dtypes (not values — the artifact
    stores executables, weights stay live operands).  A code change that
    alters lowering changes the fingerprint, so a stale artifact misses
    instead of feeding a mismatched operand pytree to a frozen
    executable."""
    rows = []
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        attrs = tuple(sorted(
            (k, v) for k, v in node.attrs.items()
            if isinstance(v, (int, bool, str, tuple))))
        pshapes = []
        for k, v in sorted(node.params.items()):
            if hasattr(v, "_fields"):           # IntegratedParams
                for f in v._fields:
                    fv = getattr(v, f)
                    pshapes.append((k + "." + f, tuple(np.shape(fv)),
                                    str(np.asarray(fv).dtype)))
            else:
                pshapes.append((k, tuple(np.shape(v)),
                                str(np.asarray(v).dtype)))
        rows.append((nid, node.op, attrs, tuple(node.inputs),
                     tuple(pshapes)))
    return hashlib.sha256(repr(rows).encode()).hexdigest()[:16]


def _env_meta(engine, *, donate_input: bool, data_parallel: int) -> dict:
    from repro.obs.provenance import provenance_meta

    return {
        "schema": ARTIFACT_SCHEMA,
        "device_kind": _device_kind(),
        "jax": jax.__version__,
        "mode": engine.matmul_mode,
        "fingerprint": graph_fingerprint(engine._graph),
        "donate_input": bool(donate_input),
        "data_parallel": int(data_parallel),
        "input_hw": list(engine.input_hw),
        "provenance": provenance_meta(),
    }


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# serialization primitives
# ---------------------------------------------------------------------------

def _serialize_compiled(compiled, path: pathlib.Path) -> str:
    """Serialize one AOT-compiled executable (payload + arg pytree defs)
    to ``path``; returns its sha256."""
    from jax.experimental import serialize_executable as _se

    payload, in_tree, out_tree = _se.serialize(compiled)
    with open(path, "wb") as f:
        pickle.dump({"payload": payload, "in_tree": in_tree,
                     "out_tree": out_tree}, f)
    return _sha256(path)


def _deserialize_compiled(path: pathlib.Path, want_sha: str):
    """Integrity-checked inverse of :func:`_serialize_compiled`.  Any
    failure — checksum, unpickling, XLA deserialization — surfaces as
    :class:`ArtifactError` before corrupt bytes reach the runtime."""
    from jax.experimental import serialize_executable as _se

    if not path.exists():
        raise ArtifactError(f"artifact executable missing: {path}")
    got_sha = _sha256(path)
    if got_sha != want_sha:
        raise ArtifactError(
            f"artifact executable corrupted: {path.name} sha256 "
            f"{got_sha[:12]} != recorded {want_sha[:12]}")
    try:
        with open(path, "rb") as f:
            blob = pickle.load(f)
        return _se.deserialize_and_load(
            blob["payload"], blob["in_tree"], blob["out_tree"])
    except ArtifactError:
        raise
    except Exception as e:              # noqa: BLE001 — wrap, never abort
        raise ArtifactError(
            f"artifact executable undeserializable: {path.name}: "
            f"{type(e).__name__}: {e}") from e


class AotExecutor:
    """A deserialized bucket executable behind the GraphExecutor serve
    surface (``__call__`` / ``arrays`` / ``trace_count``).

    ``trace_count`` is a constant 0 and can never increment: the
    executable was compiled offline and restored without tracing — this
    is the pin the zero-warmup tests assert end to end."""

    trace_count = 0

    def __init__(self, compiled: Callable, arrays: dict,
                 head: Callable | None = None, *, bucket: int,
                 donate_input: bool = False):
        self._compiled = compiled
        self._head = head
        self.arrays = arrays
        self.bucket = bucket
        self.donate_input = donate_input

    def __call__(self, x) -> jnp.ndarray:
        if _faults._PLAN is not None:
            _faults.maybe_fault("executor.call", bucket=self.bucket,
                                aot=True)
        out = self._compiled(self.arrays, x)
        if self._head is not None:
            out = self._head(out)
        return out


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def export_artifact(engine, path, buckets=(1, 2, 4, 8), *,
                    donate_input: bool = True,
                    head_fn: Callable | None = None,
                    workload: str | None = None) -> dict:
    """Serialize one AOT executable per bucket into directory ``path``.

    ``engine`` is a :class:`~repro.serving.engine.PhoneBitEngine`
    (:meth:`WorkloadEngine.export_artifact` passes its postprocess head
    as ``head_fn``, exported per bucket at the forward output shape so a
    loaded workload serves decoded predictions trace-free too).  The
    engine is compiled (and, under ``matmul_mode="auto"``, autotuned)
    live first — export is the *offline* half of the split, so paying
    trace/compile/tune here is the point.  Returns the meta block.
    """
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    meta = _env_meta(engine, donate_input=donate_input, data_parallel=1)
    meta["workload"] = workload
    meta["buckets"] = {}
    report: dict[str, Any] = {}
    for bs in sorted(set(int(b) for b in buckets)):
        with _trace.span("artifact.export", "artifact", bucket=bs):
            exe = engine.compile(bs, donate_input=donate_input)
            x_sds = jax.ShapeDtypeStruct(engine._plan_shape(bs), jnp.uint8)
            lowered = exe._jitted.lower(exe.arrays, x_sds)
            entry = {"file": f"b{bs}.fwd.bin"}
            entry["sha256"] = _serialize_compiled(
                lowered.compile(), path / entry["file"])
            if head_fn is not None:
                out_info = lowered.out_info
                y_sds = jax.ShapeDtypeStruct(out_info.shape, out_info.dtype)
                entry["head_file"] = f"b{bs}.head.bin"
                entry["head_sha256"] = _serialize_compiled(
                    jax.jit(head_fn).lower(y_sds).compile(),
                    path / entry["head_file"])
            meta["buckets"][str(bs)] = entry
        report[str(bs)] = {"backends": exe.backend_report()}
    meta["report"] = report
    # The autotune winner table rides along (T-MAC's --reuse-tuned): a
    # loader whose environment misses a bucket still warm-starts its
    # live-compile fallback from these winners instead of re-timing.
    tuner = getattr(engine, "_tuner", None)
    if tuner is not None and (tuner.cache or tuner.agnostic_cache):
        with open(path / _AUTOTUNE, "w") as f:
            json.dump({**tuner.cache, **tuner.agnostic_cache}, f, indent=1,
                      sort_keys=True)
    with open(path / _META, "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    return meta


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------

def read_meta(path) -> dict:
    path = pathlib.Path(path)
    meta_path = path / _META
    if not meta_path.exists():
        raise ArtifactError(f"not an artifact directory: {path} "
                            f"(missing {_META})")
    try:
        with open(meta_path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ArtifactError(f"unreadable artifact meta: {e}") from e


def compat_mismatches(meta: dict, engine, *, donate_input: bool,
                      data_parallel: int) -> list[str]:
    """Which :data:`COMPAT_FIELDS` differ between the artifact and this
    process/engine (empty list = fully compatible)."""
    want = _want_env(engine, donate_input=donate_input,
                     data_parallel=data_parallel)
    return [f"{k}: artifact={meta.get(k)!r} != here={want[k]!r}"
            for k in COMPAT_FIELDS if meta.get(k) != want[k]]


def _want_env(engine, *, donate_input: bool, data_parallel: int) -> dict:
    return {
        "schema": ARTIFACT_SCHEMA,
        "device_kind": _device_kind(),
        "jax": jax.__version__,
        "mode": engine.matmul_mode,
        "fingerprint": graph_fingerprint(engine._graph),
        "donate_input": bool(donate_input),
        "data_parallel": int(data_parallel),
    }


def _miss(bucket: int, reasons: list[str]) -> None:
    reg = _obs_metrics.get_registry()
    reg.counter("artifact.miss").inc()
    reg.event("artifact", outcome="miss", bucket=bucket,
              reasons=list(reasons))
    _trace.instant("artifact.miss", "artifact", bucket=bucket)


def _hit(bucket: int) -> None:
    reg = _obs_metrics.get_registry()
    reg.counter("artifact.hit").inc()
    reg.event("artifact", outcome="hit", bucket=bucket)


def load_autotune_table(path, tuner) -> int:
    """Merge the artifact's winner table into a tuner's caches (entries
    already present win; stale-environment entries are skipped exactly
    like the disk cache's).  Returns how many entries were adopted."""
    from repro.runtime.autotune import entry_env_ok

    path = pathlib.Path(path) / _AUTOTUNE
    if not path.exists():
        return 0
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, json.JSONDecodeError):
        return 0
    adopted = 0
    for key, entry in table.items():
        if not entry_env_ok(entry):
            continue
        store = (tuner.agnostic_cache if key.startswith("batchless::")
                 else tuner.cache)
        if key not in store:
            store[key] = entry
            adopted += 1
    return adopted


def load_artifact(engine, path, *, donate_input: bool = True,
                  data_parallel: int = 1, buckets=None,
                  head: bool = False) -> dict:
    """Restore AOT bucket executables from ``path`` into ``engine``'s
    per-bucket executable cache.

    Per-bucket protocol (DESIGN.md §12.2): environment mismatch →
    structured ``artifact.miss`` event + live-compile fallback on first
    use; integrity failure → :class:`ArtifactError`.  Returns
    ``{"loaded": [buckets], "missed": {bucket: [reasons]},
    "autotune_entries": n}``.  With ``head=True`` the workload
    postprocess head is deserialized per bucket and composed onto the
    forward executable (:class:`AotExecutor`).
    """
    path = pathlib.Path(path)
    meta = read_meta(path)
    mismatches = compat_mismatches(meta, engine, donate_input=donate_input,
                                   data_parallel=data_parallel)
    tuner = getattr(engine, "_tuner", None)
    adopted = load_autotune_table(path, tuner) if tuner is not None else 0
    want = ({int(b) for b in buckets} if buckets is not None else None)
    loaded: list[int] = []
    missed: dict[int, list[str]] = {}
    arrays = None
    for bs_key, entry in sorted(meta.get("buckets", {}).items(),
                                key=lambda kv: int(kv[0])):
        bs = int(bs_key)
        if want is not None and bs not in want:
            continue
        reasons = list(mismatches)
        if head and "head_file" not in entry:
            reasons.append("head: artifact has no postprocess head")
        if reasons:
            missed[bs] = reasons
            _miss(bs, reasons)
            continue
        with _trace.span("artifact.load", "artifact", bucket=bs):
            compiled = _deserialize_compiled(path / entry["file"],
                                             entry["sha256"])
            head_fn = None
            if head and "head_file" in entry:
                head_fn = _deserialize_compiled(path / entry["head_file"],
                                                entry["head_sha256"])
            if arrays is None:
                # Traced operands come from the *live* engine (weights
                # are data, not part of the executable); building the
                # operand pytree lowers the graph host-side — no jit,
                # no traces.
                arrays = {str(nid): dict(n.params)
                          for nid, n in engine._graph.nodes.items()
                          if n.params}
            exe = AotExecutor(compiled, arrays, head_fn, bucket=bs,
                              donate_input=donate_input)
        engine._install_executable(bs, exe, donate_input=donate_input,
                                   data_parallel=data_parallel)
        loaded.append(bs)
        _hit(bs)
    return {"loaded": loaded, "missed": missed,
            "autotune_entries": adopted, "workload": meta.get("workload")}
