"""KV-cache slot manager for continuous-batching LM decode.

The decode step operates on a fixed (B_slots, max_seq) cache; this manager
owns the slot lifecycle: admit a sequence into a free slot after prefill,
track its length, and release it on EOS/eviction.  It is deliberately a
host-side bookkeeping object — the cache *data* lives sharded on device
(sequence dim over the model axis, flash-decoding SP) and is mutated by
the jitted steps; the manager only decides which slots participate.

This is the "paged-lite" design point: slots are page-granularity-1
(whole sequences).  True paged attention (block tables) is noted in
DESIGN.md as the extension for production memory efficiency.
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class Sequence:
    seq_id: int
    slot: int
    length: int
    max_new: int
    generated: int = 0
    tokens: list = dataclasses.field(default_factory=list)
    # Retained so a checkpointed sequence can be replay-prefilled on a
    # different lane (cross-replica migration, DESIGN.md §14.4).  The
    # in-lane restore path never needs it — KV pages carry the prefix.
    prompt: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class KVCacheManager:
    n_slots: int
    max_seq: int

    def __post_init__(self):
        self._free = list(range(self.n_slots - 1, -1, -1))
        self.active: dict[int, Sequence] = {}
        self._next_id = 0

    # ---- admission ---------------------------------------------------------
    def can_admit(self) -> bool:
        return bool(self._free)

    def admit(self, prompt_len: int, max_new: int,
              prompt: list | None = None) -> Sequence:
        assert self._free, "no free KV slots"
        assert prompt_len + max_new <= self.max_seq, "sequence too long"
        slot = self._free.pop()
        seq = Sequence(self._next_id, slot, prompt_len, max_new,
                       prompt=list(prompt) if prompt is not None else [])
        self._next_id += 1
        self.active[seq.seq_id] = seq
        return seq

    def adopt(self, length: int, max_new: int, generated: int,
              tokens: list, prompt: list | None = None) -> Sequence:
        """Admit a *restored* sequence — one that already generated
        tokens on this or another lane — into a fresh slot (crash
        recovery / migration, DESIGN.md §14).  The caller is
        responsible for rebuilding the slot's KV pages (page write-back
        for in-lane restore, replay prefill for migration)."""
        assert self._free, "no free KV slots"
        assert length + (max_new - generated) <= self.max_seq, \
            "sequence too long"
        assert 0 < generated <= max_new and len(tokens) == generated
        slot = self._free.pop()
        seq = Sequence(self._next_id, slot, length, max_new,
                       generated=generated, tokens=list(tokens),
                       prompt=list(prompt) if prompt is not None else [])
        self._next_id += 1
        self.active[seq.seq_id] = seq
        return seq

    # ---- stepping ------------------------------------------------------------
    def record_token(self, seq_id: int, token: int,
                     eos_id: int | None = None) -> bool:
        """Append one generated token; returns True if the seq finished."""
        seq = self.active[seq_id]
        seq.tokens.append(token)
        seq.length += 1
        seq.generated += 1
        done = (seq.generated >= seq.max_new
                or (eos_id is not None and token == eos_id)
                or seq.length >= self.max_seq)
        if done:
            self.release(seq_id)
        return done

    def release(self, seq_id: int) -> None:
        seq = self.active.pop(seq_id)
        self._free.append(seq.slot)

    # ---- views -----------------------------------------------------------------
    @property
    def utilization(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def active_slots(self) -> list[int]:
        return [s.slot for s in self.active.values()]
