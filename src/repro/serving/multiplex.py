"""Multi-tenant workload multiplexing (DESIGN.md §12).

One front end serving several models (AlexNet + VGG16 + YOLOv2-Tiny
behind one process) without letting any tenant starve or poison the
others.  The design composes rather than rewrites: each tenant gets a
full :class:`~repro.serving.server.InferenceServer` **lane** — its own
scheduler, bucket pool, retry policy, :class:`BackendHealth` ladder and
flight recorder — and :class:`MultiTenantServer` arbitrates which lane
may *dispatch* each tick.  Composition buys the hard isolation
properties for free:

* **degradation isolation** — a demotion on one model's buckets lives
  in that lane's ``BackendHealth`` and cannot demote another lane;
* **per-tenant observability** — every lane's metrics snapshot and
  flight-recorder records are stamped with its tenant name
  (``InferenceServer(tenant=...)``);
* **failure isolation** — a faulted batch retries/errors inside its
  lane; the arbiter never sees the exception.

Admission across lanes is **strict priority, then weighted-fair**:

* lanes with a higher ``priority`` class always dispatch first (a
  latency-critical detector over a batch classifier; a saturated
  high-priority lane can starve lower classes — that is the contract);
* within a class, lanes are served by smallest virtual time, charged
  ``dispatched_rows / weight`` per dispatch (padded bucket rows — what
  the accelerator actually paid for), so long-run device rows split
  proportionally to ``weight`` under saturation regardless of request
  sizes or bucket shapes;
* a lane waking from idle has its vtime caught up to the arbiter's
  clock, so an idle tenant banks no credit it could later burst with.

Non-chosen lanes still run their housekeeping half each tick
(``step(dispatch=False)``): shedding expired requests and retiring
in-flight batches is never gated on winning admission.
"""

from __future__ import annotations

import time
from typing import Any, Callable

from repro.serving.scheduler import Request
from repro.serving.server import InferenceServer


class TenantLane:
    """One tenant behind the arbiter: its server plus fairness state."""

    __slots__ = ("name", "server", "weight", "priority", "vtime")

    def __init__(self, name: str, server: InferenceServer, weight: float,
                 priority: int, vtime: float):
        self.name = name
        self.server = server
        self.weight = weight
        self.priority = priority
        # Virtual time: cumulative dispatched rows / weight.  The lane
        # with the smallest vtime in the top priority class dispatches.
        self.vtime = vtime


class MultiTenantServer:
    """Weighted-fair multiplexer over per-tenant InferenceServer lanes.

    Speaks the same ``submit`` / ``poll`` / ``step`` / ``drain`` /
    ``metrics`` protocol as a single server, with ``submit`` taking the
    tenant name first.  Keyword arguments to the constructor become
    defaults for every lane's ``InferenceServer`` (per-tenant kwargs to
    :meth:`add_tenant` override them — including ``artifact=`` for
    lanes restored from AOT artifacts).
    """

    def __init__(self, *, clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] | None = None,
                 **default_server_kw):
        self.clock = clock
        self._sleep = sleep if sleep is not None \
            else (lambda s: time.sleep(min(s, 0.05)))
        self._default_kw = dict(default_server_kw)
        self.lanes: dict[str, TenantLane] = {}
        # Arbiter virtual clock: the largest vtime ever charged.  Lanes
        # waking from idle catch up to it (no banked credit).
        self._v = 0.0

    # ---- tenant registration ---------------------------------------------
    def add_tenant(self, name: str, engine, *, weight: float = 1.0,
                   priority: int = 0, **server_kw) -> InferenceServer:
        """Register a tenant: builds its lane's ``InferenceServer`` over
        ``engine`` (higher ``priority`` = served first; ``weight`` sets
        the fair share within a priority class)."""
        if name in self.lanes:
            raise ValueError(f"tenant {name!r} already registered")
        if weight <= 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        kw = {**self._default_kw, **server_kw}
        kw.setdefault("clock", self.clock)
        server = InferenceServer(engine, tenant=name, **kw)
        self.lanes[name] = TenantLane(name, server, float(weight),
                                      int(priority), self._v)
        return server

    def add_workload(self, name: str, workload, **kw) -> InferenceServer:
        """Register a :class:`~repro.workloads.workload.Workload` as a
        tenant (wires its preprocess hook and WorkloadEngine)."""
        kw.setdefault("preprocess", workload.preprocess_hook)
        return self.add_tenant(name, workload.engine, **kw)

    def _lane(self, tenant: str) -> TenantLane:
        if tenant not in self.lanes:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"have {sorted(self.lanes)}")
        return self.lanes[tenant]

    def server(self, tenant: str) -> InferenceServer:
        return self._lane(tenant).server

    # ---- request lifecycle ------------------------------------------------
    def submit(self, tenant: str, payload: Any, **kw) -> Request:
        lane = self._lane(tenant)
        srv = lane.server
        if not len(srv.scheduler) and srv._pending is None:
            # Idle-lane catch-up: competing starts from the arbiter's
            # clock, not from vtime banked while the lane had no work.
            lane.vtime = max(lane.vtime, self._v)
        return srv.submit(payload, **kw)

    def poll(self, request: Request) -> bool:
        return request.done

    # ---- arbitration ------------------------------------------------------
    def _pick(self, now: float) -> TenantLane | None:
        """The lane allowed to dispatch this tick: top priority class,
        then smallest vtime (name-ordered tiebreak for determinism).
        A lane whose whole queue is in retry backoff is not ready —
        it would win, dispatch nothing, never be charged, and win
        every following tick, starving lanes with eligible work."""
        def _eligible(l: TenantLane) -> bool:
            if not len(l.server.scheduler):
                return False
            wait = l.server.scheduler.backoff_wait(now)
            return wait is None or wait <= 0

        ready = [l for l in self.lanes.values() if _eligible(l)]
        if not ready:
            return None
        top = max(l.priority for l in ready)
        return min((l for l in ready if l.priority == top),
                   key=lambda l: (l.vtime, l.name))

    def step(self, now: float | None = None,
             force: bool = False) -> list[Request]:
        """One multiplexed tick: the arbitration winner runs a full
        serving step (and is charged for what it dispatched); every
        other lane runs housekeeping only.  Returns all requests
        completed this tick, across lanes."""
        now = self.clock() if now is None else now
        chosen = self._pick(now)
        done: list[Request] = []
        for lane in self.lanes.values():
            if lane is chosen:
                before = lane.server.dispatched_rows
                done += lane.server.step(now, force=force)
                delta = lane.server.dispatched_rows - before
                if delta:
                    lane.vtime += delta / lane.weight
                    self._v = max(self._v, lane.vtime)
            else:
                done += lane.server.step(now, dispatch=False)
        return done

    # ---- drain ------------------------------------------------------------
    def _busy(self) -> bool:
        return any(len(l.server.scheduler) or l.server._pending is not None
                   for l in self.lanes.values())

    def drain(self, now: float | None = None,
              max_steps: int | None = None) -> list[Request]:
        """Serve until every lane's queue is empty and nothing is in
        flight.  Bounded like ``InferenceServer.drain``: past
        ``max_steps`` each lane terminally errors its stragglers."""
        if max_steps is None:
            budget = max([(l.server.retry.max_attempts if l.server.retry
                           else 1) for l in self.lanes.values()] or [1])
            queued = sum(len(l.server.scheduler)
                         for l in self.lanes.values())
            max_steps = 4 * (queued + 2 * max(len(self.lanes), 1) + 2) \
                * budget + 16
        done: list[Request] = []
        steps = 0
        while self._busy():
            if steps >= max_steps:
                t = self.clock() if now is None else now
                for lane in self.lanes.values():
                    done += lane.server._abort_wedged(t)
                break
            steps += 1
            t = self.clock() if now is None else now
            done += self.step(t, force=True)
            if all(l.server._pending is None for l in self.lanes.values()):
                # Starved purely by retry backoff: wait out the soonest.
                queued_lanes = [l for l in self.lanes.values()
                                if len(l.server.scheduler)]
                waits = [l.server.scheduler.backoff_wait(t)
                         for l in queued_lanes]
                if queued_lanes and all(w is not None and w > 0
                                        for w in waits):
                    self._sleep(min(waits))
        return done

    # ---- observability ----------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return sum(l.server.queue_depth for l in self.lanes.values())

    def metrics(self) -> dict:
        """Per-tenant ``InferenceServer`` snapshots plus the fairness
        ledger (weight / priority / vtime / device rows dispatched)."""
        return {
            "tenants": {name: lane.server.metrics()
                        for name, lane in self.lanes.items()},
            "fairness": {name: {"weight": lane.weight,
                                "priority": lane.priority,
                                "vtime": round(lane.vtime, 6),
                                "dispatched_rows":
                                    lane.server.dispatched_rows}
                         for name, lane in self.lanes.items()},
            "queue_depth": self.queue_depth,
        }
