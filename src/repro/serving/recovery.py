"""Crash-safe serving primitives (DESIGN.md §14).

Three independent mechanisms, composed by the servers:

**KV checkpointing** (:class:`KVCheckpointer`) — consistent-cut
device→host snapshots of the LM decode state.  A checkpoint captures
*every* active sequence at one global position ``P``: per-slot KV cache
pages, the ``Sequence`` bookkeeping, and the token register (the last
generated token whose K/V has *not* yet been written — the next decode
tick writes it).  Because ``LMServer`` re-checkpoints after every
admission batch, the window between checkpoints contains only pure
decode ticks, so every surviving sequence has exactly
``m = pos_now − P`` uncheckpointed tokens and lockstep force-fed replay
of those ``m`` ticks reproduces the cache — and hence every subsequent
token — bit-exactly (§14.2 has the argument).  Snapshots are host-async
(``copy_to_host_async``): taking one enqueues D2H copies and returns;
the decode loop never blocks on them.

**Durable request journal** (:class:`RequestJournal`) — an append-only
JSONL write-ahead log of submit/resolve records.  Accepted submissions
are journaled *before* they are enqueued (WAL order), terminal
resolutions are journaled as they happen, and each append is fsynced —
so after a hard crash (kill -9), :func:`replay_journal` can scan the
log, find every submit without a matching resolve, and resubmit it to a
fresh server booted from a PR 8 artifact.  The scan tolerates a torn
tail (a half-written last line is exactly what a kill mid-append
leaves).  Request ids continue monotonically across reopens.

**Payload codecs** — journal payloads must round-trip through JSON:
BNN image batches encode as base64(dtype, shape, bytes); LM prompts as
plain token lists.  Deadlines are deliberately *not* replayed — they
are wall-clock promises from a process that no longer exists.
"""

from __future__ import annotations

import base64
import dataclasses
import json
import os
from pathlib import Path
from typing import Any

import numpy as np

from repro.serving import faults as _faults

__all__ = ["SequenceCheckpoint", "CheckpointSet", "KVCheckpointer",
           "RequestJournal", "JournalState", "replay_journal",
           "encode_payload", "decode_payload"]


# ---------------------------------------------------------------------------
# KV checkpointing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SequenceCheckpoint:
    """One sequence's share of a consistent cut: its bookkeeping plus
    its slot's full KV pages (device arrays with D2H copies enqueued;
    :meth:`materialize` blocks only when the pages are actually
    needed — at restore, typically many ticks later)."""

    seq_id: int
    slot: int
    length: int
    max_new: int
    generated: int
    tokens: list
    prompt: list
    register: int           # last generated token, K/V not yet written
    k_pages: Any            # (L, KV, S, hd) slice for this slot
    v_pages: Any

    def materialize(self) -> tuple[np.ndarray, np.ndarray]:
        return np.asarray(self.k_pages), np.asarray(self.v_pages)


@dataclasses.dataclass
class CheckpointSet:
    """A consistent cut: every active sequence snapshotted at one
    global position.  Restoring *any* subset of these sequences (the
    ones still active at fault time) into a fresh cache is valid
    because attention reads only the owning slot's pages."""

    pos: int
    seqs: dict[int, SequenceCheckpoint]
    reason: str             # "cadence" | "admission" | "restore"


class KVCheckpointer:
    """Takes consistent-cut snapshots of an LM decode state.

    Holds at most one :class:`CheckpointSet` (the latest); the replay
    bound is the distance back to it.  ``kv.snapshot`` is a fault site:
    an injected snapshot fault raises out of :meth:`take` and the
    caller applies the policy from §14.2 — a *cadence* snapshot fault
    keeps the previous set (still consistent, the replay bound just
    grows), an *admission* snapshot fault invalidates it (the old cut
    predates the prefill and is no longer pure-decode-reachable).
    """

    def __init__(self):
        self.set: CheckpointSet | None = None
        self.taken = 0          # successful snapshots
        self.failed = 0         # faulted snapshot attempts

    def take(self, cache: dict, manager, tokens, pos: int,
             reason: str = "cadence") -> CheckpointSet:
        """Snapshot every active sequence at global position ``pos``.
        Raises (without touching the held set) if the ``kv.snapshot``
        fault site fires; the caller decides keep-vs-invalidate."""
        if _faults._PLAN is not None:
            try:
                _faults.maybe_fault("kv.snapshot", pos=pos,
                                    active=len(manager.active),
                                    reason=reason)
            except Exception:
                self.failed += 1
                raise
        reg = np.asarray(tokens).reshape(-1)
        seqs: dict[int, SequenceCheckpoint] = {}
        for seq_id, seq in manager.active.items():
            k = cache["k"][:, seq.slot]
            v = cache["v"][:, seq.slot]
            for page in (k, v):     # host-async: enqueue D2H, don't block
                copy = getattr(page, "copy_to_host_async", None)
                if copy is not None:
                    copy()
            seqs[seq_id] = SequenceCheckpoint(
                seq_id=seq_id, slot=seq.slot, length=seq.length,
                max_new=seq.max_new, generated=seq.generated,
                tokens=list(seq.tokens), prompt=list(seq.prompt),
                register=int(reg[seq.slot]), k_pages=k, v_pages=v)
        self.set = CheckpointSet(pos=int(pos), seqs=seqs, reason=reason)
        self.taken += 1
        return self.set

    def invalidate(self) -> None:
        self.set = None

    def snapshot(self) -> dict:
        return {
            "taken": self.taken,
            "failed": self.failed,
            "pos": self.set.pos if self.set is not None else None,
            "seqs": len(self.set.seqs) if self.set is not None else 0,
        }


# ---------------------------------------------------------------------------
# Payload codecs
# ---------------------------------------------------------------------------

def encode_payload(kind: str, payload: Any) -> dict:
    """JSON-safe encoding of a request payload.  ``bnn`` payloads are
    numpy image batches; ``lm`` payloads are ``(prompt, max_new)``."""
    if kind == "lm":
        prompt, max_new = payload
        return {"prompt": [int(t) for t in prompt], "max_new": int(max_new)}
    if kind == "bnn":
        arr = np.asarray(payload)
        return {"dtype": str(arr.dtype), "shape": list(arr.shape),
                "data": base64.b64encode(arr.tobytes()).decode("ascii")}
    raise ValueError(f"unknown journal payload kind: {kind!r}")


def decode_payload(kind: str, enc: dict) -> Any:
    if kind == "lm":
        return list(enc["prompt"]), int(enc["max_new"])
    if kind == "bnn":
        raw = base64.b64decode(enc["data"])
        return np.frombuffer(raw, dtype=np.dtype(enc["dtype"])) \
            .reshape(enc["shape"]).copy()
    raise ValueError(f"unknown journal payload kind: {kind!r}")


# ---------------------------------------------------------------------------
# Durable request journal
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JournalState:
    """Result of scanning a journal file."""

    records: list
    unresolved: dict[int, dict]     # jid → submit record
    max_jid: int
    torn_tail: bool = False


class RequestJournal:
    """Append-only JSONL write-ahead log of request lifecycles.

    Records::

        {"op": "submit",  "jid": N, "kind": "bnn"|"lm", "payload": {...}}
        {"op": "resolve", "jid": N, "outcome": "served"|...}

    Every append is flushed and fsynced before returning — ``submit``
    must hit the disk before the request enters the scheduler, so a
    crash at any instant leaves either (a) no trace (caller never got a
    Request back) or (b) a journaled submit that :func:`replay_journal`
    will resubmit.  Reopening an existing journal continues ``jid``
    monotonically past the highest on disk.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        state = self.scan(self.path)
        self._next_jid = state.max_jid + 1
        self._f = open(self.path, "a", encoding="utf-8")

    # ---- appends ----------------------------------------------------------
    def _append(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def submit(self, kind: str, payload: Any) -> int:
        """Journal one accepted submission; returns its ``jid``."""
        jid = self._next_jid
        self._next_jid += 1
        self._append({"op": "submit", "jid": jid, "kind": kind,
                      "payload": encode_payload(kind, payload)})
        return jid

    def resolve(self, jid: int, outcome: str,
                error: str | None = None) -> None:
        rec = {"op": "resolve", "jid": jid, "outcome": outcome}
        if error is not None:
            rec["error"] = str(error)
        self._append(rec)

    def close(self) -> None:
        self._f.close()

    # ---- recovery scan ----------------------------------------------------
    @staticmethod
    def scan(path: str | os.PathLike) -> JournalState:
        """Parse a journal, tolerating a torn tail: a kill -9 mid-append
        leaves at most one half-written final line, which is dropped.
        Corruption *before* the tail stops the scan there too — every
        record beyond a torn line is unordered with respect to it."""
        path = Path(path)
        records: list[dict] = []
        torn = False
        if path.exists():
            with open(path, encoding="utf-8") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        records.append(json.loads(line))
                    except json.JSONDecodeError:
                        torn = True
                        break
        unresolved: dict[int, dict] = {}
        max_jid = -1
        for rec in records:
            jid = int(rec.get("jid", -1))
            max_jid = max(max_jid, jid)
            if rec.get("op") == "submit":
                unresolved[jid] = rec
            elif rec.get("op") == "resolve":
                unresolved.pop(jid, None)
        return JournalState(records=records, unresolved=unresolved,
                            max_jid=max_jid, torn_tail=torn)


def replay_journal(server, journal: RequestJournal | str | os.PathLike,
                   kind: str | None = None) -> list:
    """Resubmit every journaled-but-unresolved request to ``server``.

    ``server`` is an :class:`~repro.serving.server.InferenceServer`
    (``bnn`` records) or :class:`~repro.serving.lm_server.LMServer`
    (``lm`` records); records of the other kind are skipped (one
    journal may serve a mixed deployment).  Resubmission passes the
    original ``jid`` so the server attaches the journaled identity
    instead of journaling a duplicate submit — the eventual resolution
    closes the *original* record.  Deadlines are not replayed.
    """
    path = journal.path if isinstance(journal, RequestJournal) else journal
    state = RequestJournal.scan(path)
    if kind is None:
        kind = "lm" if hasattr(server, "manager") else "bnn"
    replayed = []
    for jid in sorted(state.unresolved):
        rec = state.unresolved[jid]
        if rec.get("kind") != kind:
            continue
        payload = decode_payload(kind, rec["payload"])
        if kind == "lm":
            prompt, max_new = payload
            r = server.submit(prompt, max_new=max_new, jid=jid)
        else:
            r = server.submit(payload, jid=jid)
        replayed.append(r)
    return replayed
