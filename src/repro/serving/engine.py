"""PhoneBitEngine: the paper's stand-alone BNN inference engine (Fig 2/3).

Deployment flow exactly as the paper's Fig 2: a trained model (latent float
params) is converted offline — BN folded to integer thresholds, weights
bit-packed, first layer bit-plane-expanded — into the compressed artifact;
the engine loads the artifact and serves the packed integer forward.

Since the graph-runtime rework the engine executes through
:mod:`repro.runtime`: the artifact is lowered to an operator graph
(DESIGN.md §4) and evaluated by a jit-compiled topological executor whose
per-node backend is either fixed by ``matmul_mode`` or chosen by the
autotuner.  The original flat ``packed_forward`` walk is kept as the
``legacy_call`` cross-check oracle.

``matmul_mode`` values (DESIGN.md §3/§4.5/§5):

* ``"xla"``             pure-JAX xor+popcount (CPU-timeable baseline),
* ``"xla_pm1"``         pure-JAX ±1-matmul reformulation,
* ``"vpu_popcount"``    im2col Pallas kernel, paper-faithful (interpret on
                        CPU),
* ``"mxu_pm1"``         ±1 matmul routed for the TPU MXU, beyond-paper,
* ``"vpu_direct"``      direct (im2col-free) Pallas conv kernel; dense
                        layers degrade to ``vpu_popcount``,
* ``"vpu_direct_pool"`` direct kernel with the OR-pool epilogue fused in
                        (``packed_conv_pool`` nodes; others degrade),
* ``"auto"``            per-node autotune — backend *and* direct-kernel
                        tile shape, winners cached per shape signature and
                        persisted to disk (``REPRO_AUTOTUNE_CACHE=0``
                        opts out).

The engine always lowers through :func:`runtime.fuse_pool_epilogue`, so
conv+pool pairs serve as single ``packed_conv_pool`` nodes and the unpooled
conv map drops out of the memory plan.

API mirrors the paper's Fig 3 simplicity::

    engine = PhoneBitEngine.from_artifact("model.npz", spec, (227, 227))
    logits = engine(images_uint8)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import bnn_model, converter

# Modes whose flat-path impl is the ±1-matmul reformulation.
_PM1_MODES = ("mxu_pm1", "xla_pm1")
# Process-wide autotune cache: engines serving structurally identical
# layers (same shapes/attrs) share measurements.
_AUTOTUNE_CACHE: dict = {}


@dataclasses.dataclass
class PhoneBitEngine:
    spec: Sequence[Any]
    packed: list[dict]
    input_hw: tuple[int, int]
    matmul_mode: str = "xla"
    batch_size: int | None = None  # autotune/memory-plan batch (default 1)

    # ---- construction ----------------------------------------------------
    @classmethod
    def from_trained(cls, params, spec, input_hw, **kw) -> "PhoneBitEngine":
        """Offline conversion (Fig 2): fold + pack trained params."""
        packed = converter.convert(params, spec, input_hw)
        return cls(spec=spec, packed=packed, input_hw=input_hw, **kw)

    @classmethod
    def from_artifact(cls, path: str, spec, input_hw,
                      **kw) -> "PhoneBitEngine":
        return cls(spec=spec, packed=converter.load_artifact(path),
                   input_hw=input_hw, **kw)

    def save_artifact(self, path: str) -> None:
        converter.save_artifact(path, self.packed)

    # ---- artifact/metadata separation ------------------------------------
    def prepare(self) -> tuple[list[dict], list[dict]]:
        """Split the packed artifact into traced arrays vs static metadata.

        ``c_per_pos`` entries are static layout metadata (they become slice
        bounds inside jit), so they must leave the traced pytree.  This is
        an explicit, side-effect-free method — callable in any order
        relative to inference — returning ``(arrays, meta)``; both the
        legacy flat path and tooling use it instead of relying on jit
        construction order.
        """
        meta = [{k: int(v) for k, v in layer.items() if k == "c_per_pos"}
                for layer in self.packed]
        arrays = [{k: v for k, v in layer.items() if k != "c_per_pos"}
                  for layer in self.packed]
        return arrays, meta

    # ---- graph runtime path (default) ------------------------------------
    @functools.cached_property
    def _executor(self):
        from repro import runtime

        graph = runtime.fuse_pool_epilogue(
            runtime.lower_packed(self.spec, self.packed, self.input_hw))
        if self.matmul_mode == "auto":
            tuner = runtime.Autotuner(cache=_AUTOTUNE_CACHE)
            return tuner.tuned_executor(graph, self._plan_shape())
        return runtime.GraphExecutor(graph, self.matmul_mode)

    def _plan_shape(self) -> tuple[int, int, int, int]:
        h, w = self.input_hw
        c = next((l.c_in for l in self.spec
                  if isinstance(l, (bnn_model.BConv, bnn_model.FloatConv))),
                 3)
        return (self.batch_size or 1, h, w, c)

    def __call__(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        h, w = self.input_hw
        assert x_uint8.shape[1:3] == (h, w), (x_uint8.shape, self.input_hw)
        return self._executor(x_uint8)

    # ---- legacy flat path (cross-check oracle) ---------------------------
    @functools.cached_property
    def _jitted_flat(self):
        spec = self.spec
        _, meta = self.prepare()
        impl = "pm1" if self.matmul_mode in _PM1_MODES else "xor"

        @jax.jit
        def fwd(arrays, x):
            packed = [dict(a, **m) for a, m in zip(arrays, meta)]
            return bnn_model.packed_forward(packed, spec, x, impl=impl)

        return fwd

    def legacy_call(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        """The pre-graph flat ``packed_forward`` walk (oracle)."""
        arrays, _ = self.prepare()
        return self._jitted_flat(arrays, x_uint8)

    def cross_check(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        """Run the graph path and assert bit-exactness vs the flat path."""
        import numpy as np

        got = self(x_uint8)
        ref = self.legacy_call(x_uint8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        return got

    # ---- introspection ---------------------------------------------------
    def memory_plan(self):
        """Static arena plan for the serving graph (DESIGN.md §4.4)."""
        from repro import runtime

        return runtime.plan_memory(self._executor.graph, self._plan_shape())

    @property
    def backend_choices(self) -> list[dict]:
        """Per-node backend decisions (fixed mode or autotune winners)."""
        return self._executor.backend_report()

    @property
    def model_bytes(self) -> int:
        return converter.model_bytes(self.packed)
