"""PhoneBitEngine: the paper's stand-alone BNN inference engine (Fig 2/3).

Deployment flow exactly as the paper's Fig 2: a trained model (latent float
params) is converted offline — BN folded to integer thresholds, weights
bit-packed, first layer bit-plane-expanded — into the compressed artifact;
the engine loads the artifact and serves the packed integer forward.

The engine's ``matmul_mode`` selects the execution path (paper §V/VI vs
the beyond-paper MXU path, DESIGN.md §3):

* ``"xla"``           pure-JAX xor+popcount (CPU-timeable baseline),
* ``"vpu_popcount"``  Pallas kernel, paper-faithful (interpret on CPU),
* ``"mxu_pm1"``       Pallas MXU kernel, beyond-paper.

API mirrors the paper's Fig 3 simplicity::

    engine = PhoneBitEngine.from_artifact("model.npz", spec, (227, 227))
    logits = engine(images_uint8)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import bnn_model, converter


@dataclasses.dataclass
class PhoneBitEngine:
    spec: Sequence[Any]
    packed: list[dict]
    input_hw: tuple[int, int]
    matmul_mode: str = "xla"

    # ---- construction ----------------------------------------------------
    @classmethod
    def from_trained(cls, params, spec, input_hw, **kw) -> "PhoneBitEngine":
        """Offline conversion (Fig 2): fold + pack trained params."""
        packed = converter.convert(params, spec, input_hw)
        return cls(spec=spec, packed=packed, input_hw=input_hw, **kw)

    @classmethod
    def from_artifact(cls, path: str, spec, input_hw,
                      **kw) -> "PhoneBitEngine":
        return cls(spec=spec, packed=converter.load_artifact(path),
                   input_hw=input_hw, **kw)

    def save_artifact(self, path: str) -> None:
        converter.save_artifact(path, self.packed)

    # ---- inference ---------------------------------------------------------
    @functools.cached_property
    def _jitted(self):
        spec = self.spec
        # c_per_pos entries are static layout metadata (they become slice
        # bounds); strip them out of the traced pytree and re-insert as
        # python ints inside the jitted fn.
        meta = [{k: int(v) for k, v in layer.items() if k == "c_per_pos"}
                for layer in self.packed]
        arrays = [{k: v for k, v in layer.items() if k != "c_per_pos"}
                  for layer in self.packed]
        self._arrays = arrays
        impl = "pm1" if self.matmul_mode in ("mxu_pm1", "xla_pm1") else "xor"

        @jax.jit
        def fwd(arrays, x):
            packed = [dict(a, **m) for a, m in zip(arrays, meta)]
            return bnn_model.packed_forward(packed, spec, x, impl=impl)

        return fwd

    def __call__(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        h, w = self.input_hw
        assert x_uint8.shape[1:3] == (h, w), (x_uint8.shape, self.input_hw)
        fwd = self._jitted
        return fwd(self._arrays, x_uint8)

    # ---- metadata ----------------------------------------------------------
    @property
    def model_bytes(self) -> int:
        return converter.model_bytes(self.packed)
