"""PhoneBitEngine: the paper's stand-alone BNN inference engine (Fig 2/3).

Deployment flow exactly as the paper's Fig 2: a trained model (latent float
params) is converted offline — BN folded to integer thresholds, weights
bit-packed, first layer bit-plane-expanded — into the compressed artifact;
the engine loads the artifact and serves the packed integer forward.

Since the graph-runtime rework the engine executes through
:mod:`repro.runtime`: the artifact is lowered to an operator graph
(DESIGN.md §4) and evaluated by a jit-compiled topological executor whose
per-node backend is either fixed by ``matmul_mode`` or chosen by the
autotuner.  The original flat ``packed_forward`` walk is kept as the
``legacy_call`` cross-check oracle.

``matmul_mode`` values (DESIGN.md §3/§4.5/§5):

* ``"xla"``             pure-JAX xor+popcount (CPU-timeable baseline),
* ``"xla_pm1"``         pure-JAX ±1-matmul reformulation,
* ``"vpu_popcount"``    im2col Pallas kernel, paper-faithful (interpret on
                        CPU),
* ``"mxu_pm1"``         ±1 matmul routed for the TPU MXU, beyond-paper,
* ``"vpu_direct"``      direct (im2col-free) Pallas conv kernel; dense
                        layers degrade to ``vpu_popcount``,
* ``"vpu_direct_pool"`` direct kernel with the OR-pool epilogue fused in
                        (``packed_conv_pool`` nodes; others degrade),
* ``"vpu_chain"``       chain-fusion megakernel regions (DESIGN.md §9):
                        maximal runs of packed conv/pool ops execute as
                        single Pallas calls with VMEM-resident
                        intermediates at planner offsets; ops outside a
                        region degrade per-node,
* ``"auto"``            per-node autotune — backend *and* direct-kernel
                        tile shape, winners cached per shape signature and
                        persisted to disk (``REPRO_AUTOTUNE_CACHE=0``
                        opts out).

The engine always lowers through :func:`runtime.fuse_pool_epilogue`, so
conv+pool pairs serve as single ``packed_conv_pool`` nodes and the unpooled
conv map drops out of the memory plan.

Batched serving (DESIGN.md §7) goes through the **per-bucket executable
cache**: ``compile(batch_size)`` builds (once) a jit-compiled
:class:`~repro.runtime.executor.GraphExecutor` for that batch bucket and
caches it on the engine, so serve time never retraces — mixed-size request
streams are padded to bucket sizes by the scheduler and always hit an
already-compiled executable.  Under ``matmul_mode="auto"`` each bucket is
autotuned at *its own* batch shape; winners measured at one bucket
transfer to others when valid (no batch-spanning tile), so warming N
buckets costs ~one tuning pass.  ``compile`` also takes ``donate_input=``
(the serving path donates each batch's input buffer to the device) and
``data_parallel=`` (autotune at the per-device shard shape when the server
shards batches across a mesh).  ``trace_count`` aggregates over all
compiled buckets — the serve-time no-recompile contract is
``engine.trace_count`` staying flat while requests flow.  There is no
manual warm-up protocol: ``InferenceServer.compile_buckets()`` (or any
first call at a bucket) populates the cache.

API mirrors the paper's Fig 3 simplicity::

    engine = PhoneBitEngine.from_artifact("model.npz", spec, (227, 227))
    logits = engine(images_uint8)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import bnn_model, converter
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _trace
from repro.serving import faults as _faults

# Modes whose flat-path impl is the ±1-matmul reformulation.
_PM1_MODES = ("mxu_pm1", "xla_pm1")
# Process-wide autotune caches: engines serving structurally identical
# layers (same shapes/attrs) share measurements; the agnostic cache
# carries winners across batch buckets (autotune.py module docstring).
_AUTOTUNE_CACHE: dict = {}
_AUTOTUNE_AGNOSTIC: dict = {}


@dataclasses.dataclass
class PhoneBitEngine:
    spec: Sequence[Any]
    packed: list[dict]
    input_hw: tuple[int, int]
    matmul_mode: str = "xla"
    batch_size: int | None = None  # autotune/memory-plan batch (default 1)

    # ---- construction ----------------------------------------------------
    @classmethod
    def from_trained(cls, params, spec, input_hw, **kw) -> "PhoneBitEngine":
        """Offline conversion (Fig 2): fold + pack trained params."""
        packed = converter.convert(params, spec, input_hw)
        return cls(spec=spec, packed=packed, input_hw=input_hw, **kw)

    @classmethod
    def from_artifact(cls, path: str, spec, input_hw,
                      **kw) -> "PhoneBitEngine":
        return cls(spec=spec, packed=converter.load_artifact(path),
                   input_hw=input_hw, **kw)

    def save_artifact(self, path: str) -> None:
        converter.save_artifact(path, self.packed)

    # ---- artifact/metadata separation ------------------------------------
    def prepare(self) -> tuple[list[dict], list[dict]]:
        """Split the packed artifact into traced arrays vs static metadata.

        ``c_per_pos`` entries are static layout metadata (they become slice
        bounds inside jit), so they must leave the traced pytree.  This is
        an explicit, side-effect-free method — callable in any order
        relative to inference — returning ``(arrays, meta)``; both the
        legacy flat path and tooling use it instead of relying on jit
        construction order.
        """
        meta = [{k: int(v) for k, v in layer.items() if k == "c_per_pos"}
                for layer in self.packed]
        arrays = [{k: v for k, v in layer.items() if k != "c_per_pos"}
                  for layer in self.packed]
        return arrays, meta

    # ---- graph runtime path (default) ------------------------------------
    @functools.cached_property
    def _graph(self):
        from repro import runtime

        return runtime.fuse_pool_epilogue(
            runtime.lower_packed(self.spec, self.packed, self.input_hw))

    @functools.cached_property
    def _compiled(self) -> dict:
        """The per-bucket executable cache: (batch, donate, dp) → executor."""
        return {}

    @functools.cached_property
    def _tuner(self):
        """One Autotuner per engine: the disk cache is read once, not
        once per compiled bucket (winners still shared process-wide via
        the module caches)."""
        from repro import runtime

        return runtime.Autotuner(cache=_AUTOTUNE_CACHE,
                                 agnostic_cache=_AUTOTUNE_AGNOSTIC)

    def compile(self, batch_size: int | None = None, *,
                donate_input: bool = False, data_parallel: int = 1,
                mode: str | None = None, pipeline=None):
        """Build (once) the executable for one serving bucket.

        Returns the cached :class:`GraphExecutor` for
        ``(batch_size, donate_input, data_parallel, mode)``, constructing
        and — under ``matmul_mode="auto"`` — autotuning it on first
        request.  Autotuning happens at the **per-device** shard shape
        (``batch_size // data_parallel``) so a data-parallel server reuses
        the winners of the equivalent single-device bucket, and winners
        transfer across buckets where the tile does not span the batch
        dim.  Serve-time calls at a compiled bucket never retrace.

        ``mode`` overrides ``matmul_mode`` for this executable only —
        the serving resilience layer (DESIGN.md §11.3) uses it to demote
        a failing bucket down the backend ladder without touching the
        engine's configured mode (all modes are bit-exact, so a demoted
        bucket serves identical results).

        ``pipeline`` is a sequence of devices for pipeline-parallel
        placement (DESIGN.md §13): the bucket compiles to a
        :class:`~repro.runtime.placement.StagedExecutor` — one
        executable per stage, cut at HBM touch points, params committed
        per device.  Mutually exclusive with ``data_parallel > 1``
        (compose data-parallel *replicas of pipelines* via
        :class:`~repro.distributed.replicas.ReplicaGroup` instead).
        """
        from repro import runtime

        mode = mode or self.matmul_mode
        bs = batch_size if batch_size is not None else (self.batch_size or 1)
        if bs < 1:
            raise ValueError(f"batch_size must be >= 1, got {bs}")
        if data_parallel > 1 and bs % data_parallel:
            raise ValueError(
                f"bucket {bs} not divisible by data_parallel={data_parallel}")
        if pipeline is not None and data_parallel > 1:
            raise ValueError("pipeline placement and data_parallel > 1 "
                             "are mutually exclusive on one executable; "
                             "compose replicas of pipelines instead")
        # The 4-tuple key is the artifact-compat surface
        # (artifact._install_executable); pipeline buckets extend it, so
        # the two key shapes can never collide.
        key = (bs, donate_input, data_parallel, mode)
        if pipeline is not None:
            key = key + (tuple(str(d) for d in pipeline),)
        if key not in self._compiled:
            if _faults._PLAN is not None:
                _faults.maybe_fault("engine.compile", bucket=bs, mode=mode)
            with _trace.span("compile.executor", "compile", bucket=bs,
                             mode=mode,
                             data_parallel=data_parallel):
                if pipeline is not None:
                    from repro.runtime import placement as _placement

                    exe = _placement.staged_executor(
                        self._graph, self._plan_shape(bs), tuple(pipeline),
                        mode=mode, donate_input=donate_input,
                        tuner=(self._tuner if mode == "auto"
                               or jax.default_backend() == "tpu"
                               else None))
                elif mode == "auto":
                    exe = self._tuner.tuned_executor(
                        self._graph,
                        self._plan_shape(max(bs // data_parallel, 1)),
                        donate_input=donate_input)
                elif mode == "vpu_chain":
                    # Region-fused serving (DESIGN.md §9): chains of packed
                    # ops run as single megakernel calls.  Per-chain tile
                    # shapes are autotuned on TPU only — interpret-mode
                    # timings are validators, not contenders (same policy as
                    # ``default_candidates``).
                    exe = runtime.chain_executor(
                        self._graph,
                        self._plan_shape(max(bs // data_parallel, 1)),
                        tuner=(self._tuner if jax.default_backend() == "tpu"
                               else None),
                        donate_input=donate_input)
                else:
                    exe = runtime.GraphExecutor(self._graph, mode,
                                                donate_input=donate_input)
            self._record_compile_metrics(exe, bs, data_parallel)
            self._compiled[key] = exe
        return self._compiled[key]

    def _record_compile_metrics(self, exe, bs: int,
                                data_parallel: int) -> None:
        """Publish runtime-wide memory series for a freshly built bucket:
        the arena plan's peak and, for region-fused executors, the HBM
        round-trip traffic the chains keep in VMEM (DESIGN.md §10.2)."""
        from repro import runtime

        reg = _obs_metrics.get_registry()
        plan = runtime.plan_memory(
            exe.graph, self._plan_shape(max(bs // data_parallel, 1)))
        reg.gauge("runtime.arena_peak_bytes").set(plan.peak_bytes())
        if getattr(exe, "regions", None):
            reg.gauge("runtime.chain_hbm_bytes_avoided").set(
                sum(c.hbm_bytes_avoided() for c in exe.regions))

    @property
    def _executor(self):
        """Default-bucket executor (``batch_size`` or 1) — introspection
        surface for ``memory_plan``/``backend_choices``."""
        return self.compile()

    @property
    def trace_count(self) -> int:
        """Total jit traces across every compiled bucket (serve-time
        no-recompile hook: this must stay flat while requests flow).
        AOT-loaded buckets contribute a constant 0 — they were never
        traced in this process."""
        return sum(e.trace_count for e in self._compiled.values())

    # ---- AOT executable artifacts (DESIGN.md §12) ------------------------
    def _install_executable(self, batch_size: int, exe, *,
                            donate_input: bool = False,
                            data_parallel: int = 1,
                            mode: str | None = None) -> None:
        """Register a prebuilt bucket executable under the same cache key
        :meth:`compile` would use — the artifact loader's entry point."""
        key = (int(batch_size), donate_input, data_parallel,
               mode or self.matmul_mode)
        self._compiled[key] = exe

    def export_artifact(self, path, buckets=(1, 2, 4, 8), *,
                        donate_input: bool = True) -> dict:
        """Serialize one AOT bucket executable per bucket (plus the
        autotune winner table and a provenance meta block) into the
        directory ``path`` — the offline half of zero-warmup serving.
        Distinct from :meth:`save_artifact`, which stores the packed
        *weights* (npz); this stores compiled *executables*."""
        from repro.serving import artifact as _artifact

        return _artifact.export_artifact(self, path, buckets,
                                         donate_input=donate_input)

    def load_artifact(self, path, *, donate_input: bool = True,
                      data_parallel: int = 1, buckets=None) -> dict:
        """Restore AOT bucket executables exported by
        :meth:`export_artifact` into the per-bucket cache with zero
        traces; per-bucket environment mismatches fall back to live
        compile (structured ``artifact.miss`` events), corrupt bytes
        raise :class:`~repro.serving.artifact.ArtifactError`."""
        from repro.serving import artifact as _artifact

        return _artifact.load_artifact(self, path,
                                       donate_input=donate_input,
                                       data_parallel=data_parallel,
                                       buckets=buckets)

    def _plan_shape(self, batch: int | None = None
                    ) -> tuple[int, int, int, int]:
        h, w = self.input_hw
        c = next((l.c_in for l in self.spec
                  if isinstance(l, (bnn_model.BConv, bnn_model.FloatConv))),
                 3)
        return (batch or self.batch_size or 1, h, w, c)

    def __call__(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        h, w = self.input_hw
        assert x_uint8.shape[1:3] == (h, w), (x_uint8.shape, self.input_hw)
        return self.compile(x_uint8.shape[0])(x_uint8)

    # ---- legacy flat path (cross-check oracle) ---------------------------
    @functools.cached_property
    def _jitted_flat(self):
        spec = self.spec
        _, meta = self.prepare()
        impl = "pm1" if self.matmul_mode in _PM1_MODES else "xor"

        @jax.jit
        def fwd(arrays, x):
            packed = [dict(a, **m) for a, m in zip(arrays, meta)]
            return bnn_model.packed_forward(packed, spec, x, impl=impl)

        return fwd

    def legacy_call(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        """The pre-graph flat ``packed_forward`` walk (oracle)."""
        arrays, _ = self.prepare()
        return self._jitted_flat(arrays, x_uint8)

    def cross_check(self, x_uint8: jnp.ndarray) -> jnp.ndarray:
        """Run the graph path and assert bit-exactness vs the flat path."""
        import numpy as np

        got = self(x_uint8)
        ref = self.legacy_call(x_uint8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        return got

    # ---- introspection ---------------------------------------------------
    def memory_plan(self):
        """Static arena plan for the serving graph (DESIGN.md §4.4)."""
        from repro import runtime

        return runtime.plan_memory(self._executor.graph, self._plan_shape())

    @property
    def backend_choices(self) -> list[dict]:
        """Per-node backend decisions (fixed mode or autotune winners)."""
        return self._executor.backend_report()

    @property
    def model_bytes(self) -> int:
        return converter.model_bytes(self.packed)
