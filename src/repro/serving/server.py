"""InferenceServer: the production serving subsystem (DESIGN.md §7).

One object owns the whole serve path the paper's phone loop inlines:

* a :class:`~repro.serving.scheduler.BatchScheduler` assembling
  deadline-aware, bucket-padded batches;
* the engine's **per-bucket executable cache** —
  ``compile_buckets()`` precompiles (and, in ``auto`` mode, autotunes)
  one :class:`GraphExecutor` per bucket so serve time never retraces;
* **async double-buffered dispatch** — batch *k+1* is dispatched while
  batch *k*'s device work is still in flight; the host blocks only when
  scattering results (``np.asarray`` at the pop of the one-deep pipeline),
  and each batch's input buffer is donated to the device;
* optional **data-parallel batch sharding** — given a mesh, inputs are
  placed with ``jax.sharding.NamedSharding(mesh, P(data_axis))`` so XLA
  splits every bucket across the data axis; buckets are rounded up to
  shard evenly and autotuning runs at the per-device shard shape (reusing
  the single-device winners).

The server surface is the protocol both serving paths share (the LM
decode server implements the same one): ``submit`` / ``poll`` / ``step``
/ ``drain`` plus ``metrics()`` (p50/p95 latency, queue depth, throughput,
dropped count — definitions in DESIGN.md §7.4).
"""

from __future__ import annotations

import math
import time
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.scheduler import BatchScheduler, Request


@runtime_checkable
class Server(Protocol):
    """What a serving front end looks like, BNN or LM."""

    def submit(self, payload: Any, **kw) -> Request: ...

    def poll(self, request: Request) -> bool: ...

    def drain(self) -> list[Request]: ...

    def metrics(self) -> dict: ...


def percentile(sorted_vals: list[float], p: float) -> float | None:
    """Nearest-rank percentile of an ascending list (None when empty):
    the smallest value with at least ``p`` of the sample at or below it,
    i.e. index ``ceil(p*n) - 1``."""
    n = len(sorted_vals)
    if not n:
        return None
    return sorted_vals[max(0, min(n - 1, math.ceil(p * n) - 1))]


class ServingMetrics:
    """Latency/throughput bookkeeping shared by both servers (§7.4): one
    definition of p50/p95, the busy window, and the metrics dict, so the
    two protocol implementations cannot drift.  The busy window uses the
    owner's (injectable) clock — under a fake clock, throughput reports
    simulated time, the same domain as the latency percentiles."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.latencies: list[float] = []
        self.served = 0
        self._t_first: float | None = None
        self._t_last: float | None = None

    def mark_dispatch(self) -> None:
        """First device work entered flight: the busy window opens."""
        if self._t_first is None:
            self._t_first = self._clock()

    def record(self, latencies: list[float]) -> None:
        """A batch of requests completed with these submit→done times."""
        self.latencies.extend(latencies)
        self.served += len(latencies)
        self._t_last = self._clock()

    def snapshot(self, *, dropped: int, queue_depth: int,
                 **extra) -> dict:
        lat = sorted(self.latencies)
        busy = (self._t_last - self._t_first
                if self._t_first is not None and self._t_last is not None
                else None)
        return {
            "served": self.served,
            "dropped": dropped,
            "queue_depth": queue_depth,
            "p50_ms": None if not lat else percentile(lat, 0.50) * 1e3,
            "p95_ms": None if not lat else percentile(lat, 0.95) * 1e3,
            "throughput": (self.served / busy if busy else None),
            **extra,
        }


class _InFlight:
    """One dispatched batch: requests + the device array still computing."""

    __slots__ = ("batch", "out")

    def __init__(self, batch: list[Request], out):
        self.batch = batch
        self.out = out


class InferenceServer:
    """Batched image-inference front end over a PhoneBitEngine.

    Parameters
    ----------
    engine:          a :class:`~repro.serving.engine.PhoneBitEngine` (or
                     anything with ``compile(bs, donate_input=,
                     data_parallel=) -> callable`` and ``_plan_shape``).
    buckets:         compiled batch sizes; mixed-size traffic is padded up
                     to the nearest one.
    async_dispatch:  double-buffer dispatch (the default); ``False`` gives
                     the synchronous drain loop (benchmark baseline).
    preprocess:      optional per-payload host transform (decode / crop /
                     normalize) applied at batch staging.  Under async
                     dispatch batch k+1's preprocessing runs while batch
                     k's device work is in flight — host preprocessing is
                     the classic serving cost double-buffering hides.
    mesh/data_axis:  optional device mesh for data-parallel sharding.
    clock:           injectable monotonic clock (tests use a fake).
    """

    def __init__(self, engine, *, max_batch: int = 8,
                 max_wait_s: float = 0.0,
                 buckets: tuple[int, ...] = (1, 2, 4, 8),
                 async_dispatch: bool = True,
                 donate_input: bool = True,
                 preprocess: Callable[[np.ndarray], np.ndarray]
                 | None = None,
                 mesh=None, data_axis: str = "data",
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.preprocess = preprocess
        self.mesh, self.data_axis = mesh, data_axis
        self.data_parallel = int(mesh.shape[data_axis]) if mesh is not None \
            else 1
        if self.data_parallel > 1:
            dp = self.data_parallel
            buckets = tuple(sorted({-(-b // dp) * dp for b in buckets}))
            max_batch = max(max_batch, buckets[0])
        self.scheduler = BatchScheduler(
            max_batch=max_batch, max_wait_s=max_wait_s,
            buckets=tuple(buckets))
        self.async_dispatch = async_dispatch
        self.donate_input = donate_input
        self.clock = clock
        self._pending: _InFlight | None = None
        self._metrics = ServingMetrics(clock)

    # ---- executable cache -------------------------------------------------
    def _executable(self, bucket: int):
        return self.engine.compile(bucket, donate_input=self.donate_input,
                                   data_parallel=self.data_parallel)

    def compile_buckets(self) -> dict[int, float]:
        """Precompile (and autotune) every bucket; returns seconds spent
        per bucket.  After this, serving any mixed-size request stream
        triggers zero retraces (``engine.trace_count`` stays flat)."""
        timings: dict[int, float] = {}
        for b in self.scheduler.buckets:
            t0 = time.perf_counter()
            exe = self._executable(b)
            x = self._place(np.zeros(self.engine._plan_shape(b), np.uint8))
            jax.block_until_ready(exe(x))
            timings[b] = time.perf_counter() - t0
        return timings

    # ---- placement --------------------------------------------------------
    def _place(self, x_np: np.ndarray):
        if self.mesh is None:
            return jnp.asarray(x_np)
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        return jax.device_put(x_np, NamedSharding(self.mesh,
                                                  P(self.data_axis)))

    # ---- request lifecycle ------------------------------------------------
    def submit(self, payload: Any, deadline_s: float | None = None,
               now: float | None = None) -> Request:
        # Arrival is stamped from the server's clock so latency samples
        # stay in one clock domain when a fake clock is injected.
        now = self.clock() if now is None else now
        return self.scheduler.submit(payload, deadline_s=deadline_s,
                                     now=now)

    def poll(self, request: Request) -> bool:
        return request.done

    # ---- dispatch / scatter ----------------------------------------------
    def _dispatch(self, batch: list[Request],
                  payloads: list[Any]) -> _InFlight:
        rows = [np.asarray(p) for p in payloads]
        if self.preprocess is not None:     # pads go through it too
            rows = [self.preprocess(r) for r in rows]
        x = self._place(np.stack(rows))
        out = self._executable(x.shape[0])(x)   # async: returns immediately
        self._metrics.mark_dispatch()
        return _InFlight(batch, out)

    def _scatter(self, flight: _InFlight) -> list[Request]:
        host = np.asarray(flight.out)           # the only blocking point
        now = self.clock()
        for r, row in zip(flight.batch, host):
            r.result, r.done = row, True
        self._metrics.record([now - r.arrival_s for r in flight.batch])
        return flight.batch

    def step(self, now: float | None = None,
             force: bool = False) -> list[Request]:
        """One serving tick: dispatch the next batch (policy permitting),
        then scatter the previously in-flight one.  Under async dispatch
        the new batch's device work overlaps the old batch's readback;
        synchronously each batch completes before the next is assembled.
        Returns the requests completed this tick."""
        now = self.clock() if now is None else now
        got = self.scheduler.padded_batch(now, force=force)
        flight = self._dispatch(*got) if got is not None else None
        if not self.async_dispatch and flight is not None:
            return self._scatter(flight)
        done: list[Request] = []
        if self._pending is not None:
            done = self._scatter(self._pending)
        self._pending = flight
        return done

    def drain(self, now: float | None = None) -> list[Request]:
        """Serve until the queue is empty and nothing is in flight
        (skipping the batch-wait policy: drain is a flush).  Returns the
        requests completed during the drain."""
        done: list[Request] = []
        while len(self.scheduler) or self._pending is not None:
            done += self.step(now, force=True)
        return done

    # ---- observability ----------------------------------------------------
    @property
    def queue_depth(self) -> int:
        inflight = len(self._pending.batch) if self._pending else 0
        return len(self.scheduler) + inflight

    def metrics(self) -> dict:
        """p50/p95 request latency (submit→scatter, ms), served/dropped
        counts, live queue depth, and throughput over the busy window
        (first dispatch → last scatter)."""
        return self._metrics.snapshot(
            dropped=self.scheduler.dropped,
            queue_depth=self.queue_depth,
            async_dispatch=self.async_dispatch,
            data_parallel=self.data_parallel,
            buckets=list(self.scheduler.buckets))
