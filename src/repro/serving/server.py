"""InferenceServer: the production serving subsystem (DESIGN.md §7, §11).

One object owns the whole serve path the paper's phone loop inlines:

* a :class:`~repro.serving.scheduler.BatchScheduler` assembling
  deadline-aware, bucket-padded batches;
* the engine's **per-bucket executable cache** —
  ``compile_buckets()`` precompiles (and, in ``auto`` mode, autotunes)
  one :class:`GraphExecutor` per bucket so serve time never retraces;
* **async double-buffered dispatch** — batch *k+1* is dispatched while
  batch *k*'s device work is still in flight; the host blocks only when
  scattering results (``np.asarray`` at the pop of the one-deep pipeline),
  and each batch's input buffer is donated to the device;
* optional **data-parallel batch sharding** — given a mesh, inputs are
  placed with ``jax.sharding.NamedSharding(mesh, P(data_axis))`` so XLA
  splits every bucket across the data axis; buckets are rounded up to
  shard evenly and autotuning runs at the per-device shard shape (reusing
  the single-device winners).

The server surface is the protocol both serving paths share (the LM
decode server implements the same one): ``submit`` / ``poll`` / ``step``
/ ``drain`` plus ``metrics()`` (p50/p95 latency, queue depth, throughput,
dropped count — definitions in DESIGN.md §7.4).

Resilience (DESIGN.md §11): every request **terminally resolves** —
``done=True`` with ``outcome`` ∈ {served, shed, error, rejected} — and
no failure escapes ``step()`` to kill the serve loop:

* ``submit`` validates payloads against the engine's input spec and
  applies bounded-queue admission control, returning a structured
  ``rejected`` request instead of raising or poisoning a batch;
* a failed batch (compile error, device fault, preprocess exception)
  retries per-request with capped exponential backoff + jitter
  (:class:`~repro.serving.faults.RetryPolicy`) on the server's
  injectable clock, resolving ``error`` when attempts are exhausted;
* repeated executable failures demote the serving mode down
  :data:`~repro.serving.faults.DEGRADE_LADDER`
  (:class:`~repro.serving.faults.BackendHealth`): the failing backend
  is quarantined and re-probed periodically, demotions are published
  via the ``serve.degraded`` counter and flight-recorder records;
* an optional dispatch watchdog (``watchdog_s``) bounds the device
  readback so a wedged executable surfaces as an error, and ``drain``
  is iteration-bounded so a wedged queue cannot hang it forever.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

# Canonical home of the latency math and serving metrics is the
# observability layer (DESIGN.md §10); re-exported here for the existing
# import surface.
from repro.obs import FlightRecorder
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _trace
from repro.obs.metrics import ServingMetrics, percentile  # noqa: F401
from repro.serving import faults as _faults
from repro.serving.faults import (BackendHealth, BucketHealth,  # noqa: F401
                                  RetryPolicy, WatchdogTimeout)
from repro.serving.scheduler import BatchScheduler, Request


@runtime_checkable
class Server(Protocol):
    """What a serving front end looks like, BNN or LM."""

    def submit(self, payload: Any, **kw) -> Request: ...

    def poll(self, request: Request) -> bool: ...

    def drain(self) -> list[Request]: ...

    def metrics(self) -> dict: ...


class _InFlight:
    """One dispatched batch: requests + the device array still computing.

    ``row_idx`` maps each request to its row of the device output (rows
    of requests whose preprocessing failed are zero-filled and skipped);
    ``mode`` is the backend the executable ran under (degradation);
    ``t_dispatch``/``stage_s`` feed the flight recorder at scatter."""

    __slots__ = ("batch", "row_idx", "out", "bucket", "t_dispatch",
                 "stage_s", "mode", "probing")

    def __init__(self, batch: list[Request], row_idx: list[int], out,
                 bucket: int, t_dispatch: float, stage_s: float,
                 mode: str | None, probing: bool = False):
        self.batch = batch
        self.row_idx = row_idx
        self.out = out
        self.bucket = bucket
        self.t_dispatch = t_dispatch
        self.stage_s = stage_s
        self.mode = mode
        self.probing = probing


class InferenceServer:
    """Batched image-inference front end over a PhoneBitEngine.

    Parameters
    ----------
    engine:          a :class:`~repro.serving.engine.PhoneBitEngine` (or
                     anything with ``compile(bs, donate_input=,
                     data_parallel=, mode=) -> callable`` and
                     ``_plan_shape``).
    buckets:         compiled batch sizes; mixed-size traffic is padded up
                     to the nearest one.
    async_dispatch:  double-buffer dispatch (the default); ``False`` gives
                     the synchronous drain loop (benchmark baseline).
    preprocess:      optional per-payload host transform (decode / crop /
                     normalize) applied at batch staging.  Under async
                     dispatch batch k+1's preprocessing runs while batch
                     k's device work is in flight — host preprocessing is
                     the classic serving cost double-buffering hides.
    mesh/data_axis:  optional device mesh for data-parallel sharding.
    placement:       optional placement object (DESIGN.md §13), the
                     generalized form of ``mesh=``: duck-typed on
                     ``.kind`` so this module never imports
                     ``repro.distributed``.  ``kind == "data"``
                     (:class:`~repro.distributed.sharding.DataParallel`)
                     supplies mesh + axis; ``kind == "pipeline"``
                     (:class:`~repro.distributed.pipeline.Pipelined`)
                     compiles every bucket as a
                     :class:`~repro.runtime.placement.StagedExecutor`
                     over its devices.
    flight_capacity: size of the flight-recorder ring (recent request
                     records for postmortems; ``server.flight.dump()``).
    clock:           injectable monotonic clock (tests use a fake).

    Resilience (DESIGN.md §11)
    --------------------------
    retry:           :class:`RetryPolicy` for failed batches (None = one
                     attempt, no retry).  Backoff is applied by stamping
                     ``Request.not_before`` on the server clock.
    max_queue:       bounded admission: submits beyond this queue depth
                     resolve ``rejected`` (None = unbounded).
    validate:        payload validation at ``submit`` (shape vs the
                     engine input spec when no preprocess hook rewrites
                     sizes, object-dtype and NaN/Inf checks).
    degrade:         demote the serving backend down ``DEGRADE_LADDER``
                     after ``demote_after`` consecutive executable
                     failures; quarantined modes re-probe after
                     ``probe_after_s`` (doubling per re-offense).
    watchdog_s:      bound the device readback; a stalled executable
                     raises :class:`WatchdogTimeout` into the normal
                     retry/error path (None = block forever, the
                     pre-resilience behavior and the zero-thread path).
    sleep:           how ``drain`` waits out retry backoff when every
                     queued request is ineligible (tests inject a fake
                     that advances their fake clock).
    tenant:          optional tenant name stamped onto flight-recorder
                     records, fault contexts and ``metrics()`` — how
                     :class:`~repro.serving.multiplex.MultiTenantServer`
                     labels each lane.
    artifact:        optional AOT artifact directory (DESIGN.md §12):
                     restore serialized bucket executables at
                     construction so serving starts with zero traces;
                     per-bucket meta mismatches fall back to live
                     compile with an ``artifact.miss`` event.

    Observability (DESIGN.md §10): when a tracer is installed
    (``repro.obs.trace.install()``) each serving stage emits a span —
    ``serve.submit`` (instant), ``serve.assemble``, ``serve.stage``,
    ``serve.dispatch``, ``serve.device``, ``serve.scatter`` — all
    host-side, so tracing never retraces the compiled executables.
    Disabled (the default), every site is one global read.
    """

    def __init__(self, engine, *, max_batch: int = 8,
                 max_wait_s: float = 0.0,
                 buckets: tuple[int, ...] = (1, 2, 4, 8),
                 async_dispatch: bool = True,
                 donate_input: bool = True,
                 preprocess: Callable[[np.ndarray], np.ndarray]
                 | None = None,
                 mesh=None, data_axis: str = "data",
                 placement=None,
                 flight_capacity: int = 256,
                 clock: Callable[[], float] = time.monotonic,
                 retry: RetryPolicy | None = RetryPolicy(),
                 max_queue: int | None = None,
                 validate: bool = True,
                 degrade: bool = True,
                 demote_after: int = 2,
                 probe_after_s: float = 30.0,
                 watchdog_s: float | None = None,
                 sleep: Callable[[float], None] | None = None,
                 tenant: str | None = None,
                 artifact: str | None = None,
                 journal=None):
        self.engine = engine
        self.tenant = tenant
        # Durable request journal (DESIGN.md §14.3): accepted submits are
        # WAL-journaled before they enter the scheduler; terminal
        # resolutions close them.  ``recovery.replay_journal`` resubmits
        # unresolved records after a crash.
        self.journal = journal
        self.preprocess = preprocess
        # Placement generalizes mesh=: duck-typed on .kind so the server
        # never imports repro.distributed (which imports this module).
        self.placement = placement
        self.pipeline_devices: tuple | None = None
        if placement is not None:
            kind = getattr(placement, "kind", None)
            if kind == "data":
                if mesh is not None:
                    raise ValueError("pass placement= or mesh=, not both")
                mesh, data_axis = placement.mesh, placement.axis
            elif kind == "pipeline":
                if mesh is not None:
                    raise ValueError("pipeline placement and mesh= are "
                                     "mutually exclusive on one server; "
                                     "compose replicas of pipelines via "
                                     "ReplicaGroup")
                self.pipeline_devices = tuple(placement.devices)
            else:
                raise ValueError(f"placement {placement!r} has no valid "
                                 f".kind ('data' | 'pipeline')")
        self.mesh, self.data_axis = mesh, data_axis
        self.data_parallel = int(mesh.shape[data_axis]) if mesh is not None \
            else 1
        if self.data_parallel > 1:
            dp = self.data_parallel
            buckets = tuple(sorted({-(-b // dp) * dp for b in buckets}))
            max_batch = max(max_batch, buckets[0])
        self.scheduler = BatchScheduler(
            max_batch=max_batch, max_wait_s=max_wait_s,
            buckets=tuple(buckets))
        self.async_dispatch = async_dispatch
        self.donate_input = donate_input
        self.clock = clock
        self.retry = retry
        self.max_queue = max_queue
        self.validate = validate
        self.watchdog_s = watchdog_s
        self._sleep = sleep if sleep is not None \
            else (lambda s: time.sleep(min(s, 0.05)))
        # Per-bucket degradation ladders (DESIGN.md §14.3): one
        # pathological bucket shape demotes only its own ladder;
        # ``health.mode`` is the worst bucket's rung (the PR 7 surface).
        self.health = BucketHealth(
            engine.matmul_mode, demote_after=demote_after,
            probe_after_s=probe_after_s) if degrade else None
        self._pending: _InFlight | None = None
        # Requests resolved ``error`` since the last step() returned —
        # terminal completions, so step/drain hand them back to callers
        # alongside the served ones.
        self._errored: list[Request] = []
        self._metrics = ServingMetrics(clock)
        # Postmortem ring of recent request records (DESIGN.md §10.3);
        # multi-tenant lanes stamp their tenant onto every record.
        self.flight = FlightRecorder(
            flight_capacity,
            tags={"tenant": tenant} if tenant is not None else None)
        # Rows dispatched to the device since construction (padded bucket
        # rows, i.e. what the accelerator actually paid for) — the cost
        # signal weighted-fair multiplexing charges each tenant's vtime.
        self.dispatched_rows = 0
        # AOT artifact restore (DESIGN.md §12): load serialized bucket
        # executables before the first request so serving starts with
        # zero traces; per-bucket misses fall back to live compile.
        self.artifact_report: dict | None = None
        if artifact is not None:
            self.artifact_report = engine.load_artifact(
                artifact, donate_input=donate_input,
                data_parallel=self.data_parallel,
                buckets=tuple(self.scheduler.buckets))

    # ---- executable cache -------------------------------------------------
    def _executable(self, bucket: int, mode: str | None = None):
        kw = {}
        if self.pipeline_devices is not None:
            kw["pipeline"] = self.pipeline_devices
        return self.engine.compile(bucket, donate_input=self.donate_input,
                                   data_parallel=self.data_parallel,
                                   mode=mode, **kw)

    def compile_buckets(self) -> dict[int, float]:
        """Precompile (and autotune) every bucket; returns seconds spent
        per bucket.  After this, serving any mixed-size request stream
        triggers zero retraces (``engine.trace_count`` stays flat)."""
        timings: dict[int, float] = {}
        for b in self.scheduler.buckets:
            with _trace.span("compile.bucket", "compile", bucket=b,
                             data_parallel=self.data_parallel):
                t0 = time.perf_counter()
                exe = self._executable(b)
                x = self._place(np.zeros(self.engine._plan_shape(b),
                                         np.uint8))
                jax.block_until_ready(exe(x))
                timings[b] = time.perf_counter() - t0
        return timings

    # ---- placement --------------------------------------------------------
    def _place(self, x_np: np.ndarray):
        if self.mesh is None:
            return jnp.asarray(x_np)
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        return jax.device_put(x_np, NamedSharding(self.mesh,
                                                  P(self.data_axis)))

    # ---- admission --------------------------------------------------------
    def _payload_error(self, payload: Any) -> str | None:
        """Why this payload cannot be served, or None when it can.

        Checked against the engine's input spec at the protocol edge so
        a malformed payload resolves alone instead of poisoning the
        whole assembled bucket batch it would have ridden in."""
        try:
            arr = np.asarray(payload)
        except Exception as e:          # noqa: BLE001 — any failure rejects
            return f"payload is not array-like: {e}"
        if not np.issubdtype(arr.dtype, np.number):
            # object arrays, strings, datetimes, ... — anything numpy
            # coerces without making numbers out of it.
            return f"payload dtype {arr.dtype} is not numeric"
        if np.issubdtype(arr.dtype, np.floating) \
                and not bool(np.isfinite(arr).all()):
            return "payload contains NaN/Inf"
        if self.preprocess is None:
            want = self.engine._plan_shape(1)[1:]
            if tuple(arr.shape) != tuple(want):
                return (f"payload shape {tuple(arr.shape)} != engine "
                        f"input {tuple(want)}")
        return None

    def _journal_resolve(self, r: Request) -> None:
        if self.journal is not None and r.jid is not None:
            self.journal.resolve(r.jid, r.outcome, error=r.error)

    def _reject(self, payload: Any, reason: str, now: float,
                deadline_s: float | None,
                jid: int | None = None) -> Request:
        r = Request(payload, deadline_s=deadline_s)
        r.jid = jid
        r.arrival_s = now
        r.resolve("rejected", error=reason)
        self._journal_resolve(r)
        self._metrics.record_rejected()
        self.flight.record(id=r.id, outcome="rejected", error=reason,
                           arrival_s=now, done_s=now, latency_s=0.0)
        _trace.instant("serve.reject", "serve", req=r.id, reason=reason)
        return r

    # ---- request lifecycle ------------------------------------------------
    def submit(self, payload: Any, deadline_s: float | None = None,
               now: float | None = None, jid: int | None = None) -> Request:
        """``jid`` is the journal-replay path (DESIGN.md §14.3): the
        record is already on disk, so the server attaches the identity
        instead of journaling a duplicate submit."""
        # Arrival is stamped from the server's clock so latency samples
        # stay in one clock domain when a fake clock is injected.
        now = self.clock() if now is None else now
        if self.validate:
            err = self._payload_error(payload)
            if err is not None:
                return self._reject(payload, err, now, deadline_s, jid=jid)
        if self.max_queue is not None \
                and len(self.scheduler) >= self.max_queue:
            return self._reject(
                payload, f"queue full ({len(self.scheduler)} >= "
                         f"max_queue={self.max_queue})", now, deadline_s,
                jid=jid)
        if self.journal is not None and jid is None:
            # WAL order: the submit record hits disk before the request
            # enters the scheduler — a crash in between replays it.
            jid = self.journal.submit("bnn", payload)
        r = self.scheduler.submit(payload, deadline_s=deadline_s, now=now)
        r.jid = jid
        _trace.instant("serve.submit", "serve", req=r.id)
        return r

    def poll(self, request: Request) -> bool:
        return request.done

    # ---- failure handling -------------------------------------------------
    def _retry_or_fail(self, r: Request, exc: Exception, now: float,
                       requeue: list[Request]) -> None:
        """One failed attempt for one request: back off and requeue, or
        resolve ``error`` when attempts are exhausted."""
        r.attempts += 1
        max_attempts = self.retry.max_attempts if self.retry else 1
        if r.attempts < max_attempts:
            r.not_before = now + self.retry.backoff_s(r.attempts)
            self._metrics.record_retry()
            _trace.instant("serve.retry", "serve", req=r.id,
                           attempt=r.attempts)
            requeue.append(r)
            return
        r.resolve("error", error=f"{type(exc).__name__}: {exc}")
        self._journal_resolve(r)
        self._metrics.record_error()
        self._errored.append(r)
        self.flight.record(
            id=r.id, outcome="error", error=r.error, attempts=r.attempts,
            arrival_s=r.arrival_s, deadline_s=r.deadline_s, done_s=now,
            latency_s=now - r.arrival_s)
        _trace.instant("serve.error", "serve", req=r.id)

    def _note_demotion(self, now: float, bucket: int) -> None:
        d = self.health.ladder(bucket).demotions[-1]
        self._metrics.record_degraded()
        _obs_metrics.get_registry().event(
            "demotion", server="bnn", **d)
        self.flight.record(kind="demotion", outcome="demoted",
                           from_mode=d["from_mode"], to_mode=d["to_mode"],
                           bucket=bucket, done_s=now)
        _trace.instant("serve.demote", "serve", bucket=bucket,
                       from_mode=d["from_mode"], to_mode=d["to_mode"])

    def _on_batch_failure(self, batch: list[Request], exc: Exception,
                          now: float, mode: str | None,
                          probing: bool, bucket: int) -> None:
        """A whole dispatched/scattered batch failed: update the
        bucket's backend-health ladder (possibly demoting it — other
        buckets are untouched), then retry-or-fail each request."""
        if self.health is not None:
            if probing:
                self.health.probe_failed(bucket, mode, now)
            elif self.health.record_failure(bucket, now) is not None:
                self._note_demotion(now, bucket)
        requeue: list[Request] = []
        for r in batch:
            self._retry_or_fail(r, exc, now, requeue)
        if requeue:
            self.scheduler.requeue(requeue)

    # ---- dispatch / scatter ----------------------------------------------
    def _stage_rows(self, batch: list[Request], payloads: list[Any]
                    ) -> tuple[list[np.ndarray], list[Request],
                               list[int], list[tuple[Request, Exception]]]:
        """Host staging with per-row fault isolation: a payload whose
        conversion/preprocess raises is zero-filled (zeros are inert —
        the same trick bucket padding uses) so the rest of the batch
        still dispatches; its request is returned as a failure."""
        zero_row: np.ndarray | None = None
        rows: list[np.ndarray | None] = []
        kept: list[Request] = []
        row_idx: list[int] = []
        failures: list[tuple[Request, Exception]] = []
        for i, p in enumerate(payloads):
            r = batch[i] if i < len(batch) else None
            try:
                row = np.asarray(p)
                if r is not None and _faults._PLAN is not None:
                    _faults.maybe_fault("server.preprocess", req=r.id)
                if self.preprocess is not None:
                    row = self.preprocess(row)
                rows.append(row)
                if r is not None:
                    kept.append(r)
                    row_idx.append(i)
            except Exception as e:      # noqa: BLE001 — isolate the row
                rows.append(None)
                if r is not None:
                    failures.append((r, e))
        if zero_row is None:
            zero_row = np.zeros(self.engine._plan_shape(1)[1:], np.uint8)
        return ([row if row is not None else zero_row for row in rows],
                kept, row_idx, failures)

    def _dispatch(self, batch: list[Request], payloads: list[Any],
                  mode: str | None = None
                  ) -> tuple[_InFlight | None,
                             list[tuple[Request, Exception]]]:
        t0 = self.clock()
        with _trace.span("serve.stage", "serve", bucket=len(payloads),
                         n_real=len(batch)):
            rows, kept, row_idx, failures = self._stage_rows(batch,
                                                             payloads)
        if not kept:
            return None, failures
        if _faults._PLAN is not None:
            _faults.maybe_fault("server.dispatch", bucket=len(rows),
                                mode=mode or self.engine.matmul_mode,
                                tenant=self.tenant)
        with _trace.span("serve.dispatch", "serve", bucket=len(rows),
                         mode=mode):
            x = self._place(np.stack(rows))
            out = self._executable(len(rows), mode)(x)  # async: returns now
        t1 = self.clock()
        self.dispatched_rows += len(rows)
        self._metrics.mark_dispatch(bucket=len(rows))
        return (_InFlight(kept, row_idx, out, len(rows), t1, t1 - t0,
                          mode), failures)

    def _try_dispatch(self, batch: list[Request], payloads: list[Any],
                      now: float) -> _InFlight | None:
        """Dispatch with the full failure protocol: per-bucket mode
        selection (this bucket's degradation ladder + quarantine
        re-probe), batch-level retry on failure, per-row failure
        resolution."""
        bucket = len(payloads)
        mode, probing = None, False
        if self.health is not None:
            # materialize this bucket's ladder at first dispatch so the
            # per-bucket surface (metrics, snapshot) covers every bucket
            # that actually served, not only the ones that failed
            self.health.ladder(bucket)
            probe = self.health.probe_due(bucket, now)
            mode, probing = ((probe, True) if probe is not None
                             else (self.health.mode_for(bucket), False))
        try:
            flight, failures = self._dispatch(batch, payloads, mode=mode)
        except Exception as e:          # noqa: BLE001 — never kill the loop
            self._on_batch_failure(batch, e, now, mode, probing, bucket)
            return None
        requeue: list[Request] = []
        for r, exc in failures:
            self._retry_or_fail(r, exc, now, requeue)
        if requeue:
            self.scheduler.requeue(requeue)
        # Health verdicts wait for the readback: an async dispatch
        # returning is no proof the executable works, and crediting it
        # here would let interleaved dispatches reset the
        # consecutive-failure count between two readback faults.
        if flight is not None:
            flight.probing = probing
        return flight

    def _readback(self, flight: _InFlight) -> np.ndarray:
        """The one blocking point, optionally watchdog-bounded: a
        stalled executable becomes :class:`WatchdogTimeout` instead of a
        hung serve loop (the stuck thread is daemonized and abandoned —
        its buffer is dropped on the floor, not replayed)."""
        def blocking() -> np.ndarray:
            if _faults._PLAN is not None:
                _faults.maybe_fault("server.device", bucket=flight.bucket,
                                    tenant=self.tenant)
            return np.asarray(flight.out)

        if self.watchdog_s is None:
            return blocking()
        box: dict[str, Any] = {}

        def work():
            try:
                box["out"] = blocking()
            except Exception as e:      # noqa: BLE001 — re-raised below
                box["err"] = e

        th = threading.Thread(target=work, daemon=True)
        th.start()
        th.join(self.watchdog_s)
        if th.is_alive():
            raise WatchdogTimeout(
                f"device readback exceeded watchdog_s={self.watchdog_s}")
        if "err" in box:
            raise box["err"]
        return box["out"]

    def _scatter(self, flight: _InFlight) -> list[Request]:
        with _trace.span("serve.device", "serve", bucket=flight.bucket):
            host = self._readback(flight)   # the only blocking point
        now = self.clock()
        with _trace.span("serve.scatter", "serve",
                         n_real=len(flight.batch)):
            for r, i in zip(flight.batch, flight.row_idx):
                r.resolve("served", host[i])
                self._journal_resolve(r)
        self._metrics.record([now - r.arrival_s for r in flight.batch])
        for r in flight.batch:
            self.flight.record(
                id=r.id, outcome="served", bucket=flight.bucket,
                arrival_s=r.arrival_s, deadline_s=r.deadline_s,
                dispatched_s=flight.t_dispatch, done_s=now,
                queue_s=flight.t_dispatch - r.arrival_s,
                stage_s=flight.stage_s, latency_s=now - r.arrival_s,
                attempts=r.attempts, mode=flight.mode)
        return flight.batch

    def _try_scatter(self, flight: _InFlight,
                     now: float | None = None) -> list[Request]:
        try:
            done = self._scatter(flight)
        except Exception as e:          # noqa: BLE001 — never kill the loop
            now = self.clock() if now is None else now
            self._on_batch_failure(flight.batch, e, now, flight.mode,
                                   probing=flight.probing,
                                   bucket=flight.bucket)
            return []
        if self.health is not None:
            if flight.probing:
                # The quarantined faster mode survived its probe end to
                # end: promote this bucket's ladder back up.
                self.health.promote(flight.bucket, flight.mode)
                _trace.instant("serve.promote", "serve", mode=flight.mode,
                               bucket=flight.bucket)
                self.flight.record(kind="promotion", outcome="promoted",
                                   to_mode=flight.mode,
                                   bucket=flight.bucket,
                                   done_s=self.clock() if now is None
                                   else now)
            else:
                self.health.record_success(flight.bucket)
        return done

    def _record_shed(self, shed: list[Request], now: float) -> None:
        self._metrics.record_dropped(len(shed))
        for r in shed:
            self._journal_resolve(r)
            self.flight.record(id=r.id, outcome="shed",
                               arrival_s=r.arrival_s,
                               deadline_s=r.deadline_s, done_s=now,
                               latency_s=now - r.arrival_s)
            _trace.instant("serve.shed", "serve", req=r.id)

    def step(self, now: float | None = None,
             force: bool = False, dispatch: bool = True) -> list[Request]:
        """One serving tick: dispatch the next batch (policy permitting),
        then scatter the previously in-flight one.  Under async dispatch
        the new batch's device work overlaps the old batch's readback;
        synchronously each batch completes before the next is assembled.
        Returns the requests completed this tick.  Failures never
        escape: a faulted batch re-queues (retry policy) or resolves
        ``error``, and the loop keeps serving.

        ``dispatch=False`` runs the housekeeping half only — shed
        expired requests, scatter the in-flight batch, hand back error
        completions — without assembling a new batch.  A multi-tenant
        arbiter uses it to retire a lane's in-flight work on ticks where
        fair-share admission picked a different lane."""
        now = self.clock() if now is None else now
        # Shed before assembly so the flight recorder sees every deadline
        # outcome (padded_batch sheds too, but silently — same policy,
        # same ``now``, so nothing is left for it to shed).
        shed = self.scheduler.shed_expired(now)
        if shed:
            self._record_shed(shed, now)
        flight = None
        if dispatch:
            with _trace.span("serve.assemble", "serve"):
                got = self.scheduler.padded_batch(now, force=force)
            if got is not None:
                flight = self._try_dispatch(*got, now)
        done: list[Request] = []
        if not self.async_dispatch:
            if flight is not None:
                done = self._try_scatter(flight, now)
        else:
            if self._pending is not None:
                pending, self._pending = self._pending, None
                done = self._try_scatter(pending, now)
            self._pending = flight
        # Error-resolved requests are terminal completions too.
        if self._errored:
            done, self._errored = done + self._errored, []
        return done

    def _abort_wedged(self, now: float) -> list[Request]:
        """Drain's last resort: terminally resolve everything still
        outstanding as ``error`` so no request is left dangling."""
        stuck: list[Request] = []
        if self._pending is not None:
            stuck += self._pending.batch
            self._pending = None
        stuck += self.scheduler.next_batch(now, force=True) or []
        while len(self.scheduler):     # backoff'd stragglers too
            r = self.scheduler._queue.popleft()
            stuck.append(r)
        for r in stuck:
            if r.done:
                continue
            r.resolve("error", error="drain wedged: step budget exhausted")
            self._journal_resolve(r)
            self._metrics.record_error()
            self.flight.record(id=r.id, outcome="error", error=r.error,
                               arrival_s=r.arrival_s, done_s=now,
                               latency_s=now - r.arrival_s)
        return [r for r in stuck if r.outcome == "error"]

    def drain(self, now: float | None = None,
              max_steps: int | None = None) -> list[Request]:
        """Serve until the queue is empty and nothing is in flight
        (skipping the batch-wait policy: drain is a flush).  Returns the
        requests completed during the drain.

        Bounded: at most ``max_steps`` ticks (default: generous for the
        current queue × retry budget), after which anything still
        outstanding resolves ``error`` — a wedged in-flight batch
        surfaces instead of hanging the caller forever.  When every
        queued request is in retry backoff, waits it out through the
        injectable ``sleep`` (a fixed explicit ``now`` cannot advance,
        so backoff under it falls to the step bound)."""
        if max_steps is None:
            budget = self.retry.max_attempts if self.retry else 1
            max_steps = 4 * (len(self.scheduler) + 2) * budget + 16
        done: list[Request] = []
        steps = 0
        while len(self.scheduler) or self._pending is not None:
            if steps >= max_steps:
                done += self._abort_wedged(
                    self.clock() if now is None else now)
                break
            steps += 1
            t = self.clock() if now is None else now
            done += self.step(t, force=True)
            if self._pending is None and len(self.scheduler):
                wait = self.scheduler.backoff_wait(t)
                if wait is not None and wait > 0:
                    self._sleep(wait)
        return done

    # ---- observability ----------------------------------------------------
    @property
    def metrics_registry(self):
        """This server's metric series (``repro.obs.MetricsRegistry``):
        ``serve.latency_s``, ``serve.bucket_size`` (per-bucket dispatch
        histogram), ``serve.served``, ``serve.dropped``, plus the
        resilience counters ``serve.retries`` / ``serve.errors`` /
        ``serve.rejected`` / ``serve.degraded``."""
        return self._metrics.registry

    @property
    def queue_depth(self) -> int:
        inflight = len(self._pending.batch) if self._pending else 0
        return len(self.scheduler) + inflight

    def metrics(self) -> dict:
        """p50/p95 request latency (submit→scatter, ms), served/dropped
        counts, resilience counters (retries/errors/rejected/degraded),
        live queue depth, the current serving mode, and throughput over
        the busy window (first dispatch → last scatter)."""
        extra = {"tenant": self.tenant} if self.tenant is not None else {}
        if self.health is not None and self.health.ladders:
            # Per-bucket ladder state (DESIGN.md §14.3): which buckets
            # are demoted/quarantined, independent of the worst-case
            # ``mode`` reported below.
            extra["bucket_health"] = {
                b: lad.snapshot(self.clock())
                for b, lad in sorted(self.health.ladders.items())}
        if self.pipeline_devices is not None:
            extra["placement"] = {"kind": "pipeline",
                                  "devices": [str(d) for d in
                                              self.pipeline_devices]}
        elif self.placement is not None:
            extra["placement"] = {"kind": "data",
                                  "shards": self.data_parallel}
        return self._metrics.snapshot(
            dropped=self.scheduler.dropped,
            queue_depth=self.queue_depth,
            async_dispatch=self.async_dispatch,
            data_parallel=self.data_parallel,
            mode=(self.health.mode if self.health is not None
                  else self.engine.matmul_mode),
            buckets=list(self.scheduler.buckets), **extra)
