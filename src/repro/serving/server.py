"""InferenceServer: the production serving subsystem (DESIGN.md §7).

One object owns the whole serve path the paper's phone loop inlines:

* a :class:`~repro.serving.scheduler.BatchScheduler` assembling
  deadline-aware, bucket-padded batches;
* the engine's **per-bucket executable cache** —
  ``compile_buckets()`` precompiles (and, in ``auto`` mode, autotunes)
  one :class:`GraphExecutor` per bucket so serve time never retraces;
* **async double-buffered dispatch** — batch *k+1* is dispatched while
  batch *k*'s device work is still in flight; the host blocks only when
  scattering results (``np.asarray`` at the pop of the one-deep pipeline),
  and each batch's input buffer is donated to the device;
* optional **data-parallel batch sharding** — given a mesh, inputs are
  placed with ``jax.sharding.NamedSharding(mesh, P(data_axis))`` so XLA
  splits every bucket across the data axis; buckets are rounded up to
  shard evenly and autotuning runs at the per-device shard shape (reusing
  the single-device winners).

The server surface is the protocol both serving paths share (the LM
decode server implements the same one): ``submit`` / ``poll`` / ``step``
/ ``drain`` plus ``metrics()`` (p50/p95 latency, queue depth, throughput,
dropped count — definitions in DESIGN.md §7.4).
"""

from __future__ import annotations

import time
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

# Canonical home of the latency math and serving metrics is the
# observability layer (DESIGN.md §10); re-exported here for the existing
# import surface.
from repro.obs import FlightRecorder
from repro.obs import trace as _trace
from repro.obs.metrics import ServingMetrics, percentile  # noqa: F401
from repro.serving.scheduler import BatchScheduler, Request


@runtime_checkable
class Server(Protocol):
    """What a serving front end looks like, BNN or LM."""

    def submit(self, payload: Any, **kw) -> Request: ...

    def poll(self, request: Request) -> bool: ...

    def drain(self) -> list[Request]: ...

    def metrics(self) -> dict: ...


class _InFlight:
    """One dispatched batch: requests + the device array still computing,
    plus the dispatch stamp and host-stage timings the flight recorder
    attaches to each request at scatter."""

    __slots__ = ("batch", "out", "bucket", "t_dispatch", "stage_s")

    def __init__(self, batch: list[Request], out, bucket: int,
                 t_dispatch: float, stage_s: float):
        self.batch = batch
        self.out = out
        self.bucket = bucket
        self.t_dispatch = t_dispatch
        self.stage_s = stage_s


class InferenceServer:
    """Batched image-inference front end over a PhoneBitEngine.

    Parameters
    ----------
    engine:          a :class:`~repro.serving.engine.PhoneBitEngine` (or
                     anything with ``compile(bs, donate_input=,
                     data_parallel=) -> callable`` and ``_plan_shape``).
    buckets:         compiled batch sizes; mixed-size traffic is padded up
                     to the nearest one.
    async_dispatch:  double-buffer dispatch (the default); ``False`` gives
                     the synchronous drain loop (benchmark baseline).
    preprocess:      optional per-payload host transform (decode / crop /
                     normalize) applied at batch staging.  Under async
                     dispatch batch k+1's preprocessing runs while batch
                     k's device work is in flight — host preprocessing is
                     the classic serving cost double-buffering hides.
    mesh/data_axis:  optional device mesh for data-parallel sharding.
    flight_capacity: size of the flight-recorder ring (recent request
                     records for postmortems; ``server.flight.dump()``).
    clock:           injectable monotonic clock (tests use a fake).

    Observability (DESIGN.md §10): when a tracer is installed
    (``repro.obs.trace.install()``) each serving stage emits a span —
    ``serve.submit`` (instant), ``serve.assemble``, ``serve.stage``,
    ``serve.dispatch``, ``serve.device``, ``serve.scatter`` — all
    host-side, so tracing never retraces the compiled executables.
    Disabled (the default), every site is one global read.
    """

    def __init__(self, engine, *, max_batch: int = 8,
                 max_wait_s: float = 0.0,
                 buckets: tuple[int, ...] = (1, 2, 4, 8),
                 async_dispatch: bool = True,
                 donate_input: bool = True,
                 preprocess: Callable[[np.ndarray], np.ndarray]
                 | None = None,
                 mesh=None, data_axis: str = "data",
                 flight_capacity: int = 256,
                 clock: Callable[[], float] = time.monotonic):
        self.engine = engine
        self.preprocess = preprocess
        self.mesh, self.data_axis = mesh, data_axis
        self.data_parallel = int(mesh.shape[data_axis]) if mesh is not None \
            else 1
        if self.data_parallel > 1:
            dp = self.data_parallel
            buckets = tuple(sorted({-(-b // dp) * dp for b in buckets}))
            max_batch = max(max_batch, buckets[0])
        self.scheduler = BatchScheduler(
            max_batch=max_batch, max_wait_s=max_wait_s,
            buckets=tuple(buckets))
        self.async_dispatch = async_dispatch
        self.donate_input = donate_input
        self.clock = clock
        self._pending: _InFlight | None = None
        self._metrics = ServingMetrics(clock)
        # Postmortem ring of recent request records (DESIGN.md §10.3).
        self.flight = FlightRecorder(flight_capacity)

    # ---- executable cache -------------------------------------------------
    def _executable(self, bucket: int):
        return self.engine.compile(bucket, donate_input=self.donate_input,
                                   data_parallel=self.data_parallel)

    def compile_buckets(self) -> dict[int, float]:
        """Precompile (and autotune) every bucket; returns seconds spent
        per bucket.  After this, serving any mixed-size request stream
        triggers zero retraces (``engine.trace_count`` stays flat)."""
        timings: dict[int, float] = {}
        for b in self.scheduler.buckets:
            with _trace.span("compile.bucket", "compile", bucket=b,
                             data_parallel=self.data_parallel):
                t0 = time.perf_counter()
                exe = self._executable(b)
                x = self._place(np.zeros(self.engine._plan_shape(b),
                                         np.uint8))
                jax.block_until_ready(exe(x))
                timings[b] = time.perf_counter() - t0
        return timings

    # ---- placement --------------------------------------------------------
    def _place(self, x_np: np.ndarray):
        if self.mesh is None:
            return jnp.asarray(x_np)
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        return jax.device_put(x_np, NamedSharding(self.mesh,
                                                  P(self.data_axis)))

    # ---- request lifecycle ------------------------------------------------
    def submit(self, payload: Any, deadline_s: float | None = None,
               now: float | None = None) -> Request:
        # Arrival is stamped from the server's clock so latency samples
        # stay in one clock domain when a fake clock is injected.
        now = self.clock() if now is None else now
        r = self.scheduler.submit(payload, deadline_s=deadline_s, now=now)
        _trace.instant("serve.submit", "serve", req=r.id)
        return r

    def poll(self, request: Request) -> bool:
        return request.done

    # ---- dispatch / scatter ----------------------------------------------
    def _dispatch(self, batch: list[Request],
                  payloads: list[Any]) -> _InFlight:
        t0 = self.clock()
        with _trace.span("serve.stage", "serve", bucket=len(payloads),
                         n_real=len(batch)):
            rows = [np.asarray(p) for p in payloads]
            if self.preprocess is not None:     # pads go through it too
                rows = [self.preprocess(r) for r in rows]
            x = self._place(np.stack(rows))
        with _trace.span("serve.dispatch", "serve", bucket=x.shape[0]):
            out = self._executable(x.shape[0])(x)   # async: returns now
        t1 = self.clock()
        self._metrics.mark_dispatch(bucket=len(payloads))
        return _InFlight(batch, out, len(payloads), t1, t1 - t0)

    def _scatter(self, flight: _InFlight) -> list[Request]:
        with _trace.span("serve.device", "serve", bucket=flight.bucket):
            host = np.asarray(flight.out)       # the only blocking point
        now = self.clock()
        with _trace.span("serve.scatter", "serve",
                         n_real=len(flight.batch)):
            for r, row in zip(flight.batch, host):
                r.result, r.done = row, True
        self._metrics.record([now - r.arrival_s for r in flight.batch])
        for r in flight.batch:
            self.flight.record(
                id=r.id, outcome="served", bucket=flight.bucket,
                arrival_s=r.arrival_s, deadline_s=r.deadline_s,
                dispatched_s=flight.t_dispatch, done_s=now,
                queue_s=flight.t_dispatch - r.arrival_s,
                stage_s=flight.stage_s, latency_s=now - r.arrival_s)
        return flight.batch

    def _record_shed(self, shed: list[Request], now: float) -> None:
        self._metrics.record_dropped(len(shed))
        for r in shed:
            self.flight.record(id=r.id, outcome="shed",
                               arrival_s=r.arrival_s,
                               deadline_s=r.deadline_s, done_s=now,
                               latency_s=now - r.arrival_s)
            _trace.instant("serve.shed", "serve", req=r.id)

    def step(self, now: float | None = None,
             force: bool = False) -> list[Request]:
        """One serving tick: dispatch the next batch (policy permitting),
        then scatter the previously in-flight one.  Under async dispatch
        the new batch's device work overlaps the old batch's readback;
        synchronously each batch completes before the next is assembled.
        Returns the requests completed this tick."""
        now = self.clock() if now is None else now
        # Shed before assembly so the flight recorder sees every deadline
        # outcome (padded_batch sheds too, but silently — same policy,
        # same ``now``, so nothing is left for it to shed).
        shed = self.scheduler.shed_expired(now)
        if shed:
            self._record_shed(shed, now)
        with _trace.span("serve.assemble", "serve"):
            got = self.scheduler.padded_batch(now, force=force)
        flight = self._dispatch(*got) if got is not None else None
        if not self.async_dispatch and flight is not None:
            return self._scatter(flight)
        done: list[Request] = []
        if self._pending is not None:
            done = self._scatter(self._pending)
        self._pending = flight
        return done

    def drain(self, now: float | None = None) -> list[Request]:
        """Serve until the queue is empty and nothing is in flight
        (skipping the batch-wait policy: drain is a flush).  Returns the
        requests completed during the drain."""
        done: list[Request] = []
        while len(self.scheduler) or self._pending is not None:
            done += self.step(now, force=True)
        return done

    # ---- observability ----------------------------------------------------
    @property
    def metrics_registry(self):
        """This server's metric series (``repro.obs.MetricsRegistry``):
        ``serve.latency_s``, ``serve.bucket_size`` (per-bucket dispatch
        histogram), ``serve.served``, ``serve.dropped``."""
        return self._metrics.registry

    @property
    def queue_depth(self) -> int:
        inflight = len(self._pending.batch) if self._pending else 0
        return len(self.scheduler) + inflight

    def metrics(self) -> dict:
        """p50/p95 request latency (submit→scatter, ms), served/dropped
        counts, live queue depth, and throughput over the busy window
        (first dispatch → last scatter)."""
        return self._metrics.snapshot(
            dropped=self.scheduler.dropped,
            queue_depth=self.queue_depth,
            async_dispatch=self.async_dispatch,
            data_parallel=self.data_parallel,
            buckets=list(self.scheduler.buckets))
