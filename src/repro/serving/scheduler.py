"""Request batching for serving.

The paper's engine serves one image at a time on a phone; at datacenter
scale the same engine fronts a batch scheduler.  Policy: assemble the
largest batch available up to ``max_batch``, but never hold a request
longer than ``max_wait_s`` (latency/throughput knob).  Batches are padded
to the nearest compiled bucket size so XLA never recompiles at serve time;
padding is **zero-filled** (shaped like the last real payload) and the
padded tail of the results is discarded — pad rows cost device FLOPs but
never replay a real request through a potentially stateful ``run``.

Overload protection: a request may carry a ``deadline_s`` (seconds of
queue residency it will tolerate).  Expired requests are shed — popped
with ``done=True, result=None`` and counted in ``dropped`` — so a queue
growing faster than the engine drains it sheds load instead of growing
without bound.

Every time-dependent method takes an injectable ``now=`` (monotonic
seconds) so policy is testable with a fake clock.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable

import numpy as np


#: The terminal request outcomes (DESIGN.md §11): every submitted
#: request ends ``done=True`` with exactly one of these.
OUTCOMES = ("served", "shed", "error", "rejected")


@dataclasses.dataclass
class Request:
    payload: Any
    arrival_s: float = dataclasses.field(default_factory=time.monotonic)
    deadline_s: float | None = None   # max queue residency; None = patient
    id: int = dataclasses.field(
        default_factory=itertools.count().__next__)
    result: Any = None
    done: bool = False
    # ---- resilience state (DESIGN.md §11) -------------------------------
    outcome: str | None = None        # one of OUTCOMES once done
    error: str | None = None          # terminal failure reason
    attempts: int = 0                 # dispatch tries so far
    not_before: float | None = None   # retry backoff: ineligible until
    # ---- crash safety (DESIGN.md §14) -----------------------------------
    jid: int | None = None            # durable journal id, if journaled

    def expired(self, now: float) -> bool:
        return (self.deadline_s is not None
                and (now - self.arrival_s) >= self.deadline_s)

    def eligible(self, now: float) -> bool:
        """In-backoff requests sit in the queue but skip assembly."""
        return self.not_before is None or now >= self.not_before

    def resolve(self, outcome: str, result: Any = None,
                error: str | None = None) -> "Request":
        assert outcome in OUTCOMES, outcome
        self.result, self.done = result, True
        self.outcome, self.error = outcome, error
        return self


def _zero_like(payload: Any) -> Any:
    """A zero payload with the shape/dtype of a real one (batch padding)."""
    return np.zeros_like(np.asarray(payload))


def shed_expired_requests(queue: "deque[Request]", now: float
                          ) -> tuple["deque[Request]", list[Request]]:
    """Partition a request queue into (kept, shed-by-deadline); shed
    requests are completed with ``result=None``.  The one shed policy —
    used by both the batch scheduler and the LM admission queue."""
    kept: deque[Request] = deque()
    shed: list[Request] = []
    for r in queue:
        if r.expired(now):
            r.resolve("shed")
            shed.append(r)
        else:
            kept.append(r)
    return kept, shed


def buckets_for(max_batch: int,
                ladder: tuple[int, ...] = (1, 2, 4, 8, 16)) -> tuple[int, ...]:
    """The canonical bucket set for a max batch size: the power-of-two
    ladder below it plus ``max_batch`` itself (so the scheduler invariant
    ``buckets[-1] >= max_batch`` holds for any value)."""
    return tuple(sorted({b for b in ladder if b < max_batch} | {max_batch}))


@dataclasses.dataclass
class BatchScheduler:
    max_batch: int = 8
    max_wait_s: float = 0.005
    buckets: tuple[int, ...] = (1, 2, 4, 8)

    def __post_init__(self):
        self._queue: deque[Request] = deque()
        self.dropped = 0          # deadline-shed requests (overload stat)
        assert tuple(sorted(self.buckets)) == tuple(self.buckets)
        assert self.buckets[-1] >= self.max_batch

    def submit(self, payload: Any, deadline_s: float | None = None,
               now: float | None = None) -> Request:
        r = Request(payload, deadline_s=deadline_s)
        if now is not None:
            r.arrival_s = now
        self._queue.append(r)
        return r

    def __len__(self) -> int:
        return len(self._queue)

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    # ---- deadline shedding -----------------------------------------------
    def shed_expired(self, now: float | None = None) -> list[Request]:
        """Pop every expired request (done, result=None); count them."""
        if not self._queue:
            return []
        now = time.monotonic() if now is None else now
        self._queue, shed = shed_expired_requests(self._queue, now)
        self.dropped += len(shed)
        return shed

    # ---- batch assembly ---------------------------------------------------
    def ready(self, now: float | None = None) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        now = time.monotonic() if now is None else now
        return (now - self._queue[0].arrival_s) >= self.max_wait_s

    def next_batch(self, now: float | None = None,
                   force: bool = False) -> list[Request] | None:
        """Shed expired requests, then pop up to max_batch *eligible*
        requests if the policy says go (``force=True`` skips the wait
        policy — final flush).  Requests in retry backoff
        (``not_before`` in the future) keep their queue position but are
        passed over until their delay elapses."""
        now = time.monotonic() if now is None else now
        self.shed_expired(now)
        if not (self._queue if force else self.ready(now)):
            return None
        take: list[Request] = []
        keep: deque[Request] = deque()
        for r in self._queue:
            if len(take) < self.max_batch and r.eligible(now):
                take.append(r)
            else:
                keep.append(r)
        if not take:
            return None
        self._queue = keep
        return take

    def requeue(self, requests: list[Request]) -> None:
        """Front-insert failed-batch requests for retry, preserving
        their relative order (they were at the head when popped)."""
        for r in reversed(requests):
            self._queue.appendleft(r)

    def backoff_wait(self, now: float) -> float | None:
        """Seconds until the soonest queued request leaves retry
        backoff, or None when the queue is empty / something is already
        eligible (i.e. only meaningful when assembly is starved purely
        by backoff)."""
        if not self._queue or any(r.eligible(now) for r in self._queue):
            return None
        return min(r.not_before for r in self._queue) - now

    def padded_batch(self, now: float | None = None, force: bool = False
                     ) -> tuple[list[Request], list[Any]] | None:
        """Pop a batch and zero-pad its payloads to the bucket size.

        The single batch-assembly path: every executed payload list is
        exactly a bucket size, and rows past ``len(batch)`` are padding.
        """
        batch = self.next_batch(now, force=force)
        if batch is None:
            return None
        bucket = self.bucket_for(len(batch))
        payloads = [r.payload for r in batch]
        pad = bucket - len(batch)
        if pad:
            payloads = payloads + [_zero_like(payloads[-1])] * pad
        return batch, payloads

    def drain(self, run: Callable[[list[Any]], list[Any]],
              now: float | None = None) -> list[Request]:
        """Assemble, zero-pad to bucket, execute, scatter the real rows.

        ``run`` is always called with exactly a bucket-sized payload list;
        results beyond ``len(batch)`` are padding output and discarded.
        """
        got = self.padded_batch(now)
        if got is None:
            return []
        batch, payloads = got
        results = run(payloads)
        for r, out in zip(batch, results):
            r.resolve("served", out)
        return batch
