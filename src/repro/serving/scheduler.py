"""Request batching for serving.

The paper's engine serves one image at a time on a phone; at datacenter
scale the same engine fronts a batch scheduler.  Policy: assemble the
largest batch available up to ``max_batch``, but never hold a request
longer than ``max_wait_s`` (latency/throughput knob).  Batches are padded
to the nearest compiled bucket size so XLA never recompiles at serve time.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable


@dataclasses.dataclass
class Request:
    payload: Any
    arrival_s: float = dataclasses.field(default_factory=time.monotonic)
    id: int = dataclasses.field(
        default_factory=itertools.count().__next__)
    result: Any = None
    done: bool = False


@dataclasses.dataclass
class BatchScheduler:
    max_batch: int = 8
    max_wait_s: float = 0.005
    buckets: tuple[int, ...] = (1, 2, 4, 8)

    def __post_init__(self):
        self._queue: deque[Request] = deque()
        assert tuple(sorted(self.buckets)) == self.buckets
        assert self.buckets[-1] >= self.max_batch

    def submit(self, payload: Any) -> Request:
        r = Request(payload)
        self._queue.append(r)
        return r

    def __len__(self) -> int:
        return len(self._queue)

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def ready(self, now: float | None = None) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        now = time.monotonic() if now is None else now
        return (now - self._queue[0].arrival_s) >= self.max_wait_s

    def next_batch(self, now: float | None = None) -> list[Request] | None:
        """Pop up to max_batch requests if the policy says go."""
        if not self.ready(now):
            return None
        n = min(len(self._queue), self.max_batch)
        return [self._queue.popleft() for _ in range(n)]

    def drain(self, run: Callable[[list[Any]], list[Any]],
              now: float | None = None) -> list[Request]:
        """Assemble, pad to bucket, execute, scatter results."""
        batch = self.next_batch(now)
        if batch is None:
            return []
        bucket = self.bucket_for(len(batch))
        payloads = [r.payload for r in batch]
        pad = bucket - len(batch)
        if pad:
            payloads = payloads + [payloads[-1]] * pad
        results = run(payloads)
        for r, out in zip(batch, results):
            r.result, r.done = out, True
        return batch
