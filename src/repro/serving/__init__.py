"""Serving layer.

engine      PhoneBitEngine — the paper's deployment story (Fig 2/Fig 3):
            load a converted artifact, run the packed integer forward
scheduler   request batching: latency/throughput-bounded batch assembly
kv_cache    paged-lite KV cache manager for LM decode serving
lm_server   continuous-batching LM decode loop (prefill + decode steps)
"""

from repro.serving.engine import PhoneBitEngine
from repro.serving.scheduler import BatchScheduler, Request
from repro.serving.kv_cache import KVCacheManager

__all__ = ["PhoneBitEngine", "BatchScheduler", "Request", "KVCacheManager"]
