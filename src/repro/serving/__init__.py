"""Serving layer (DESIGN.md §7, §11).

engine      PhoneBitEngine — the paper's deployment story (Fig 2/Fig 3):
            load a converted artifact, run the packed integer forward;
            grows ``compile(batch)`` — the per-bucket executable cache
server      InferenceServer — the production front end: bucketed
            precompiled executables, async double-buffered dispatch,
            optional placement (data-parallel sharding or pipeline
            stages, DESIGN.md §13), p50/p95 metrics, retry/degrade
            resilience (every request terminally resolves)
scheduler   request batching: deadline-aware, latency/throughput-bounded
            batch assembly, zero-padded to compiled buckets
faults      seeded deterministic fault injection (FaultPlan/FaultSpec),
            retry backoff policy, and the backend degradation ladder
artifact    AOT executable artifacts (DESIGN.md §12): export compiled
            bucket executables + autotune winners + provenance meta to a
            versioned directory; load with zero serve-time traces
multiplex   MultiTenantServer — several workloads behind one front end:
            per-tenant server lanes, strict-priority + weighted-fair
            admission, per-tenant metrics and degradation isolation
kv_cache    paged-lite KV cache manager for LM decode serving
lm_server   continuous-batching LM decode loop speaking the same
            submit/poll/drain/metrics protocol as InferenceServer
recovery    crash-safe serving (DESIGN.md §14): consistent-cut KV
            checkpoint/restore for the LM decode loop and the durable
            JSONL request journal both servers can write through
"""

from repro.serving import faults
from repro.serving.artifact import (
    ARTIFACT_SCHEMA,
    ArtifactError,
    export_artifact,
    load_artifact,
    read_meta,
)
from repro.serving.engine import PhoneBitEngine
from repro.serving.faults import (
    DEGRADE_LADDER,
    BackendHealth,
    BucketHealth,
    FaultError,
    FaultPlan,
    FaultSpec,
    RetryPolicy,
    WatchdogTimeout,
)
from repro.serving.kv_cache import KVCacheManager
from repro.serving.multiplex import MultiTenantServer, TenantLane
from repro.serving.recovery import (
    KVCheckpointer,
    RequestJournal,
    replay_journal,
)
from repro.serving.scheduler import (
    OUTCOMES,
    BatchScheduler,
    Request,
    buckets_for,
)
from repro.serving.server import InferenceServer, Server

__all__ = ["PhoneBitEngine", "BatchScheduler", "Request", "KVCacheManager",
           "InferenceServer", "Server", "buckets_for", "faults",
           "FaultPlan", "FaultSpec", "FaultError", "RetryPolicy",
           "BackendHealth", "BucketHealth", "WatchdogTimeout",
           "DEGRADE_LADDER", "OUTCOMES", "ARTIFACT_SCHEMA",
           "ArtifactError", "export_artifact", "load_artifact",
           "read_meta", "MultiTenantServer", "TenantLane",
           "KVCheckpointer", "RequestJournal", "replay_journal"]
