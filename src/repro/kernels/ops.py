"""Jit'd wrappers + execution-path dispatch for the PhoneBit kernels.

``matmul_mode`` selects the engine for binary matmuls:

* ``"vpu_popcount"``  — paper-faithful xor+popcount Pallas kernel (C1).
* ``"mxu_pm1"``       — beyond-paper MXU kernel (unpack-to-bf16 in VMEM).
* ``"xla"``           — pure-JAX fallback (always available; what benchmarks
                        time on CPU and what models use under jit on any
                        backend).

On CPU the Pallas kernels run with ``interpret=True`` (bit-exact, slow) —
the TPU is the compile target, CPU interpret mode is the validator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import binary_ops, layer_integration, packing
from repro.kernels import (bitplane_pack as _bitplane_pack_mod,
                           fused_conv_bn_binarize as _fused_mod,
                           mxu_pm1_matmul as _mxu_mod,
                           xnor_popcount_matmul as _xnor_mod)
from repro.core.binary_conv import conv_out_size, extract_patches_packed

VALID_MODES = ("vpu_popcount", "mxu_pm1", "xla")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def binary_matmul_dot(a: jnp.ndarray, b: jnp.ndarray, k_valid: int,
                      mode: str = "vpu_popcount", **block_kw) -> jnp.ndarray:
    """Binary +-1 dots (M, N) int32; dispatches on execution path."""
    if mode == "vpu_popcount":
        cnt = _xnor_mod.xnor_popcount_matmul(
            a, b, interpret=_interpret(), **block_kw)
        return k_valid - 2 * cnt
    if mode == "mxu_pm1":
        return _mxu_mod.mxu_pm1_matmul(
            a, b, k_valid=k_valid, interpret=_interpret(), **block_kw)
    if mode == "xla":
        return binary_ops.packed_matmul_dot(a, b, k_valid)
    raise ValueError(f"unknown matmul mode {mode!r}; want one of {VALID_MODES}")


def matmul_counts(a: jnp.ndarray, b: jnp.ndarray,
                  word_weights: jnp.ndarray | None = None,
                  mode: str = "vpu_popcount", **block_kw) -> jnp.ndarray:
    if mode == "vpu_popcount":
        return _xnor_mod.xnor_popcount_matmul(
            a, b, word_weights, interpret=_interpret(), **block_kw)
    if mode == "xla":
        return binary_ops.packed_matmul_counts(a, b, word_weights=word_weights)
    raise ValueError(f"counts not supported for mode {mode!r}")


def fused_matmul_bn_binarize(a, b, p: layer_integration.IntegratedParams,
                             word_weights=None, mode: str = "vpu_popcount",
                             **block_kw) -> jnp.ndarray:
    """Integrated matmul+BN+sign+pack: (M, ceil(N/32)) int32."""
    if mode == "vpu_popcount":
        return _fused_mod.fused_matmul_bn_binarize(
            a, b, p.threshold, p.sign_flip, word_weights,
            interpret=_interpret(), **block_kw)
    if mode == "xla":
        cnt = binary_ops.packed_matmul_counts(a, b, word_weights=word_weights)
        bits = layer_integration.apply_threshold(cnt, p)
        return packing.pack_bits(bits, axis=-1)
    raise ValueError(f"fused path not supported for mode {mode!r}")


def fused_binary_conv2d(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
                        p: layer_integration.IntegratedParams,
                        kh: int, kw: int, stride: int = 1, pad: int = 0,
                        word_weights=None, mode: str = "vpu_popcount",
                        **block_kw) -> jnp.ndarray:
    """Conv wrapper: im2col on packed words + the fused kernel (C4+C6)."""
    patches = extract_patches_packed(x_packed, kh, kw, stride, pad)
    n, oh, ow, pw = patches.shape
    out = fused_matmul_bn_binarize(
        patches.reshape(n * oh * ow, pw), w_packed, p,
        word_weights=word_weights, mode=mode, **block_kw)
    return out.reshape(n, oh, ow, out.shape[-1])


def bitplane_pack(x: jnp.ndarray, **kw) -> jnp.ndarray:
    """(N,H,W,C) uint8 -> (N,H,W,8*Cw) packed planes via the Pallas kernel."""
    return _bitplane_pack_mod.bitplane_pack(x, interpret=_interpret(), **kw)
