"""Jit'd wrappers + execution-path dispatch for the PhoneBit kernels.

This module is the *single* conv/dense dispatch surface: the graph executor
and the flat legacy path both come through here, so every backend shares
one canonical patch-extraction + weight-packing convention
(``repro.core.binary_conv``) instead of parallel implementations.

Conv backends (``CONV_MODES``):

* ``"vpu_direct"``    — direct (im2col-free) fused Pallas kernel: input
                        tiles stream to VMEM once, KHxKW walked as in-VMEM
                        shifted reads, threshold+pack (+ OR-pool) epilogue
                        (DESIGN.md §5).  No patch tensor exists.
* ``"vpu_popcount"``  — paper-faithful xor+popcount Pallas kernel on
                        im2col patches (C1); the legacy im2col path, kept
                        as a selectable backend.
* ``"mxu_pm1"``       — beyond-paper MXU kernel (unpack-to-bf16 in VMEM).
* ``"xla"/"xla_pm1"`` — pure-JAX fallbacks (always available; what
                        benchmarks time on CPU and what models use under
                        jit on any backend).

On CPU the Pallas kernels run with ``interpret=True`` (bit-exact, slow) —
the TPU is the compile target, CPU interpret mode is the validator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binary_conv, binary_ops, layer_integration, packing
from repro.kernels import (bitplane_pack as _bitplane_pack_mod,
                           chain_conv as _chain_mod,
                           direct_conv_bn_binarize as _direct_mod,
                           fused_conv_bn_binarize as _fused_mod,
                           mxu_pm1_matmul as _mxu_mod,
                           xnor_popcount_matmul as _xnor_mod)
from repro.core.binary_conv import im2col_matmul

VALID_MODES = ("vpu_popcount", "mxu_pm1", "xla")
# Every engine the fused conv dispatches to; "vpu_direct" is im2col-free,
# the rest ride the canonical im2col lowering.
CONV_MODES = ("xla", "xla_pm1", "mxu_pm1", "vpu_popcount", "vpu_direct")
_IMPL = {"xla": "xor", "xla_pm1": "pm1", "mxu_pm1": "pm1"}


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def binary_matmul_dot(a: jnp.ndarray, b: jnp.ndarray, k_valid: int,
                      mode: str = "vpu_popcount", **block_kw) -> jnp.ndarray:
    """Binary +-1 dots (M, N) int32; dispatches on execution path."""
    if mode == "vpu_popcount":
        cnt = _xnor_mod.xnor_popcount_matmul(
            a, b, interpret=_interpret(), **block_kw)
        return k_valid - 2 * cnt
    if mode == "mxu_pm1":
        return _mxu_mod.mxu_pm1_matmul(
            a, b, k_valid=k_valid, interpret=_interpret(), **block_kw)
    if mode == "xla":
        return binary_ops.packed_matmul_dot(a, b, k_valid)
    raise ValueError(f"unknown matmul mode {mode!r}; want one of {VALID_MODES}")


def matmul_counts(a: jnp.ndarray, b: jnp.ndarray,
                  word_weights: jnp.ndarray | None = None,
                  mode: str = "vpu_popcount", **block_kw) -> jnp.ndarray:
    if mode == "vpu_popcount":
        return _xnor_mod.xnor_popcount_matmul(
            a, b, word_weights, interpret=_interpret(), **block_kw)
    if mode == "xla":
        return binary_ops.packed_matmul_counts(a, b, word_weights=word_weights)
    raise ValueError(f"counts not supported for mode {mode!r}")


def fused_matmul_bn_binarize(a, b, p: layer_integration.IntegratedParams,
                             word_weights=None, mode: str = "vpu_popcount",
                             **block_kw) -> jnp.ndarray:
    """Integrated matmul+BN+sign+pack: (M, ceil(N/32)) int32."""
    if mode == "vpu_popcount":
        return _fused_mod.fused_matmul_bn_binarize(
            a, b, p.threshold, p.sign_flip, word_weights,
            interpret=_interpret(), **block_kw)
    if mode in _IMPL:
        cnt = binary_ops.packed_matmul_counts(
            a, b, word_weights=word_weights, impl=_IMPL[mode])
        bits = layer_integration.apply_threshold(cnt, p)
        return packing.pack_bits(bits, axis=-1)
    raise ValueError(f"fused path not supported for mode {mode!r}")


def fused_binary_dense(x_packed, w_packed,
                       p: layer_integration.IntegratedParams,
                       mode: str = "vpu_popcount", **block_kw) -> jnp.ndarray:
    """Integrated dense+BN+binarize on flattened packed input, any mode."""
    flat = x_packed.reshape(x_packed.shape[0], -1)
    return fused_matmul_bn_binarize(flat, w_packed, p, mode=mode, **block_kw)


def fused_binary_conv2d(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
                        p: layer_integration.IntegratedParams,
                        kh: int, kw: int, stride: int = 1, pad: int = 0,
                        word_weights=None, mode: str = "vpu_popcount",
                        pool: tuple[int, int, tuple[int, int]] | None = None,
                        **block_kw) -> jnp.ndarray:
    """Fused conv+BN+binarize(+OR-pool) dispatch — one call site for every
    backend (C4+C6).

    ``pool`` is an optional ``(window, stride, (pad_lo, pad_hi))`` OR-pool
    epilogue.  On ``"vpu_direct"`` it fuses into the kernel epilogue (the
    pre-pool conv output never reaches HBM); on the im2col backends it runs
    as a separate packed-domain OR-pool after the conv.
    """
    if mode == "vpu_direct":
        pool_kw = {}
        if pool is not None:
            pool_kw = dict(pool_window=pool[0], pool_stride=pool[1],
                           pool_pad=tuple(pool[2]))
        return _direct_mod.direct_conv_bn_binarize(
            x_packed, w_packed, p.threshold, p.sign_flip,
            kh=kh, kw=kw, stride=stride, pad=pad,
            word_weights=word_weights, interpret=_interpret(),
            **pool_kw, **block_kw)
    if mode == "vpu_popcount":
        flat, (n, oh, ow) = im2col_matmul(x_packed, kh, kw, stride, pad)
        out = fused_matmul_bn_binarize(
            flat, w_packed, p, word_weights=word_weights, mode=mode,
            **block_kw)
        out = out.reshape(n, oh, ow, out.shape[-1])
    elif mode in _IMPL:
        out = binary_conv.binary_conv2d_fused(
            x_packed, w_packed, p, kh, kw, stride, pad,
            word_weights=word_weights, impl=_IMPL[mode])
    else:
        raise ValueError(
            f"unknown conv mode {mode!r}; want one of {CONV_MODES}")
    if pool is not None:
        out = binary_conv.binary_or_maxpool(out, pool[0], pool[1],
                                            pad=tuple(pool[2]))
    return out


def chain_forward(x_packed: jnp.ndarray, stages, stage_arrays,
                  **kw) -> jnp.ndarray:
    """Run a fused conv/pool chain (one region) in a single megakernel
    call with VMEM-resident intermediates (DESIGN.md §9); the region-level
    counterpart of :func:`fused_binary_conv2d`."""
    return _chain_mod.chain_conv(x_packed, tuple(stages),
                                 tuple(stage_arrays),
                                 interpret=_interpret(), **kw)


def bitplane_pack(x: jnp.ndarray, **kw) -> jnp.ndarray:
    """(N,H,W,C) uint8 -> (N,H,W,8*Cw) packed planes via the Pallas kernel."""
    return _bitplane_pack_mod.bitplane_pack(x, interpret=_interpret(), **kw)
