"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binary_ops, bitplanes, layer_integration, packing


def xnor_popcount_matmul(a: jnp.ndarray, b: jnp.ndarray,
                         word_weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """cnt (M, N) int32 — oracle for kernels.xnor_popcount_matmul."""
    return binary_ops.packed_matmul_counts(a, b, word_weights=word_weights)


def fused_matmul_bn_binarize(a, b, threshold, sign_flip,
                             word_weights=None) -> jnp.ndarray:
    """Packed (M, ceil(N/32)) — oracle for kernels.fused_conv_bn_binarize."""
    cnt = binary_ops.packed_matmul_counts(a, b, word_weights=word_weights)
    p = layer_integration.IntegratedParams(threshold, sign_flip)
    bits = layer_integration.apply_threshold(cnt, p)
    return packing.pack_bits(bits, axis=-1)


def bitplane_pack(x: jnp.ndarray) -> jnp.ndarray:
    """(N,H,W,8*Cw) int32 — oracle for kernels.bitplane_pack."""
    p = bitplanes.pack_bitplanes(x)           # (N, H, W, 8, Cw)
    n, h, w, planes, cw = p.shape
    return p.reshape(n, h, w, planes * cw)


def mxu_pm1_matmul(a, b, *, k_valid: int) -> jnp.ndarray:
    """+-1 dots (M, N) int32 — oracle for kernels.mxu_pm1_matmul."""
    return k_valid - 2 * binary_ops.packed_matmul_counts(a, b)
