"""Pallas TPU kernels for PhoneBit's compute hot-spots.

xnor_popcount_matmul     paper-faithful binary matmul (VPU, Eqn 1),
                         whole-tile vectorized xor+popcount reduction
fused_conv_bn_binarize   integrated conv+BN+sign+pack on im2col patches
                         (C4/C6, Eqns 5-9)
direct_conv_bn_binarize  direct (im2col-free) fused conv: VMEM-resident
                         input tiles, in-VMEM KHxKW window walk, integer
                         threshold + bit-pack + OR-pool epilogue
                         (DESIGN.md §5)
bitplane_pack            first-layer bit-plane split+pack (C8, Eqn 2)
mxu_pm1_matmul           beyond-paper MXU path (unpack-to-bf16 in VMEM)
flash_attention          fused attention (score chain never leaves VMEM —
                         the LM/DiT/ViT hot-spot; custom_vjp bwd)
ops                      jit'd wrappers + mode dispatch
ref                      pure-jnp oracles
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
