"""Pallas TPU kernel: xor+popcount matmul on channel-packed words (Eqn 1).

Computes cnt[m, n] = sum_w ww[w] * popcount(a[m, w] ^ b[n, w]) for packed
int32 operands.  This is the paper's binary-convolution inner loop (C1/C3):
the reduction dim W is the packed channel dim — minor-most in memory, so an
HBM->VMEM block copy streams contiguous words (C7, coalesced access), and
the xor/popcount runs on the VPU's 8x128 int32 lanes.

Tiling: grid (M/bm, N/bn, W/bk).  The (bm, bn) int32 accumulator lives in a
VMEM scratch buffer across the sequential k steps (the TPU grid's innermost
dim), which is the Pallas analogue of the paper's private-memory per-thread
accumulation (C6); Pallas double-buffers the a/b block DMAs against compute
(C7, latency hiding).

The inner reduction is *whole-tile vectorized* (DESIGN.md §5.2): one
block-level xor of the broadcast (bm, bn, bk) cube, one population_count,
one weighted reduction over the word axis — every VPU lane busy every
cycle.  The historical per-word ``fori_loop`` + ``dynamic_slice`` form is
kept selectable (``reduction="loop"``) purely so benchmarks/kernels_bench
can measure the win; it is not a serving path.

The optional per-word weight vector ``ww`` implements Eqn 2's bit-plane
powers 2^(n-1) so the first layer reuses this same kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

REDUCTIONS = ("vector", "loop")


# Word-axis width below which the broadcast cube is not worth building:
# with only a handful of packed words the reduction fully unrolls at trace
# time into straight-line whole-tile (bm, bn) ops — no cube, no loop
# state, every step a full VPU op with output channels on the lanes.
_NARROW_K = 16
# Word-axis slab per broadcast cube: bounds the live (bm, bn, _SLAB_K)
# intermediate to ~2 MiB at 128x128 blocks so it fits VMEM alongside the
# double-buffered operand blocks even at the largest default tiles.
_SLAB_K = 32


def tile_counts(a: jnp.ndarray, b: jnp.ndarray,
                ww: jnp.ndarray) -> jnp.ndarray:
    """Whole-tile vectorized weighted xor-popcount: (bm, bk) x (bn, bk) ->
    (bm, bn) int32.  No per-word ``dynamic_slice`` and no ``fori_loop``:
    wide word dims do broadcast xor -> population_count -> weighted
    reduction over the minor (word) axis, in static ``_SLAB_K``-word slabs
    so the (bm, bn, slab) cube stays VMEM-sized; narrow word dims
    (< ``_NARROW_K``) unroll statically into bk fused whole-tile ops,
    which beats both the cube (nothing materialized) and the loop
    (no loop-carried state)."""
    bk = a.shape[1]
    if bk < _NARROW_K:
        bt = jnp.transpose(b)                                  # (bk, bn)
        acc = None
        for w in range(bk):
            c = jax.lax.population_count(
                jax.lax.bitwise_xor(a[:, w:w + 1], bt[w:w + 1, :])) * ww[w]
            acc = c if acc is None else acc + c
        return acc
    acc = None
    for s in range(0, bk, _SLAB_K):
        e = min(s + _SLAB_K, bk)
        x = jax.lax.bitwise_xor(a[:, None, s:e], b[None, :, s:e])
        cnt = jnp.sum(jax.lax.population_count(x) * ww[None, None, s:e],
                      axis=-1, dtype=jnp.int32)               # (bm, bn)
        acc = cnt if acc is None else acc + cnt
    return acc


def tile_counts_loop(a: jnp.ndarray, b: jnp.ndarray,
                     ww: jnp.ndarray) -> jnp.ndarray:
    """Legacy per-word reduction (benchmark baseline only): one packed word
    per ``fori_loop`` step via ``dynamic_slice`` — scalar-ish on the VPU."""
    def body(w, acc):
        aw = jax.lax.dynamic_slice_in_dim(a, w, 1, axis=1)       # (bm, 1)
        bw = jax.lax.dynamic_slice_in_dim(b, w, 1, axis=1)       # (bn, 1)
        www = jax.lax.dynamic_slice_in_dim(ww, w, 1, axis=0)     # (1,)
        x = jax.lax.bitwise_xor(aw, jnp.transpose(bw))           # (bm, bn)
        return acc + jax.lax.population_count(x) * www[0]

    init = jnp.zeros((a.shape[0], b.shape[0]), jnp.int32)
    return jax.lax.fori_loop(0, a.shape[1], body, init)


def _tile_counts(a, b, ww, reduction: str):
    if reduction == "vector":
        return tile_counts(a, b, ww)
    if reduction == "loop":
        return tile_counts_loop(a, b, ww)
    raise ValueError(f"unknown reduction {reduction!r}; want {REDUCTIONS}")


def _kernel(a_ref, b_ref, ww_ref, o_ref, acc_ref, *, n_k_steps: int,
            reduction: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _tile_counts(a_ref[...], b_ref[...], ww_ref[...],
                                 reduction)

    @pl.when(k == n_k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def compiler_params(interpret: bool,
                    semantics=("parallel", "parallel", "arbitrary")) -> dict:
    """kwargs for ``pl.pallas_call`` carrying the TPU dimension semantics
    (version-portable; empty off-TPU / in interpret mode)."""
    if interpret:
        return {}
    params = getattr(pltpu, "CompilerParams",
                     getattr(pltpu, "TPUCompilerParams", None))
    if params is None:
        return {}
    return {"compiler_params": params(dimension_semantics=semantics)}


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "reduction",
                     "interpret"))
def xnor_popcount_matmul(a: jnp.ndarray, b: jnp.ndarray,
                         word_weights: jnp.ndarray | None = None,
                         *, block_m: int = 128, block_n: int = 128,
                         block_k: int = 128, reduction: str = "vector",
                         interpret: bool = False) -> jnp.ndarray:
    """a: (M, W) int32, b: (N, W) int32 -> counts (M, N) int32."""
    m, w = a.shape
    n, wb = b.shape
    assert w == wb, (a.shape, b.shape)
    if word_weights is None:
        word_weights = jnp.ones((w,), jnp.int32)

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, w)
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(w, bk)
    # Pad to block multiples; pad words are 0 in both operands and weight 0,
    # so they contribute nothing.
    a = jnp.pad(a, ((0, gm * bm - m), (0, gk * bk - w)))
    b = jnp.pad(b, ((0, gn * bn - n), (0, gk * bk - w)))
    word_weights = jnp.pad(word_weights.astype(jnp.int32),
                           (0, gk * bk - w))

    out = pl.pallas_call(
        functools.partial(_kernel, n_k_steps=gk, reduction=reduction),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **compiler_params(interpret),
    )(a, b, word_weights)
    return out[:m, :n]
