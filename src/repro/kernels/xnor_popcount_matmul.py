"""Pallas TPU kernel: xor+popcount matmul on channel-packed words (Eqn 1).

Computes cnt[m, n] = sum_w ww[w] * popcount(a[m, w] ^ b[n, w]) for packed
int32 operands.  This is the paper's binary-convolution inner loop (C1/C3):
the reduction dim W is the packed channel dim — minor-most in memory, so an
HBM->VMEM block copy streams contiguous words (C7, coalesced access), and
the xor/popcount runs on the VPU's 8x128 int32 lanes.

Tiling: grid (M/bm, N/bn, W/bk).  The (bm, bn) int32 accumulator lives in a
VMEM scratch buffer across the sequential k steps (the TPU grid's innermost
dim), which is the Pallas analogue of the paper's private-memory per-thread
accumulation (C6); Pallas double-buffers the a/b block DMAs against compute
(C7, latency hiding).

The optional per-word weight vector ``ww`` implements Eqn 2's bit-plane
powers 2^(n-1) so the first layer reuses this same kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, ww_ref, o_ref, acc_ref, *, n_k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]            # (bm, bk) int32
    b = b_ref[...]            # (bn, bk) int32
    ww = ww_ref[...]          # (bk,)    int32
    bk = a.shape[1]

    def body(w, acc):
        aw = jax.lax.dynamic_slice_in_dim(a, w, 1, axis=1)       # (bm, 1)
        bw = jax.lax.dynamic_slice_in_dim(b, w, 1, axis=1)       # (bn, 1)
        www = jax.lax.dynamic_slice_in_dim(ww, w, 1, axis=0)     # (1,)
        x = jax.lax.bitwise_xor(aw, jnp.transpose(bw))           # (bm, bn)
        return acc + jax.lax.population_count(x) * www[0]

    acc_ref[...] += jax.lax.fori_loop(0, bk, body, jnp.zeros_like(acc_ref))

    @pl.when(k == n_k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def xnor_popcount_matmul(a: jnp.ndarray, b: jnp.ndarray,
                         word_weights: jnp.ndarray | None = None,
                         *, block_m: int = 128, block_n: int = 128,
                         block_k: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """a: (M, W) int32, b: (N, W) int32 -> counts (M, N) int32."""
    m, w = a.shape
    n, wb = b.shape
    assert w == wb, (a.shape, b.shape)
    if word_weights is None:
        word_weights = jnp.ones((w,), jnp.int32)

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, w)
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(w, bk)
    # Pad to block multiples; pad words are 0 in both operands and weight 0,
    # so they contribute nothing.
    a = jnp.pad(a, ((0, gm * bm - m), (0, gk * bk - w)))
    b = jnp.pad(b, ((0, gn * bn - n), (0, gk * bk - w)))
    word_weights = jnp.pad(word_weights.astype(jnp.int32),
                           (0, gk * bk - w))

    kwargs = {}
    if not interpret:
        params = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
        if params is not None:
            kwargs["compiler_params"] = params(
                dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_kernel, n_k_steps=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(a, b, word_weights)
    return out[:m, :n]
