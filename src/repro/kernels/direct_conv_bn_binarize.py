"""Pallas TPU kernel: direct (im2col-free) fused binary convolution.

The im2col wrapper around ``fused_conv_bn_binarize`` materializes a
``(N, OH, OW, KH*KW*Cw)`` patch tensor in HBM — KH*KW times the input's
bytes — before the matmul ever runs.  daBNN (1908.05858) and Khan et al.
(1808.00209) both measure that this patch traffic, not the popcounts,
dominates BNN conv time.  This kernel removes it (DESIGN.md §5):

* each grid step streams one packed NHWC input tile **once** into VMEM
  (overlapping halo reads via element-offset / ``pl.Unblocked`` block
  indexing — consecutive spatial tiles re-read only the KH-1 / KW-1 halo),
* the KH x KW window walk happens as *in-VMEM shifted reads*: per tap a
  strided slice of the resident tile, xor'd against that tap's filter
  words with the whole-tile vectorized popcount reduction
  (``xnor_popcount_matmul.tile_counts``),
* the epilogue applies the integer threshold (Eqns 5-9), bit-packs 32
  output channels per int32 word in-register, and optionally OR-pools the
  packed words (max-pool == windowed OR in the packed domain) before the
  single packed store.

Neither the im2col patches nor the unpacked conv output (nor, with the
pool epilogue, the pre-pool conv output) ever reach HBM.

Tile shape knobs — ``block_h`` / ``block_w`` (output rows/cols per step,
*final* rows: pooled rows when the pool epilogue is on), ``block_n``
(batch images per step), ``block_o`` (output filters per step, multiple of
32) — are what ``runtime.autotune`` sweeps per node.  A pool epilogue with
nonzero pool padding forces a single spatial tile (the pad is applied to
the in-VMEM conv words, which must then all be resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import WORD_BITS
from repro.kernels.fused_conv_bn_binarize import threshold_pack
from repro.kernels.xnor_popcount_matmul import compiler_params, tile_counts


def _or_pool_words(words: jnp.ndarray, window: int, stride: int,
                   out_h: int, out_w: int) -> jnp.ndarray:
    """Windowed bitwise OR over packed words: (bn, ch, cw, nw) ->
    (bn, out_h, out_w, nw).  0-words are 32 channels of -1 — the OR
    identity — so padding never distorts the max."""
    out = None
    for i in range(window):
        for j in range(window):
            s = jax.lax.slice(
                words,
                (0, i, j, 0),
                (words.shape[0], i + (out_h - 1) * stride + 1,
                 j + (out_w - 1) * stride + 1, words.shape[3]),
                (1, stride, stride, 1))
            out = s if out is None else (out | s)
    return out


def _kernel(x_ref, w_ref, ww_ref, t_ref, s_ref, o_ref, *,
            kh: int, kw: int, stride: int, cw_words: int,
            conv_h: int, conv_w: int,
            pool: tuple[int, int, tuple[int, int]] | None,
            out_h: int, out_w: int):
    x = x_ref[...]                               # (bn, ih, iw, Cw) resident
    bn = x.shape[0]
    npos = bn * conv_h * conv_w
    acc = jnp.zeros((npos, w_ref.shape[0]), jnp.int32)
    for di in range(kh):                         # KH x KW window walk:
        for dj in range(kw):                     # in-VMEM shifted reads
            tap = di * kw + dj
            patch = jax.lax.slice(               # (bn, conv_h, conv_w, Cw)
                x,
                (0, di, dj, 0),
                (bn, di + (conv_h - 1) * stride + 1,
                 dj + (conv_w - 1) * stride + 1, cw_words),
                (1, stride, stride, 1))
            filt = w_ref[:, tap * cw_words:(tap + 1) * cw_words]
            wwt = ww_ref[tap * cw_words:(tap + 1) * cw_words]
            acc += tile_counts(patch.reshape(npos, cw_words), filt, wwt)

    # Epilogue: integer threshold + in-register 32-channel pack (+ OR-pool).
    words = threshold_pack(acc, t_ref[...][None, :], s_ref[...][None, :])
    words = words.reshape(bn, conv_h, conv_w, -1)
    if pool is not None:
        pwin, pstr, ppad = pool
        if ppad != (0, 0):
            words = jnp.pad(words, ((0, 0), ppad, ppad, (0, 0)))
        words = _or_pool_words(words, pwin, pstr, out_h, out_w)
    o_ref[...] = words


@functools.partial(
    jax.jit,
    static_argnames=("kh", "kw", "stride", "pad", "pool_window",
                     "pool_stride", "pool_pad", "block_h", "block_w",
                     "block_n", "block_o", "interpret"))
def direct_conv_bn_binarize(
        x_packed: jnp.ndarray, w_packed: jnp.ndarray,
        threshold: jnp.ndarray, sign_flip: jnp.ndarray,
        *, kh: int, kw: int, stride: int = 1, pad: int = 0,
        word_weights: jnp.ndarray | None = None,
        pool_window: int | None = None, pool_stride: int | None = None,
        pool_pad: tuple[int, int] = (0, 0),
        block_h: int | None = None, block_w: int | None = None,
        block_n: int = 1, block_o: int | None = None,
        interpret: bool = False) -> jnp.ndarray:
    """Direct fused conv(+pool): packed NHWC in, packed NHWC out.

    x_packed: (N, H, W, Cw) int32 channel-packed input (for the bit-plane
        first layer, Cw is the flattened 8*Cw plane-word dim).
    w_packed: (O, KH*KW*Cw) int32 canonical filter layout
        (``binary_conv.pack_conv_weights`` order).
    threshold/sign_flip: (O,) folded integer epilogue (Eqns 5-9).
    word_weights: (KH*KW*Cw,) per-word weights (Eqn 2 bit-plane powers).
    Returns (N, OH', OW', ceil(O/32)) int32 where OH'/OW' are the conv
    output dims, pooled when ``pool_window`` is given.
    """
    n, h, w_in, cw = x_packed.shape
    o, pw = w_packed.shape
    assert pw == kh * kw * cw, (w_packed.shape, (kh, kw, cw))
    if word_weights is None:
        word_weights = jnp.ones((pw,), jnp.int32)

    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w_in + 2 * pad - kw) // stride + 1
    if pool_window is not None:
        pstr = pool_stride or pool_window
        fh = (oh + sum(pool_pad) - pool_window) // pstr + 1
        fw = (ow + sum(pool_pad) - pool_window) // pstr + 1
        pool = (pool_window, pstr, tuple(pool_pad))
    else:
        pstr, pool = 1, None
        fh, fw = oh, ow

    # Tile shapes (the autotuner's knobs).  Nonzero pool padding must see
    # the whole conv output at once -> single spatial tile.
    bh = min(block_h or 8, fh)
    bw = min(block_w or fw, fw)
    if pool is not None and tuple(pool_pad) != (0, 0):
        bh, bw = fh, fw
    bn = max(1, min(block_n, n))
    nw_valid = -(-o // WORD_BITS)
    bo = min(block_o or 128, nw_valid * WORD_BITS)
    bo = max(WORD_BITS, (bo // WORD_BITS) * WORD_BITS)

    gn, gh, gw, go = (pl.cdiv(n, bn), pl.cdiv(fh, bh), pl.cdiv(fw, bw),
                      pl.cdiv(nw_valid * WORD_BITS, bo))

    single_spatial = (gh == 1 and gw == 1)
    if pool is not None and not single_spatial:
        # Tiled pool epilogue: each tile covers whole pool windows.
        conv_h, conv_w = (bh - 1) * pstr + pool_window, \
                         (bw - 1) * pstr + pool_window
        rstep, cstep = bh * pstr * stride, bw * pstr * stride
    elif pool is not None:
        conv_h, conv_w = oh, ow
        rstep = cstep = 0
    else:
        conv_h, conv_w = bh, bw
        rstep, cstep = bh * stride, bw * stride
    ih = (conv_h - 1) * stride + kh
    iw = (conv_w - 1) * stride + kw

    # Spatial pad: conv padding (0-words == -1 channels, DESIGN.md §3.2)
    # plus bottom/right slack so every halo read stays in bounds.
    need_h = (gh - 1) * rstep + ih
    need_w = (gw - 1) * cstep + iw
    x_packed = jnp.pad(x_packed, (
        (0, gn * bn - n),
        (pad, max(pad, need_h - h - pad)),
        (pad, max(pad, need_w - w_in - pad)),
        (0, 0)))

    # Output-channel pad: threshold=-1 / sign=0 -> pad bits are 0, matching
    # ``packing.pack_bits`` semantics.
    o_pad = go * bo
    w_packed = jnp.pad(w_packed, ((0, o_pad - o), (0, 0)))
    threshold = jnp.pad(threshold.astype(jnp.int32), (0, o_pad - o),
                        constant_values=-1)
    sign_flip = jnp.pad(sign_flip.astype(jnp.int32), (0, o_pad - o))
    word_weights = word_weights.astype(jnp.int32)

    nwb = bo // WORD_BITS
    out = pl.pallas_call(
        functools.partial(
            _kernel, kh=kh, kw=kw, stride=stride, cw_words=cw,
            conv_h=conv_h, conv_w=conv_w, pool=pool, out_h=bh, out_w=bw),
        grid=(gn, gh, gw, go),
        in_specs=[
            # Element-offset (Unblocked) spec: overlapping halo reads.
            pl.BlockSpec(
                (bn, ih, iw, cw),
                lambda ni, hi, wi, oi: (ni * bn, hi * rstep, wi * cstep, 0),
                indexing_mode=pl.Unblocked()),
            pl.BlockSpec((bo, pw), lambda ni, hi, wi, oi: (oi, 0)),
            pl.BlockSpec((pw,), lambda ni, hi, wi, oi: (0,)),
            pl.BlockSpec((bo,), lambda ni, hi, wi, oi: (oi,)),
            pl.BlockSpec((bo,), lambda ni, hi, wi, oi: (oi,)),
        ],
        out_specs=pl.BlockSpec(
            (bn, bh, bw, nwb), lambda ni, hi, wi, oi: (ni, hi, wi, oi)),
        out_shape=jax.ShapeDtypeStruct(
            (gn * bn, gh * bh, gw * bw, go * nwb), jnp.int32),
        interpret=interpret,
        **compiler_params(
            interpret, ("parallel", "parallel", "parallel", "parallel")),
    )(x_packed, w_packed, word_weights, threshold, sign_flip)
    return out[:n, :fh, :fw, :nw_valid]
