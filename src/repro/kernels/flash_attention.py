"""Pallas TPU kernel: fused flash attention (forward).

Beyond-paper kernel for the framework's LM/DiT/ViT hot spot.  The pure-JAX
chunked attention (models.layers.chunked_attention) is memory-bounded but
its score chain (scores -> mask -> max -> exp -> sum -> PV) still rounds
through HBM between XLA fusions; measured in the dry-run it accounts for
the largest share of LM training's HBM bytes.  This kernel keeps one
(block_q × block_k) f32 score tile + the running (m, l, acc) statistics in
VMEM for an entire KV sweep — the score chain NEVER touches HBM, exactly
the paper's layer-integration philosophy (C4: no intermediate results in
memory) applied to attention.

Grid: (batch·kv_heads·q_groups, S_q/block_q); the kernel loops KV blocks
with lax.fori_loop over dynamic slices of the (S_kv, hd) VMEM-resident
K/V panels.  Causal masking skips fully-masked KV blocks via the loop
upper bound (triangular schedule inside the kernel).

Backward: jax.custom_vjp recomputes through the pure-jnp oracle — exact
gradients, no flash-bwd kernel yet (the TPU deployment would add the
standard dKV/dQ kernels; documented in DESIGN.md).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
            q_start_base: int, scale: float):
    """One (q-block × full-KV) flash pass.

    q_ref: (block_q, hd); k_ref/v_ref: (S_kv, hd); o_ref: (block_q, hd).
    """
    qi = pl.program_id(1)
    block_q, hd = q_ref.shape
    s_kv = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, hd), jnp.float32)

    q_lo = qi * block_q  # offset of this q block within the q panel

    def body(ki, carry):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[...], ki * block_k,
                                             block_k, axis=0)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[...], ki * block_k,
                                             block_k, axis=0)
        s = jax.lax.dot_general(
            q, k_blk.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bk)
        if causal:
            q_pos = (q_start_base + q_lo
                     + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0))
            kv_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p.astype(v_blk.dtype), v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc * corr[:, None] + pv

    if causal:
        # triangular: this q block attends KV positions
        # [0, q_start_base + q_lo + block_q)
        n_k = (q_start_base + q_lo + block_q + block_k - 1) // block_k
        n_k_max = s_kv // block_k
        # dynamic bound (q_lo is static per grid cell only through
        # program_id) -> fori_loop with traced upper bound
        n_k = jnp.minimum(n_k, n_k_max)
    else:
        n_k = s_kv // block_k
    m, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _flash_fwd(q, k, v, *, causal: bool, block_q: int, block_k: int,
               interpret: bool):
    """q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd) -> (B, Sq, H, hd).

    GQA: H = KV·G; q heads are regrouped so each kernel instance sees its
    single KV head.  Causal assumes Sq == Skv (training/prefill).
    """
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    scale = 1.0 / math.sqrt(hd)

    # (B, KV, G, Sq, hd) -> rows = B·KV·G panels
    qr = jnp.transpose(q.reshape(b, sq, kvh, g, hd),
                       (0, 2, 3, 1, 4)).reshape(b * kvh * g, sq, hd)
    kr = jnp.repeat(
        jnp.transpose(k, (0, 2, 1, 3)).reshape(b * kvh, 1, skv, hd),
        g, axis=1).reshape(b * kvh * g, skv, hd)
    vr = jnp.repeat(
        jnp.transpose(v, (0, 2, 1, 3)).reshape(b * kvh, 1, skv, hd),
        g, axis=1).reshape(b * kvh * g, skv, hd)

    kernel = functools.partial(_kernel, block_k=block_k, causal=causal,
                               q_start_base=0, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(qr.shape[0], sq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda r, i: (r, i, 0)),
            pl.BlockSpec((None, skv, hd), lambda r, i: (r, 0, 0)),
            pl.BlockSpec((None, skv, hd), lambda r, i: (r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda r, i: (r, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qr.shape, q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(b, kvh, g, sq, hd)
    return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, h, hd)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """Fused flash attention (fwd Pallas kernel, recompute-jnp bwd)."""
    return _flash_fwd(q, k, v, causal=causal, block_q=block_q,
                      block_k=block_k, interpret=interpret)


def _fwd(q, k, v, causal, block_q, block_k, interpret):
    out = _flash_fwd(q, k, v, causal=causal, block_q=block_q,
                     block_k=block_k, interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, block_q, block_k, interpret, res, g):
    from repro.models import layers
    q, k, v = res

    def ref(q, k, v):
        return layers.chunked_attention(
            q, k, v, causal=causal, q_chunk=block_q, kv_chunk=block_k)

    _, vjp = jax.vjp(ref, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
