"""Pallas TPU megakernel: a multi-layer binary-conv chain in one call.

PhoneBit's layer-integration thesis (§V-C) taken one level up: PR 2's
direct kernel fused conv+BN+binarize+pool *within* a layer, but every
layer boundary still round-trips a packed activation through HBM and pays
a kernel dispatch.  On a 32x-compressed tensor that boundary traffic and
dispatch overhead rival the compute (daBNN, 1908.05858, measures the same
shift on ARM once the binary ops are cheap).  This kernel executes a whole
*region* — a static chain of conv / pool stages — in a single
``pallas_call``:

* the chain **entry** streams one packed NHWC input tile into VMEM via the
  same overlapping-halo ``pl.Unblocked`` element-offset reads as the
  direct kernel;
* every **interior** stage output is stored to a flat VMEM scratch
  **arena** at the byte offset the memory planner assigned
  (:func:`repro.runtime.memory.vmem_plan` — lifetime-aware first-fit, so
  stage i and stage i+2 ping-pong into shared space), and the next stage
  reads its input back from that offset — HBM is touched only at the
  chain's entry and exit;
* conv stages walk KH x KW as in-VMEM shifted strided reads feeding the
  whole-tile vectorized xor+popcount reduction, then apply the integer
  threshold + in-register 32-channel bit-pack; pool stages are windowed
  bitwise ORs over resident words.

Tiling couples the stages through **halo growth**: to emit a
``(block_h, block_w)`` tile of the *final* stage, stage k must produce a
tile grown backwards through every later kernel window and stride, so the
entry tile (and the per-stage recompute overlap between adjacent grid
steps) grows with chain depth — which is why per-chain tile shapes are a
new autotuning search space (DESIGN.md §9.3).  The default tile is the
whole spatial map (no recompute; region formation already guaranteed the
arena fits the VMEM budget).

Correctness at tile and image borders: every position is computed in the
final stage's coordinate frame and mapped backwards affinely
(``origin = hi * step - offset``), so interior tiles read real neighbor
data while border tiles run past a stage's valid extent.  Out-of-range
positions of each interior stage are masked to zero words before the
arena store — the zero word is 32 channels of -1, which is simultaneously
this codebase's conv-padding convention and the OR-pool identity
(DESIGN.md §3.2), so the masked store *is* the next stage's padding.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import WORD_BITS, num_words
from repro.kernels.fused_conv_bn_binarize import threshold_pack
from repro.kernels.xnor_popcount_matmul import compiler_params, tile_counts


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One static chain stage.  ``kind`` is ``"conv"`` (fused binary conv +
    integer threshold + pack; ``kernel``/``stride``/``pad_*`` are the conv
    geometry, ``channels`` the valid output channels) or ``"pool"``
    (windowed OR over packed words; ``kernel`` is the pool window).
    Hashable so a chain spec can be a jit-static argument."""
    kind: str
    kernel: int
    stride: int
    pad_lo: int = 0
    pad_hi: int = 0
    channels: int = 0
    first: bool = False

    def out_size(self, size: int) -> int:
        return (size + self.pad_lo + self.pad_hi - self.kernel) \
            // self.stride + 1


@dataclasses.dataclass(frozen=True)
class _Geometry:
    """Host-side tile geometry for one (chain, tile-shape) pairing."""
    out_tile: tuple[tuple[int, int], ...]   # per-stage output tile (th, tw)
    out_step: tuple[tuple[int, int], ...]   # tile-origin step per grid inc
    out_off: tuple[tuple[int, int], ...]    # tile-origin static offset
    valid_hw: tuple[tuple[int, int], ...]   # per-stage valid output extent
    entry_tile: tuple[int, int]
    entry_step: tuple[int, int]
    entry_off: tuple[int, int]              # == top/left pre-pad of entry
    final_hw: tuple[int, int]


def chain_geometry(stages: tuple[StageSpec, ...], h: int, w: int,
                   block_h: int | None, block_w: int | None) -> _Geometry:
    """Backward halo propagation: from the final (block_h, block_w) output
    tile, grow each stage's required tile through its window and stride.
    Tile origins are affine in the grid index: ``origin = gi*step - off``.
    """
    hs, ws = [h], [w]
    for st in stages:
        hs.append(st.out_size(hs[-1]))
        ws.append(st.out_size(ws[-1]))
    fh, fw = hs[-1], ws[-1]
    th, tw = min(block_h or fh, fh), min(block_w or fw, fw)

    out_tile, out_step, out_off, valid = [], [], [], []
    mh, oh, mw, ow = th, 0, tw, 0
    for k in reversed(range(len(stages))):
        st = stages[k]
        out_tile.append((th, tw))
        out_step.append((mh, mw))
        out_off.append((oh, ow))
        valid.append((hs[k + 1], ws[k + 1]))
        th = (th - 1) * st.stride + st.kernel
        tw = (tw - 1) * st.stride + st.kernel
        mh, oh = mh * st.stride, oh * st.stride + st.pad_lo
        mw, ow = mw * st.stride, ow * st.stride + st.pad_lo
    return _Geometry(
        out_tile=tuple(reversed(out_tile)),
        out_step=tuple(reversed(out_step)),
        out_off=tuple(reversed(out_off)),
        valid_hw=tuple(reversed(valid)),
        entry_tile=(th, tw), entry_step=(mh, mw), entry_off=(oh, ow),
        final_hw=(fh, fw))


def chain_word_counts(stages: tuple[StageSpec, ...], cw_in: int
                      ) -> list[int]:
    """Packed word count entering each stage (index 0 = chain input) and
    leaving the last (index len(stages))."""
    cws = [cw_in]
    for st in stages:
        cws.append(num_words(st.channels) if st.kind == "conv" else cws[-1])
    return cws


def _conv_stage(x, st: StageSpec, w, ww, t, s, *, out_h: int, out_w: int,
                cw: int):
    """(bn, ih, iw, cw) resident tile -> (bn, out_h, out_w, nw) words:
    KHxKW in-VMEM shifted reads + vectorized popcount + threshold/pack."""
    bn = x.shape[0]
    npos = bn * out_h * out_w
    acc = jnp.zeros((npos, w.shape[0]), jnp.int32)
    k = st.kernel
    for di in range(k):
        for dj in range(k):
            tap = di * k + dj
            patch = jax.lax.slice(
                x, (0, di, dj, 0),
                (bn, di + (out_h - 1) * st.stride + 1,
                 dj + (out_w - 1) * st.stride + 1, cw),
                (1, st.stride, st.stride, 1))
            acc += tile_counts(patch.reshape(npos, cw),
                               w[:, tap * cw:(tap + 1) * cw],
                               ww[tap * cw:(tap + 1) * cw])
    words = threshold_pack(acc, t[None, :], s[None, :])
    return words.reshape(bn, out_h, out_w, -1)


def _pool_stage(x, st: StageSpec, *, out_h: int, out_w: int):
    """Windowed bitwise OR over packed words (max-pool in the packed
    domain); zero words are the OR identity, so masked pad positions in
    the resident tile never distort the max."""
    out = None
    for i in range(st.kernel):
        for j in range(st.kernel):
            s = jax.lax.slice(
                x, (0, i, j, 0),
                (x.shape[0], i + (out_h - 1) * st.stride + 1,
                 j + (out_w - 1) * st.stride + 1, x.shape[3]),
                (1, st.stride, st.stride, 1))
            out = s if out is None else (out | s)
    return out


def _mask_invalid(y, hi, wi, step, off, valid):
    """Zero positions outside the stage's valid output extent.  The tile
    origin is ``gi*step - off`` (dynamic in the grid index), so border
    tiles cover pad-region coordinates — zeroing them reproduces the
    packed-domain padding convention for the next stage."""
    row0 = hi * step[0] - off[0]
    col0 = wi * step[1] - off[1]
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, y.shape, 2)
    ok = ((rows >= 0) & (rows < valid[0]) &
          (cols >= 0) & (cols < valid[1]))
    return jnp.where(ok, y, 0)


def _kernel(*refs, stages: tuple[StageSpec, ...], geo: _Geometry,
            cws: tuple[int, ...], arena_offsets: tuple[int, ...]):
    """One grid step: walk the whole chain for one final-output tile.
    ``refs`` = entry tile, 4 refs per conv stage (w, ww, t, s), output
    tile, then the flat int32 VMEM arena scratch."""
    hi, wi = pl.program_id(1), pl.program_id(2)
    x_ref, refs = refs[0], refs[1:]
    arena_ref = refs[-1]
    o_ref = refs[-2]
    param_refs = refs[:-2]

    x = x_ref[...]
    pi = 0
    last = len(stages) - 1
    for k, st in enumerate(stages):
        th, tw = geo.out_tile[k]
        if st.kind == "conv":
            w, ww, t, s = (param_refs[pi][...], param_refs[pi + 1][...],
                           param_refs[pi + 2][...], param_refs[pi + 3][...])
            pi += 4
            y = _conv_stage(x, st, w, ww, t, s, out_h=th, out_w=tw,
                            cw=cws[k])
        else:
            y = _pool_stage(x, st, out_h=th, out_w=tw)
        if k == last:
            o_ref[...] = y
        else:
            # Interior boundary: mask pad-region positions to zero words,
            # store at the planner's arena offset, and hand the next stage
            # its input straight back out of VMEM — HBM never sees it.
            y = _mask_invalid(y, hi, wi, geo.out_step[k], geo.out_off[k],
                              geo.valid_hw[k])
            bn = y.shape[0]
            size = bn * th * tw * cws[k + 1]
            off = arena_offsets[k]
            arena_ref[off:off + size] = y.reshape(-1)
            x = arena_ref[off:off + size].reshape(bn, th, tw, cws[k + 1])


@functools.partial(
    jax.jit,
    static_argnames=("stages", "block_h", "block_w", "block_n",
                     "arena_offsets", "arena_words", "interpret"))
def chain_conv(x_packed: jnp.ndarray, stages: tuple[StageSpec, ...],
               stage_arrays: tuple[jnp.ndarray, ...],
               *, block_h: int | None = None, block_w: int | None = None,
               block_n: int = 1,
               arena_offsets: tuple[int, ...] | None = None,
               arena_words: int | None = None,
               interpret: bool = False) -> jnp.ndarray:
    """Run a static conv/pool chain in one Pallas call.

    x_packed: (N, H, W, Cw) int32 packed words (bit-plane words for a
        first-layer entry).
    stages: static chain spec; ``stage_arrays`` carries, per conv stage in
        order, ``(w_packed (O, K*K*Cw), word_weights (K*K*Cw,) | None,
        threshold (O,), sign_flip (O,))`` — pool stages carry nothing.
    arena_offsets / arena_words: int32-element offsets per interior stage
        output and total scratch extent, normally from the memory
        planner's :func:`~repro.runtime.memory.vmem_plan`; defaulted to a
        dense no-reuse layout when omitted (kernel-level tests).
    Returns (N, FH, FW, ceil(O_last/32)) int32 (pool chains keep Cw).
    """
    n, h, w_in, cw0 = x_packed.shape
    geo = chain_geometry(stages, h, w_in, block_h, block_w)
    fh, fw = geo.final_hw
    bh, bw = geo.out_tile[-1]
    bn = max(1, min(block_n, n))
    cws = tuple(chain_word_counts(stages, cw0))

    if arena_offsets is None:
        offs, total = [], 0
        for k in range(len(stages) - 1):
            offs.append(total)
            th, tw = geo.out_tile[k]
            total += bn * th * tw * cws[k + 1]
        arena_offsets, arena_words = tuple(offs), total

    # Pad + widen per-stage operands: output channels to word multiples
    # with threshold=-1 / sign=0 so pad bits are 0 (pack_bits semantics).
    ops: list[jnp.ndarray] = []
    ai = 0
    for st in stages:
        if st.kind != "conv":
            continue
        w_p, ww, t, s = stage_arrays[ai:ai + 4]
        ai += 4
        o, pw = w_p.shape
        o_pad = num_words(st.channels) * WORD_BITS
        if ww is None:
            ww = jnp.ones((pw,), jnp.int32)
        ops += [jnp.pad(w_p, ((0, o_pad - o), (0, 0))),
                ww.astype(jnp.int32),
                jnp.pad(t.astype(jnp.int32), (0, o_pad - o),
                        constant_values=-1),
                jnp.pad(s.astype(jnp.int32), (0, o_pad - o))]

    gn, gh, gw = pl.cdiv(n, bn), pl.cdiv(fh, bh), pl.cdiv(fw, bw)
    ih, iw = geo.entry_tile
    rstep, cstep = geo.entry_step
    top, left = geo.entry_off
    # Entry pre-pad: the chain's cumulative left/top pad plus bottom/right
    # slack so every grown halo read stays in bounds (0-words == -1
    # channels == the packed-domain conv pad).
    need_h = (gh - 1) * rstep + ih
    need_w = (gw - 1) * cstep + iw
    x_packed = jnp.pad(x_packed, (
        (0, gn * bn - n),
        (top, max(0, need_h - h - top)),
        (left, max(0, need_w - w_in - left)),
        (0, 0)))

    nw_out = cws[-1]
    in_specs = [pl.BlockSpec(
        (bn, ih, iw, cw0),
        lambda ni, hi, wi: (ni * bn, hi * rstep, wi * cstep, 0),
        indexing_mode=pl.Unblocked())]
    for arr in ops:
        shape = arr.shape
        in_specs.append(pl.BlockSpec(
            shape, lambda ni, hi, wi, _nd=len(shape): (0,) * _nd))

    out = pl.pallas_call(
        functools.partial(_kernel, stages=stages, geo=geo, cws=cws,
                          arena_offsets=arena_offsets),
        grid=(gn, gh, gw),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, bh, bw, nw_out),
                               lambda ni, hi, wi: (ni, hi, wi, 0)),
        out_shape=jax.ShapeDtypeStruct((gn * bn, gh * bh, gw * bw, nw_out),
                                       jnp.int32),
        scratch_shapes=[pltpu.VMEM((max(arena_words, 1),), jnp.int32)],
        interpret=interpret,
        **compiler_params(interpret, ("parallel",) * 3),
    )(x_packed, *ops)
    return out[:n, :fh, :fw, :]
