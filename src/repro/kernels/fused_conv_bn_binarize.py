"""Pallas TPU kernel: integrated binary-conv + BN + binarize + bit-pack (C4+C6).

The flagship PhoneBit kernel.  One output tile:

  1. accumulates xor-popcounts over the packed reduction dim (Eqn 1),
  2. applies the offline-folded integer threshold  bit = (cnt <= t) xor s
     (Eqns 5-9, integer-strengthened form, branch-free on the VPU),
  3. bit-packs 32 output channels per int32 word *in-register* and performs a
     single packed store — the TPU analogue of Fig 4's "one thread computes
     8 filters, binarizes 8 results and packs into one byte".

No float op and no unpacked intermediate ever reaches VMEM/HBM, which is
exactly the paper's layer-integration claim (§V-B): intermediate results
between conv/BN/binarization layers are never materialized in memory.

Operands are im2col patches (matmul-shaped); the conv wrapper lives in
``repro.kernels.ops.fused_binary_conv2d``.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import WORD_BITS


def _pack_weights3d() -> jnp.ndarray:
    """(1, 1, 32) int32 modular weights: bit i -> 1<<i, computed in-kernel.

    Built from a broadcasted iota + shift so the kernel body has no captured
    constants (Pallas requires all operands to be explicit inputs).  Bit 31
    wraps to INT32_MIN — the correct two's-complement pattern for modular
    int32 accumulation.
    """
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, WORD_BITS), 2)
    return jax.lax.shift_left(jnp.int32(1), shifts)


def _kernel(a_ref, b_ref, ww_ref, t_ref, s_ref, o_ref, acc_ref,
            *, n_k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]            # (bm, bk) int32 packed patches
    b = b_ref[...]            # (bn, bk) int32 packed filters
    ww = ww_ref[...]          # (bk,)    int32 word weights (Eqn 2 powers)

    def body(w, acc):
        aw = jax.lax.dynamic_slice_in_dim(a, w, 1, axis=1)
        bw = jax.lax.dynamic_slice_in_dim(b, w, 1, axis=1)
        www = jax.lax.dynamic_slice_in_dim(ww, w, 1, axis=0)
        x = jax.lax.bitwise_xor(aw, jnp.transpose(bw))
        return acc + jax.lax.population_count(x) * www[0]

    acc_ref[...] += jax.lax.fori_loop(0, a.shape[1], body,
                                      jnp.zeros_like(acc_ref))

    @pl.when(k == n_k_steps - 1)
    def _epilogue():
        cnt = acc_ref[...]                                # (bm, bn)
        t = t_ref[...]                                    # (bn,)
        s = s_ref[...]                                    # (bn,) int32 0/1
        bits = (jnp.less_equal(cnt, t[None, :]).astype(jnp.int32)
                ^ s[None, :])                             # Eqn 9, int form
        bm, bn = bits.shape
        words = bits.reshape(bm, bn // WORD_BITS, WORD_BITS)
        o_ref[...] = jnp.sum(words * _pack_weights3d(), axis=-1,
                             dtype=jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def fused_matmul_bn_binarize(a: jnp.ndarray, b: jnp.ndarray,
                             threshold: jnp.ndarray, sign_flip: jnp.ndarray,
                             word_weights: jnp.ndarray | None = None,
                             *, block_m: int = 128, block_n: int = 256,
                             block_k: int = 128,
                             interpret: bool = False) -> jnp.ndarray:
    """a: (M, W) patches, b: (N, W) filters -> packed bits (M, ceil(N/32)).

    threshold: (N,) int32; sign_flip: (N,) bool.  Output channel padding
    (N -> block multiple) uses threshold=-1 / sign=0 so pad bits are 0,
    matching ``packing.pack_bits`` semantics.
    """
    m, w = a.shape
    n, wb = b.shape
    assert w == wb
    if word_weights is None:
        word_weights = jnp.ones((w,), jnp.int32)

    bm, bk = min(block_m, m), min(block_k, w)
    bn = min(block_n, max(WORD_BITS, n))
    bn = max(WORD_BITS, (bn // WORD_BITS) * WORD_BITS)
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(w, bk)

    a = jnp.pad(a, ((0, gm * bm - m), (0, gk * bk - w)))
    b = jnp.pad(b, ((0, gn * bn - n), (0, gk * bk - w)))
    word_weights = jnp.pad(word_weights.astype(jnp.int32), (0, gk * bk - w))
    threshold = jnp.pad(threshold.astype(jnp.int32), (0, gn * bn - n),
                        constant_values=-1)
    sign_flip = jnp.pad(sign_flip.astype(jnp.int32), (0, gn * bn - n))

    kwargs = {}
    if not interpret:
        params = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
        if params is not None:
            kwargs["compiler_params"] = params(
                dimension_semantics=("parallel", "parallel", "arbitrary"))

    nw = bn // WORD_BITS
    out = pl.pallas_call(
        functools.partial(_kernel, n_k_steps=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, nw), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * nw), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **kwargs,
    )(a, b, word_weights, threshold, sign_flip)
    return out[:m, : -(-n // WORD_BITS)]
