"""Pallas TPU kernel: integrated binary-conv + BN + binarize + bit-pack (C4+C6).

The im2col-shaped fused PhoneBit kernel.  One output tile:

  1. accumulates xor-popcounts over the packed reduction dim (Eqn 1) with
     the whole-tile vectorized reduction of ``xnor_popcount_matmul``
     (block xor -> population_count -> weighted reduction; the legacy
     per-word ``fori_loop`` is selectable as ``reduction="loop"`` for
     benchmarking only),
  2. applies the offline-folded integer threshold  bit = (cnt <= t) xor s
     (Eqns 5-9, integer-strengthened form, branch-free on the VPU),
  3. bit-packs 32 output channels per int32 word *in-register* and performs a
     single packed store — the TPU analogue of Fig 4's "one thread computes
     8 filters, binarizes 8 results and packs into one byte".

No float op and no unpacked intermediate ever reaches VMEM/HBM, which is
exactly the paper's layer-integration claim (§V-B): intermediate results
between conv/BN/binarization layers are never materialized in memory.

Operands are im2col patches (matmul-shaped); the conv wrapper lives in
``repro.kernels.ops.fused_binary_conv2d``.  For the im2col-*free* direct
convolution form of the same contract see
``repro.kernels.direct_conv_bn_binarize`` (DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import WORD_BITS
from repro.kernels.xnor_popcount_matmul import _tile_counts, compiler_params


def pack_words(bits: jnp.ndarray) -> jnp.ndarray:
    """In-register bit-pack of the minor axis: (..., n*32) {0,1} int32 ->
    (..., n) int32 words, LSB-first.

    The weights are built from a broadcasted iota + shift so kernel bodies
    have no captured constants (Pallas requires all operands explicit).
    Bit 31 wraps to INT32_MIN — the correct two's-complement pattern for
    modular int32 accumulation.
    """
    shape = bits.shape[:-1] + (bits.shape[-1] // WORD_BITS, WORD_BITS)
    words = bits.reshape(shape)
    shifts = jax.lax.broadcasted_iota(jnp.int32, words.shape, words.ndim - 1)
    return jnp.sum(words * jax.lax.shift_left(jnp.int32(1), shifts),
                   axis=-1, dtype=jnp.int32)


def threshold_pack(cnt: jnp.ndarray, t: jnp.ndarray,
                   s: jnp.ndarray) -> jnp.ndarray:
    """Fused epilogue on a count tile: integer threshold (Eqn 9's
    ``(cnt <= t) xor s`` form) + in-register 32-channel bit-pack.
    cnt: (..., bn); t, s: (bn,) int32 -> (..., bn//32) int32 words."""
    bits = (jnp.less_equal(cnt, t).astype(jnp.int32) ^ s)
    return pack_words(bits)


def _kernel(a_ref, b_ref, ww_ref, t_ref, s_ref, o_ref, acc_ref,
            *, n_k_steps: int, reduction: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _tile_counts(a_ref[...], b_ref[...], ww_ref[...],
                                 reduction)

    @pl.when(k == n_k_steps - 1)
    def _epilogue():
        o_ref[...] = threshold_pack(acc_ref[...], t_ref[...][None, :],
                                    s_ref[...][None, :])


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "reduction",
                     "interpret"))
def fused_matmul_bn_binarize(a: jnp.ndarray, b: jnp.ndarray,
                             threshold: jnp.ndarray, sign_flip: jnp.ndarray,
                             word_weights: jnp.ndarray | None = None,
                             *, block_m: int = 128, block_n: int = 256,
                             block_k: int = 128, reduction: str = "vector",
                             interpret: bool = False) -> jnp.ndarray:
    """a: (M, W) patches, b: (N, W) filters -> packed bits (M, ceil(N/32)).

    threshold: (N,) int32; sign_flip: (N,) bool.  Output channel padding
    (N -> block multiple) uses threshold=-1 / sign=0 so pad bits are 0,
    matching ``packing.pack_bits`` semantics.
    """
    m, w = a.shape
    n, wb = b.shape
    assert w == wb
    if word_weights is None:
        word_weights = jnp.ones((w,), jnp.int32)

    bm, bk = min(block_m, m), min(block_k, w)
    bn = min(block_n, max(WORD_BITS, n))
    bn = max(WORD_BITS, (bn // WORD_BITS) * WORD_BITS)
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(w, bk)

    a = jnp.pad(a, ((0, gm * bm - m), (0, gk * bk - w)))
    b = jnp.pad(b, ((0, gn * bn - n), (0, gk * bk - w)))
    word_weights = jnp.pad(word_weights.astype(jnp.int32), (0, gk * bk - w))
    threshold = jnp.pad(threshold.astype(jnp.int32), (0, gn * bn - n),
                        constant_values=-1)
    sign_flip = jnp.pad(sign_flip.astype(jnp.int32), (0, gn * bn - n))

    nw = bn // WORD_BITS
    out = pl.pallas_call(
        functools.partial(_kernel, n_k_steps=gk, reduction=reduction),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, nw), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * nw), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
        **compiler_params(interpret),
    )(a, b, word_weights, threshold, sign_flip)
    return out[:m, : -(-n // WORD_BITS)]
