"""Pallas TPU kernel: first-layer bit-plane split + channel packing (C8).

(N, H, W, C) 8-bit input -> (N, H, W, 8*Cw) int32: 8 bit-planes (Eqn 2),
each packed along the channel dim (C2).  Pure data movement + bit twiddling;
one pass over the image, packed words written once.  The output word layout
is plane-major per pixel — plane n occupies words [n*Cw, (n+1)*Cw) — matching
``bitplanes.plane_word_weights`` and the first-layer filter packing in
``converter.convert``.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitplanes import NUM_PLANES
from repro.core.packing import WORD_BITS, num_words

def _pack_w(width: int) -> jnp.ndarray:
    """(1, 1, 1, width) int32 weights bit i -> 1<<i, built in-kernel.

    Iota + shift keeps the kernel free of captured constants; bit 31 wraps
    to INT32_MIN (correct modular int32 packing).
    """
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, width), 3)
    return jax.lax.shift_left(jnp.int32(1), shifts)


def _kernel(x_ref, o_ref, *, channels: int):
    x = x_ref[...].astype(jnp.int32)          # (1, bh, bw, C)
    cw = num_words(channels)
    # One iota+shift for the whole kernel; per-word slices view into it
    # (this used to be re-emitted 8*Cw times per block).
    pack_w = _pack_w(WORD_BITS)
    words = []
    for n in range(NUM_PLANES):
        bits = (x >> n) & 1                   # (1, bh, bw, C)
        for wi in range(cw):
            lo = wi * WORD_BITS
            hi = min(lo + WORD_BITS, channels)
            chunk = bits[..., lo:hi]
            words.append(jnp.sum(chunk * pack_w[..., :hi - lo], axis=-1,
                                 dtype=jnp.int32))
    o_ref[...] = jnp.stack(words, axis=-1)    # (1, bh, bw, 8*Cw)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def bitplane_pack(x: jnp.ndarray, *, block_h: int = 32,
                  interpret: bool = False) -> jnp.ndarray:
    """x: (N, H, W, C) uint8/int -> (N, H, W, 8*Cw) int32 packed planes."""
    n, h, w, c = x.shape
    x = x.astype(jnp.int32)  # widen on entry; kernel works on int32 lanes
    bh = min(block_h, h)
    gh = pl.cdiv(h, bh)
    pad_h = gh * bh - h
    if pad_h:
        x = jnp.pad(x, ((0, 0), (0, pad_h), (0, 0), (0, 0)))
    cw = num_words(c)
    out = pl.pallas_call(
        functools.partial(_kernel, channels=c),
        grid=(n, gh),
        in_specs=[pl.BlockSpec((1, bh, w, c), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, bh, w, NUM_PLANES * cw),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, gh * bh, w, NUM_PLANES * cw),
                                       jnp.int32),
        interpret=interpret,
    )(x)
    return out[:, :h]
