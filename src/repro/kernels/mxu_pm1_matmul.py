"""Pallas TPU kernel: packed-binary matmul on the MXU (beyond-paper path).

The paper's algorithm (xor+popcount) is a VPU workload.  On TPU the MXU's
bf16 throughput is ~50x the VPU's int32 op rate, so past a crossover in the
reduction dim it is faster to *unpack* packed words to +-1 bf16 inside VMEM
(32x expansion happens HBM->VMEM once per tile, never touching HBM) and feed
the systolic array:  dot_pm1(A, B) == K - 2*cnt  directly.

This keeps PhoneBit's storage/bandwidth win (HBM traffic stays packed, 32x
compressed — the paper's C2 layout) while swapping the compute engine for
the one TPUs are built around.  See EXPERIMENTS.md §Perf for the comparison
against the paper-faithful VPU kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import WORD_BITS


def _unpack_pm1(words: jnp.ndarray) -> jnp.ndarray:
    """(r, wk) int32 -> (r, wk*32) bf16 in {-1, +1} (LSB-first)."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.int32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & 1
    pm1 = (2 * bits - 1).astype(jnp.bfloat16)
    return pm1.reshape(words.shape[0], words.shape[1] * WORD_BITS)


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    av = _unpack_pm1(a_ref[...])              # (bm, bk*32) bf16
    bv = _unpack_pm1(b_ref[...])              # (bn, bk*32) bf16
    acc_ref[...] += jax.lax.dot_general(
        av, bv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # MXU, f32 accumulate

    @pl.when(k == n_k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("k_valid", "block_m", "block_n", "block_k", "interpret"))
def mxu_pm1_matmul(a: jnp.ndarray, b: jnp.ndarray, *, k_valid: int,
                   block_m: int = 128, block_n: int = 128, block_k: int = 16,
                   interpret: bool = False) -> jnp.ndarray:
    """a: (M, W) int32, b: (N, W) int32 -> +-1 dots (M, N) int32 (Eqn 1).

    Packed padding words unpack to -1 in *both* operands and so contribute
    +1 each to the dot; the correction  dot -= (W*32 - k_valid)  restores
    exactness (pad positions always agree: (-1)*(-1) = +1).
    """
    m, w = a.shape
    n, wb = b.shape
    assert w == wb
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, w)
    gm, gn, gk = pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(w, bk)
    a = jnp.pad(a, ((0, gm * bm - m), (0, gk * bk - w)))
    b = jnp.pad(b, ((0, gn * bn - n), (0, gk * bk - w)))

    kwargs = {}
    if not interpret:
        params = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
        if params is not None:
            kwargs["compiler_params"] = params(
                dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_kernel, n_k_steps=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(a, b)
    pad_bits = gk * bk * WORD_BITS - k_valid
    return out[:m, :n] - pad_bits
