"""Data pipelines: deterministic, shardable, restart-safe synthetic sources.

No dataset downloads exist in this environment, so the pipelines generate
synthetic batches — but through the same interface a real loader would use:
host-local generation of each host's shard, ``jax.make_array_from_process_
local_data``-style assembly (single-host here: device_put with the batch
sharding), and a step-indexed PRNG so a restarted job resumes the exact
batch sequence (checkpoint stores only the step counter).
"""

from repro.data.pipeline import (ImagePipeline, LatentPipeline,
                                 TokenPipeline)

__all__ = ["ImagePipeline", "LatentPipeline", "TokenPipeline"]
