"""Synthetic-but-realistic data pipelines (tokens / images / latents).

Design requirements inherited from the fault-tolerance story:

* **step-indexed determinism** — batch ``i`` is a pure function of
  (seed, i): a job restarted from step ``i`` regenerates the identical
  stream with no loader state in the checkpoint;
* **sharded placement** — batches are placed with the step's batch
  sharding (device_put with a NamedSharding), never materialized on one
  device;
* **prefetch** — a small background thread keeps ``prefetch`` batches
  ahead (double-buffering host->device transfer behind compute, the
  single-host analogue of per-host input pipelines).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class _Base:
    seed: int = 0
    prefetch: int = 2

    def batch_at(self, step: int) -> Any:
        raise NotImplementedError

    def __iter__(self) -> Iterator[Any]:
        return self.iter_from(0)

    def iter_from(self, step: int) -> Iterator[Any]:
        """Resume-safe iterator: yields batch(step), batch(step+1), ..."""
        if self.prefetch <= 0:
            i = step
            while True:
                yield self.batch_at(i)
                i += 1
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            i = step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(i), timeout=0.5)
                    i += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


@dataclasses.dataclass
class TokenPipeline(_Base):
    """LM batches: {tokens, labels} (B, S) int32, labels = next-token."""
    batch: int = 8
    seq_len: int = 128
    vocab: int = 256
    sharding: Any = None

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(0, self.vocab,
                            (self.batch, self.seq_len + 1), dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.sharding is not None:
            out = {k: jax.device_put(v, self.sharding)
                   for k, v in out.items()}
        return out


@dataclasses.dataclass
class ImagePipeline(_Base):
    """Vision batches: {images (B,R,R,3) f32 in [0,1], labels (B,)}."""
    batch: int = 8
    img_res: int = 32
    n_classes: int = 10
    sharding: Any = None
    label_sharding: Any = None

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        imgs = rng.random((self.batch, self.img_res, self.img_res, 3),
                          dtype=np.float32)
        labels = rng.integers(0, self.n_classes, (self.batch,),
                              dtype=np.int32)
        out = {"images": imgs, "labels": labels}
        if self.sharding is not None:
            out["images"] = jax.device_put(out["images"], self.sharding)
        if self.label_sharding is not None:
            out["labels"] = jax.device_put(out["labels"],
                                           self.label_sharding)
        return out


@dataclasses.dataclass
class LatentPipeline(_Base):
    """DiT batches: {latents, labels, t, noise} for ε-prediction."""
    batch: int = 8
    latent_res: int = 8
    channels: int = 4
    n_classes: int = 10
    n_timesteps: int = 1000
    sharding: Any = None

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        shape = (self.batch, self.latent_res, self.latent_res,
                 self.channels)
        out = {
            "latents": rng.standard_normal(shape, dtype=np.float32),
            "labels": rng.integers(0, self.n_classes, (self.batch,),
                                   dtype=np.int32),
            "t": rng.integers(0, self.n_timesteps, (self.batch,),
                              dtype=np.int32),
            "noise": rng.standard_normal(shape, dtype=np.float32),
        }
        if self.sharding is not None:
            out["latents"] = jax.device_put(out["latents"], self.sharding)
            out["noise"] = jax.device_put(out["noise"], self.sharding)
        return out
