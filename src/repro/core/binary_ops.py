"""Binary dot products / matmuls via xor + popcount (paper Eqn 1).

With the bit encoding 1 <-> +1, 0 <-> -1, for two packed vectors of
``k_valid`` meaningful bits:

    dot(A, B) = k_valid - 2 * popcount(xor(A, B))

These are the *pure JAX* execution paths: a memory-chunked VPU formulation
(the paper-faithful algorithm) and an MXU formulation that unpacks to +-1
bf16 and uses a real matmul (TPU-idiomatic beyond-paper path).  The Pallas
kernels in ``repro.kernels`` implement the same contracts with explicit VMEM
tiling; ``repro.kernels.ops`` dispatches between all of them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import packing


def packed_matmul_counts(a: jnp.ndarray, b: jnp.ndarray,
                         word_weights: jnp.ndarray | None = None,
                         chunk: int = 4096,
                         impl: str = "xor") -> jnp.ndarray:
    """Popcount-of-xor matmul.

    a: (M, W) int32 packed rows.
    b: (N, W) int32 packed rows (e.g. one row per output filter).
    word_weights: optional (W,) int32 per-word weights (bit-plane powers for
        the first layer, Eqn 2); default all-ones.
    Returns cnt (M, N) int32 where
        cnt[m, n] = sum_w word_weights[w] * popcount(a[m, w] ^ b[n, w]).

    impl selects the count algorithm:

    * ``"xor"`` — the paper's Eqn 1 (xor + popcount on packed words).
      Optimal on wide-bitwise-SIMD hardware (mobile-GPU ALUs, TPU VPU);
      on a host CPU XLA lowers popcount to bit arithmetic and it is slow.
    * ``"pm1"`` — dot reformulation: cnt = (total_bits − dot_pm1)/2 where
      dot_pm1 unpacks both operands to ±1 and uses a real matmul.  Exact
      (padding bits agree in both operands: each contributes +1 to the
      dot and 0 to cnt, and total_bits absorbs them).  This is the
      matmul-engine path (oneDNN on CPU, MXU on TPU) — the beyond-paper
      crossover of DESIGN.md §3.

    The (M, N, W) xor intermediate is materialized in chunks of rows to
    bound memory on the host path.
    """
    if impl == "pm1" and word_weights is None:
        total_bits = a.shape[-1] * packing.WORD_BITS
        av = packing.unpack_to_pm1(a, total_bits, dtype=jnp.float32)
        bv = packing.unpack_to_pm1(b, total_bits, dtype=jnp.float32)
        dot = jax.lax.dot_general(
            av, bv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        return ((total_bits - dot) * 0.5).astype(jnp.int32)
    m = a.shape[0]

    def one_chunk(a_chunk):
        x = jax.lax.bitwise_xor(a_chunk[:, None, :], b[None, :, :])
        c = jax.lax.population_count(x)
        if word_weights is not None:
            c = c * word_weights[None, None, :]
        return jnp.sum(c, axis=-1, dtype=jnp.int32)

    if m <= chunk:
        return one_chunk(a)
    # Static chunking keeps peak memory ~ chunk*N*W.
    pieces = []
    for start in range(0, m, chunk):
        pieces.append(one_chunk(jax.lax.slice_in_dim(a, start, min(start + chunk, m))))
    return jnp.concatenate(pieces, axis=0)


def packed_matmul_dot(a: jnp.ndarray, b: jnp.ndarray, k_valid: int) -> jnp.ndarray:
    """Binary dot products (paper Eqn 1): (M, N) int32 in +-1 arithmetic."""
    return k_valid - 2 * packed_matmul_counts(a, b)


def mxu_pm1_matmul(a: jnp.ndarray, b: jnp.ndarray, k_valid: int,
                   channels: int | None = None,
                   dtype: jnp.dtype = jnp.bfloat16) -> jnp.ndarray:
    """Beyond-paper path: unpack both operands to +-1 and use a dense matmul.

    On TPU the MXU's bf16 throughput (~197 TFLOP/s) can beat VPU popcount for
    large reduction dims despite the 32x data expansion, because the expansion
    happens HBM->VMEM->VREG once per tile.  Here (pure JAX) XLA fuses the
    unpack into the matmul producer.  Exact for k_valid <= 2^24 (bf16 exactly
    represents the integer dot because we accumulate in f32).
    """
    w = a.shape[-1]
    channels = channels if channels is not None else w * packing.WORD_BITS
    av = packing.unpack_to_pm1(a, channels, dtype=dtype)
    bv = packing.unpack_to_pm1(b, channels, dtype=dtype)
    # Padding bits unpack to -1 in both operands -> contribute +1 each; the
    # unpack above slices them away (channels), so no correction is needed.
    out = jax.lax.dot_general(
        av, bv, (((av.ndim - 1,), (bv.ndim - 1,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.astype(jnp.int32)


def binary_dense_counts(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
                        impl: str = "xor") -> jnp.ndarray:
    """Fully-connected layer counts: x (..., W) @ filters (O, W) -> (..., O)."""
    lead = x_packed.shape[:-1]
    flat = x_packed.reshape((-1, x_packed.shape[-1]))
    cnt = packed_matmul_counts(flat, w_packed, impl=impl)
    return cnt.reshape(lead + (w_packed.shape[0],))


@functools.partial(jax.jit, static_argnames=("k_valid",))
def binary_dense_dot(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
                     k_valid: int) -> jnp.ndarray:
    return k_valid - 2 * binary_dense_counts(x_packed, w_packed)
