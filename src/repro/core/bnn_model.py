"""BNN network assembly: spec -> (training forward | packed inference engine).

This is the heart of the PhoneBit engine.  A network is a sequence of layer
specs (Fig 3's conv/pool/dense calls).  Two execution paths share one set of
trained parameters:

* ``float_forward`` — the training path (STE sign, float BN), also the
  end-to-end oracle for the packed engine.
* ``packed_forward`` — the deployed path: everything between the 8-bit input
  and the final full-precision layer is integer xor/popcount/compare on
  channel-packed words (paper §V, §VI).  Produced from trained params by
  :mod:`repro.core.converter` (Fig 2's offline transform).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (binarize, binary_conv, binary_ops, bitplanes,
                        layer_integration, packing)


# --------------------------------------------------------------------------
# Layer specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BConv:
    """Integrated binary conv + BN + binarize (first=True: bit-plane input)."""
    c_in: int
    c_out: int
    kernel: int = 3
    stride: int = 1
    pad: int = 1
    first: bool = False

    @property
    def k_valid(self) -> int:
        return self.kernel * self.kernel * self.c_in


@dataclasses.dataclass(frozen=True)
class Pool:
    """Max pool.  pad = (lo, hi) on both spatial dims; pad values are -1
    (float path) / 0-words (packed path), which agree in the +-1 domain so
    OR-pooling stays the exact oracle (YOLOv2-Tiny's stride-1 pool6 pads
    (0, 1) to keep 13x13, darknet-style)."""
    window: int = 2
    stride: int = 2
    pad: tuple[int, int] = (0, 0)


@dataclasses.dataclass(frozen=True)
class BDense:
    """Integrated binary dense + BN + binarize; input is flattened NHWC."""
    d_in: int
    d_out: int


@dataclasses.dataclass(frozen=True)
class FloatDense:
    """Paper's final full-precision layer (kept float, like conv9 in Fig 5)."""
    d_in: int
    d_out: int


@dataclasses.dataclass(frozen=True)
class FloatConv:
    """Full-precision conv (YOLOv2-Tiny's conv9: 1x1, float in/out)."""
    c_in: int
    c_out: int
    kernel: int = 1
    stride: int = 1
    pad: int = 0


LayerSpec = Any  # BConv | Pool | BDense | FloatDense | FloatConv


# --------------------------------------------------------------------------
# Parameter init (latent float weights for training)
# --------------------------------------------------------------------------

def init_params(key: jax.Array, spec: Sequence[LayerSpec]) -> list[dict]:
    params: list[dict] = []
    for layer in spec:
        if isinstance(layer, BConv):
            key, k1 = jax.random.split(key)
            w = jax.random.uniform(k1, (layer.kernel, layer.kernel,
                                        layer.c_in, layer.c_out),
                                   minval=-1.0, maxval=1.0, dtype=jnp.float32)
            params.append(dict(
                w=w,
                gamma=jnp.ones((layer.c_out,), jnp.float32),
                beta=jnp.zeros((layer.c_out,), jnp.float32),
                mu=jnp.zeros((layer.c_out,), jnp.float32),
                var=jnp.ones((layer.c_out,), jnp.float32),
            ))
        elif isinstance(layer, BDense):
            key, k1 = jax.random.split(key)
            w = jax.random.uniform(k1, (layer.d_in, layer.d_out),
                                   minval=-1.0, maxval=1.0, dtype=jnp.float32)
            params.append(dict(
                w=w,
                gamma=jnp.ones((layer.d_out,), jnp.float32),
                beta=jnp.zeros((layer.d_out,), jnp.float32),
                mu=jnp.zeros((layer.d_out,), jnp.float32),
                var=jnp.ones((layer.d_out,), jnp.float32),
            ))
        elif isinstance(layer, FloatDense):
            key, k1 = jax.random.split(key)
            scale = 1.0 / jnp.sqrt(jnp.float32(layer.d_in))
            params.append(dict(
                w=jax.random.normal(k1, (layer.d_in, layer.d_out),
                                    jnp.float32) * scale,
                b=jnp.zeros((layer.d_out,), jnp.float32),
            ))
        elif isinstance(layer, FloatConv):
            key, k1 = jax.random.split(key)
            fan = layer.kernel * layer.kernel * layer.c_in
            params.append(dict(
                w=jax.random.normal(
                    k1, (layer.kernel, layer.kernel, layer.c_in,
                         layer.c_out), jnp.float32) / jnp.sqrt(
                             jnp.float32(fan)),
                b=jnp.zeros((layer.c_out,), jnp.float32),
            ))
        else:
            params.append({})
    return params


# --------------------------------------------------------------------------
# Training / oracle path (float, STE)
# --------------------------------------------------------------------------

_BN_EPS = 1e-4


def _bn(x, p):
    sigma = jnp.sqrt(p["var"] + _BN_EPS)
    return p["gamma"] * (x - p["mu"]) / sigma + p["beta"]


def float_forward(params: Sequence[dict], spec: Sequence[LayerSpec],
                  x_uint8: jnp.ndarray, *, train: bool = False) -> jnp.ndarray:
    """Float path.  x_uint8: (N, H, W, C) uint8.  Returns final float logits.

    Uses -1 padding for SAME-padded binary convs so it is the exact oracle
    of the packed engine (DESIGN.md §3.2).  With train=True, sign() uses the
    straight-through estimator so the whole net is differentiable w.r.t. the
    latent float weights.
    """
    sign = binarize.ste_sign if train else (
        lambda v: jnp.where(v >= 0, 1.0, -1.0).astype(v.dtype))
    x = x_uint8.astype(jnp.float32)
    for layer, p in zip(spec, params):
        if isinstance(layer, BConv):
            wb = sign(p["w"])
            if layer.first:
                # Integer-valued input conv; padding with 0 (a real 0 pixel).
                x = lax.conv_general_dilated(
                    x, wb, (layer.stride, layer.stride),
                    [(layer.pad, layer.pad)] * 2,
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            else:
                # +-1 activations, -1 padding == pad the float map with -1.
                xp = jnp.pad(x, ((0, 0), (layer.pad, layer.pad),
                                 (layer.pad, layer.pad), (0, 0)),
                             constant_values=-1.0)
                x = lax.conv_general_dilated(
                    xp, wb, (layer.stride, layer.stride), "VALID",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = sign(_bn(x, p))
        elif isinstance(layer, Pool):
            if layer.pad != (0, 0):
                x = jnp.pad(x, ((0, 0), layer.pad, layer.pad, (0, 0)),
                            constant_values=-1.0)
            x = lax.reduce_window(
                x, -jnp.inf, lax.max,
                (1, layer.window, layer.window, 1),
                (1, layer.stride, layer.stride, 1), "VALID")
        elif isinstance(layer, BDense):
            x = x.reshape(x.shape[0], -1)
            x = x @ sign(p["w"])
            x = sign(_bn(x, p))
        elif isinstance(layer, FloatDense):
            x = x.reshape(x.shape[0], -1)
            x = x @ p["w"] + p["b"]
        elif isinstance(layer, FloatConv):
            x = lax.conv_general_dilated(
                x, p["w"], (layer.stride, layer.stride),
                [(layer.pad, layer.pad)] * 2,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    return x


def to_graph(params: Sequence[dict], spec: Sequence[LayerSpec],
             input_hw: tuple[int, int]):
    """Lower trained latent-float params to the *unfused* operator graph.

    Hook into :mod:`repro.runtime` (DESIGN.md §4.2): the unfused graph is
    the input of the optimization-pass pipeline (layout assignment, BN
    integration, epilogue fusion, OR-pool absorption), which converges to
    the same fused graph :func:`repro.core.converter.to_graph` produces
    from an artifact.  Imported lazily to avoid a core→runtime cycle.
    """
    from repro.runtime import lower_trained
    return lower_trained(spec, params, input_hw)


# --------------------------------------------------------------------------
# Packed inference path (the engine)
# --------------------------------------------------------------------------

def packed_forward(packed: Sequence[dict], spec: Sequence[LayerSpec],
                   x_uint8: jnp.ndarray, impl: str = "xor") -> jnp.ndarray:
    """Deployed path on channel-packed int32 words (paper §V/§VI).

    ``packed`` comes from :func:`repro.core.converter.convert`.  All hidden
    layers are integer ops; only the final FloatDense touches floats.
    ``impl`` selects the count algorithm ("xor" = paper Eqn 1, "pm1" =
    matmul-engine reformulation — see binary_ops.packed_matmul_counts).
    """
    x = None
    for layer, p in zip(spec, packed):
        if isinstance(layer, BConv):
            if layer.first:
                planes = bitplanes.pack_bitplanes(x_uint8)      # (N,H,W,8,Cw)
                n, h, w, np_, cw = planes.shape
                flat = planes.reshape(n, h, w, np_ * cw)
                x = binary_conv.binary_conv2d_fused(
                    flat, p["w_packed"], p["thresh"],
                    layer.kernel, layer.kernel, layer.stride, layer.pad,
                    word_weights=p["word_weights"])
            else:
                x = binary_conv.binary_conv2d_fused(
                    x, p["w_packed"], p["thresh"],
                    layer.kernel, layer.kernel, layer.stride, layer.pad,
                    impl=impl)
        elif isinstance(layer, Pool):
            if layer.pad != (0, 0):
                # 0-words == all -1 channels: identity under OR-pooling.
                x = jnp.pad(x, ((0, 0), layer.pad, layer.pad, (0, 0)))
            x = binary_conv.binary_or_maxpool(x, layer.window, layer.stride)
        elif isinstance(layer, BDense):
            flat = x.reshape(x.shape[0], -1)
            x = binary_conv.binary_dense_fused(flat, p["w_packed"],
                                               p["thresh"], impl=impl)
        elif isinstance(layer, FloatDense):
            # Unpack per position *before* flattening so per-word channel
            # padding never leaks into the float matmul.
            xv = packing.unpack_to_pm1(x, p["c_per_pos"], dtype=jnp.float32)
            xv = xv.reshape(xv.shape[0], -1)
            x = xv @ p["w"] + p["b"]
        elif isinstance(layer, FloatConv):
            # Final float conv (paper conv9): unpack the +-1 activations
            # and run a plain float conv, same as the paper's SIMD `dot`.
            xv = packing.unpack_to_pm1(x, p["c_per_pos"], dtype=jnp.float32)
            x = lax.conv_general_dilated(
                xv, p["w"], (layer.stride, layer.stride),
                [(layer.pad, layer.pad)] * 2,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
    return x
