"""First-layer bit-plane decomposition (paper §III-B, Eqn 2).

8-bit input images are split into 8 bit-planes I_n in {0,1}; binary
convolution runs on each plane against the same binary weights and the
results are recombined as s = sum_n 2^(n-1) <I_n . W>.

Layout: planes are packed along the channel dimension per plane —
(N, H, W, C) uint8  ->  (N, H, W, 8, Cw) int32 — so a patch flattens to
KH*KW*8*Cw words and a *single* weighted-popcount matmul (word weight
2^(n-1) per plane) produces the whole Eqn-2 sum.  See
``layer_integration.fold_bn_first_layer`` for how the weighted count folds
into the integer threshold.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing

NUM_PLANES = 8


def split_bitplanes(x: jnp.ndarray) -> jnp.ndarray:
    """(..., C) uint8/int -> (..., 8, C) int32 bits, plane n at index n-1."""
    x = jnp.asarray(x).astype(jnp.int32)
    shifts = jnp.arange(NUM_PLANES, dtype=jnp.int32)
    return (x[..., None, :] >> shifts[:, None]) & 1


def pack_bitplanes(x: jnp.ndarray) -> jnp.ndarray:
    """(N, H, W, C) uint8 -> (N, H, W, 8, Cw) packed int32 planes."""
    return packing.pack_bits(split_bitplanes(x), axis=-1)


def plane_word_weights(c_words: int) -> jnp.ndarray:
    """(8*Cw,) int32 word-weight vector: 2^(n-1) for every word of plane n."""
    w = jnp.left_shift(jnp.int32(1), jnp.arange(NUM_PLANES, dtype=jnp.int32))
    return jnp.repeat(w, c_words)


def recombine_planes(dots: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    """Reference Eqn 2: sum_n 2^(n-1) * dots_n along ``axis`` (plane dim)."""
    n = dots.shape[axis]
    w = jnp.left_shift(jnp.int32(1), jnp.arange(n, dtype=jnp.int32))
    shape = [1] * dots.ndim
    shape[axis] = n
    return jnp.sum(dots * w.reshape(shape), axis=axis)
