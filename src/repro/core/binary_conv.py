"""Packed binary convolution + pooling on the NHWC channel-compressed layout.

The convolution is im2col over *packed* words: spatial patches are gathered
with static strided slices (the packed channel words stay contiguous,
preserving the locality-friendly layout of §V-A), then a single
xor-popcount matmul produces counts for all output positions x filters.

Padding semantics: spatial padding inserts 0-words == 32 channels of -1,
i.e. the -1-padding convention of the reference BNN implementations (see
DESIGN.md §3.2).  The float oracles use the identical convention, so packed
results are bit-exact against them.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax.numpy as jnp
from jax import lax

from repro.core import binary_ops, layer_integration, packing


def conv_out_size(size: int, k: int, stride: int, pad: int) -> int:
    return (size + 2 * pad - k) // stride + 1


def extract_patches_packed(x: jnp.ndarray, kh: int, kw: int,
                           stride: int = 1, pad: int = 0) -> jnp.ndarray:
    """im2col on packed input.

    x: (N, H, W, Cw) int32 (Cw may itself be 8*Cw for bit-plane input that
       was reshaped to a flat word dim — the function is agnostic).
    Returns (N, OH, OW, kh*kw*Cw) int32; patch words ordered (kh, kw, Cw)
    major-to-minor so filter packing must match (`pack_conv_weights`).
    """
    n, h, w, cw = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = conv_out_size(h, kh, stride, pad)
    ow = conv_out_size(w, kw, stride, pad)
    slices = []
    for i in range(kh):
        for j in range(kw):
            s = lax.slice(
                x,
                (0, i, j, 0),
                (n, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, cw),
                (1, stride, stride, 1),
            )
            slices.append(s)
    return jnp.concatenate(slices, axis=-1)


def im2col_matmul(x: jnp.ndarray, kh: int, kw: int, stride: int = 1,
                  pad: int = 0) -> tuple[jnp.ndarray, tuple[int, int, int]]:
    """Canonical im2col lowering shared by every im2col conv backend.

    Returns ``(patches_2d, (n, oh, ow))`` where ``patches_2d`` is the
    matmul-shaped ``(n*oh*ow, kh*kw*Cw)`` view of the packed patches.  The
    direct-conv kernel (DESIGN.md §5) is the path that *avoids* building
    this tensor; everything that does build it must come through here so
    patch/filter word order stays in one place (`pack_conv_weights`).
    """
    patches = extract_patches_packed(x, kh, kw, stride, pad)
    n, oh, ow, pw = patches.shape
    return patches.reshape(n * oh * ow, pw), (n, oh, ow)


def pack_conv_weights(w: jnp.ndarray) -> jnp.ndarray:
    """(KH, KW, C, O) +-1/float weights -> (O, KH*KW*Cw) packed filters.

    Word order matches extract_patches_packed: (kh, kw, word) major->minor.
    """
    kh, kw, c, o = w.shape
    packed = packing.pack_signs(w, axis=2)          # (KH, KW, Cw, O)
    packed = jnp.transpose(packed, (3, 0, 1, 2))    # (O, KH, KW, Cw)
    return packed.reshape(o, kh * kw * packed.shape[-1])


def binary_conv2d_counts(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
                         kh: int, kw: int, stride: int = 1, pad: int = 0,
                         word_weights: jnp.ndarray | None = None,
                         impl: str = "xor") -> jnp.ndarray:
    """Counts cnt[n,oh,ow,o] = sum_w ww[w] * popcount(patch ^ filter).

    x_packed: (N, H, W, Cw); w_packed: (O, kh*kw*Cw).
    """
    flat, (n, oh, ow) = im2col_matmul(x_packed, kh, kw, stride, pad)
    cnt = binary_ops.packed_matmul_counts(flat, w_packed,
                                          word_weights=word_weights,
                                          impl=impl)
    return cnt.reshape(n, oh, ow, w_packed.shape[0])


def binary_conv2d_dot(x_packed, w_packed, k_valid: int, kh: int, kw: int,
                      stride: int = 1, pad: int = 0) -> jnp.ndarray:
    """+-1 dot products: K - 2*cnt (paper Eqn 1), int32 NHWO."""
    cnt = binary_conv2d_counts(x_packed, w_packed, kh, kw, stride, pad)
    return k_valid - 2 * cnt


def binary_conv2d_fused(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
                        p: layer_integration.IntegratedParams,
                        kh: int, kw: int, stride: int = 1, pad: int = 0,
                        word_weights: jnp.ndarray | None = None,
                        impl: str = "xor") -> jnp.ndarray:
    """Integrated conv+BN+binarize producing *packed* output (paper C4+C6).

    Output: (N, OH, OW, Ow) int32 — output filters binarized against the
    integer thresholds and bit-packed along the output-channel dim, the
    TPU analogue of Fig 4's 8-filters-per-thread byte packing.
    """
    cnt = binary_conv2d_counts(x_packed, w_packed, kh, kw, stride, pad,
                               word_weights=word_weights, impl=impl)
    bits = layer_integration.apply_threshold(cnt, p)
    return packing.pack_bits(bits, axis=-1)


def binary_or_maxpool(x_packed: jnp.ndarray, window: int, stride: int,
                      pad: tuple[int, int] = (0, 0)) -> jnp.ndarray:
    """Max-pool on packed binary maps = bitwise OR over the window.

    sign() is monotone, so maxpool-then-binarize == binarize-then-OR-pool;
    pooling never leaves the packed domain (no unpack round-trip).
    ``pad`` spatially pads with 0-words (32 channels of -1 — the OR
    identity) on both dims before pooling.
    """
    if tuple(pad) != (0, 0):
        x_packed = jnp.pad(x_packed, ((0, 0), pad, pad, (0, 0)))
    n, h, w, cw = x_packed.shape
    oh = (h - window) // stride + 1
    ow = (w - window) // stride + 1
    out = None
    for i in range(window):
        for j in range(window):
            s = lax.slice(
                x_packed,
                (0, i, j, 0),
                (n, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, cw),
                (1, stride, stride, 1),
            )
            out = s if out is None else (out | s)
    return out


def binary_dense_fused(x_packed: jnp.ndarray, w_packed: jnp.ndarray,
                       p: layer_integration.IntegratedParams,
                       impl: str = "xor") -> jnp.ndarray:
    """Integrated dense+BN+binarize with packed output (..., Ow)."""
    cnt = binary_ops.binary_dense_counts(x_packed, w_packed, impl=impl)
    bits = layer_integration.apply_threshold(cnt, p)
    return packing.pack_bits(bits, axis=-1)


def final_float_dense(x_packed: jnp.ndarray, w: jnp.ndarray,
                      b: jnp.ndarray | None, channels: int,
                      dtype=jnp.float32) -> jnp.ndarray:
    """Paper's final full-precision layer: unpack +-1 acts, float matmul."""
    xv = packing.unpack_to_pm1(x_packed, channels, dtype=dtype)
    out = xv @ w.astype(dtype)
    if b is not None:
        out = out + b.astype(dtype)
    return out
